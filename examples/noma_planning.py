"""ECC planning across the 10 assigned LM architectures: how the optimal
split point moves with the radio environment and QoS weights — plus an
online *fleet* re-planning demo over correlated-fading scenarios, sharded
across devices when more than one is available.

  PYTHONPATH=src python examples/noma_planning.py
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/noma_planning.py   # sharded fleet demo
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import GdConfig, make_env, make_weights, planner, profiles
from repro.planning import PlannerEngine
from repro.pshard import fleet_mesh, shard_fleet
from repro.scenarios import Scenario, presets

cfg_gd = GdConfig(max_iters=150)

print(f"{'arch':26s} {'w_T=0.2':>8s} {'w_T=0.5':>8s} {'w_T=0.8':>8s}   (split layer s*/F)")
env = make_env(jax.random.PRNGKey(0), n_users=12, n_aps=3, n_sub=4)
for name in configs.all_names():
    arch = configs.get(name)
    prof = profiles.from_arch_config(arch, seq=128)
    engine = PlannerEngine(prof, cfg=cfg_gd)
    row = []
    for wt in (0.2, 0.5, 0.8):
        state = engine.plan(env, make_weights(env.n_users, wt))
        row.append(f"{int(state.plan.s):3d}/{arch.n_layers}")
    print(f"{name:26s} {row[0]:>8s} {row[1]:>8s} {row[2]:>8s}")

print("\nHigher w_T (latency matters more) pushes the split toward the edge"
      "\n(s* -> 0, full offload); higher w_E keeps layers on the device.")

# The pre-engine facade still works (deprecated; one call to keep it covered):
legacy = planner.plan(env, profiles.nin(), make_weights(env.n_users), cfg_gd)
fresh = PlannerEngine(profiles.nin(), cfg=cfg_gd).plan(env)
assert int(legacy.s) == int(fresh.plan.s), "facade drifted from the engine"

# --------------------------------------------------------------------------
# Online fleet re-planning: B independent hotspot scenarios with correlated
# fading evolve in parallel; one compiled program warm-starts all of them
# each epoch. With multiple devices the fleet is sharded over a mesh
# (shard_map) and the whole loop dispatches asynchronously — nothing syncs
# to host except the printed report.
# --------------------------------------------------------------------------
scfg = presets.get("iot_massive")
fleet = max(1, jax.device_count())
mesh = fleet_mesh() if jax.device_count() > 1 else None
print(f"\nOnline fleet: preset={scfg.name}, U={scfg.n_users}, N={scfg.n_aps}, "
      f"M={scfg.n_sub}, fading rho={scfg.rho:.3f}, B={fleet}"
      + (f", sharded over {jax.device_count()} devices" if mesh else " (vmap)"))
engine = PlannerEngine(
    profiles.nin(),
    weights=make_weights(scfg.n_users),
    cfg=GdConfig(step_size=1e-2, eps=1e-4, max_iters=400, optimizer="adam"),
    mesh=mesh,
)
sc = Scenario(scfg)
states = sc.init_many(jax.random.split(jax.random.PRNGKey(7), fleet))
plan_state, key = None, jax.random.PRNGKey(8)
print(f"{'epoch':>5} {'gd_iters':>9} {'mean_util':>10} {'mean_rho_est':>13} {'s*':>12}")
for t in range(6):
    envs = sc.env_many(states)
    if mesh is not None:
        envs = shard_fleet(envs, mesh)   # place the fleet on the mesh once
    plan_state = engine.replan_many(plan_state, envs)
    rho_est = ("      (cold)" if plan_state.warm_rho is None
               else f"{float(jnp.mean(plan_state.warm_rho)):13.4f}")
    print(f"{t:5d} {int(jnp.sum(plan_state.total_iters)):9d}"
          f" {float(jnp.mean(plan_state.plan.utility)):10.4f} {rho_est}"
          f" {str(list(map(int, plan_state.plan.s))):>12}")
    key, k = jax.random.split(key)
    states = sc.step_many(jax.random.split(k, fleet), states)
print("Epoch 0 is a cold solve; later epochs warm-start every fleet member"
      "\nfrom its previous optimum on device (the rho gate and Adam resume"
      "\nare traced into the compiled program). See benchmarks/online_replan.py"
      "\nfor warm-vs-cold numbers and the --mesh sharded mode.")
