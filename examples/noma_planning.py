"""ECC planning across the 10 assigned LM architectures: how the optimal
split point moves with the radio environment and QoS weights — plus an
online re-planning demo over a correlated-fading episode.

  PYTHONPATH=src python examples/noma_planning.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import GdConfig, make_env, make_weights, planner, profiles
from repro.planning import PlannerEngine
from repro.scenarios import Scenario, presets

cfg_gd = GdConfig(max_iters=150)

print(f"{'arch':26s} {'w_T=0.2':>8s} {'w_T=0.5':>8s} {'w_T=0.8':>8s}   (split layer s*/F)")
env = make_env(jax.random.PRNGKey(0), n_users=12, n_aps=3, n_sub=4)
for name in configs.all_names():
    arch = configs.get(name)
    prof = profiles.from_arch_config(arch, seq=128)
    row = []
    for wt in (0.2, 0.5, 0.8):
        w = make_weights(env.n_users, wt)
        plan = planner.plan(env, prof, w, cfg_gd)
        row.append(f"{int(plan.s):3d}/{arch.n_layers}")
    print(f"{name:26s} {row[0]:>8s} {row[1]:>8s} {row[2]:>8s}")

print("\nHigher w_T (latency matters more) pushes the split toward the edge"
      "\n(s* -> 0, full offload); higher w_E keeps layers on the device.")

# --------------------------------------------------------------------------
# Online re-planning: a hotspot scenario with time-correlated fading. The
# engine warm-starts each epoch from the previous optimum, so tracking the
# channel costs a fraction of a fresh solve.
# --------------------------------------------------------------------------
scfg = presets.get("iot_massive")
print(f"\nOnline episode: preset={scfg.name}, U={scfg.n_users}, "
      f"N={scfg.n_aps}, M={scfg.n_sub}, fading rho={scfg.rho:.3f}")
prof = profiles.nin()
engine = PlannerEngine(
    prof,
    weights=make_weights(scfg.n_users),
    cfg=GdConfig(step_size=1e-2, eps=1e-4, max_iters=400, optimizer="adam"),
)
state = None
print(f"{'epoch':>5} {'s*':>4} {'gd_iters':>9} {'utility':>9}")
for t, env in enumerate(Scenario(scfg).episode(jax.random.PRNGKey(7), 8)):
    state = engine.replan(state, env)
    print(f"{t:5d} {int(state.plan.s):4d} {int(state.total_iters):9d}"
          f" {float(state.plan.utility):9.4f}")
print("Epoch 0 is a cold solve; later epochs warm-start from the previous"
      "\noptimum and need far fewer GD iterations when the channel stays"
      "\ncorrelated (Corollary 4, applied across time). See"
      "\nbenchmarks/online_replan.py for the warm-vs-cold comparison.")
