"""ECC planning across the 10 assigned LM architectures: how the optimal
split point moves with the radio environment and QoS weights.

  PYTHONPATH=src python examples/noma_planning.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import GdConfig, make_env, make_weights, planner, profiles

cfg_gd = GdConfig(max_iters=150)

print(f"{'arch':26s} {'w_T=0.2':>8s} {'w_T=0.5':>8s} {'w_T=0.8':>8s}   (split layer s*/F)")
env = make_env(jax.random.PRNGKey(0), n_users=12, n_aps=3, n_sub=4)
for name in configs.all_names():
    arch = configs.get(name)
    prof = profiles.from_arch_config(arch, seq=128)
    row = []
    for wt in (0.2, 0.5, 0.8):
        w = make_weights(env.n_users, wt)
        plan = planner.plan(env, prof, w, cfg_gd)
        row.append(f"{int(plan.s):3d}/{arch.n_layers}")
    print(f"{name:26s} {row[0]:>8s} {row[1]:>8s} {row[2]:>8s}")

print("\nHigher w_T (latency matters more) pushes the split toward the edge"
      "\n(s* -> 0, full offload); higher w_E keeps layers on the device.")
