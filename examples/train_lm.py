"""End-to-end training driver example: a ~100M-class LM (xlstm-125m, full
config at reduced sequence/batch so it runs on CPU) for a few hundred steps
with checkpoint/restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

(On a real pod you would pass --mesh 16x16 and the full batch; this example
exercises the same code path end-to-end on 1 device.)
"""
import argparse
import sys

from repro.launch.train import main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args, _ = ap.parse_known_args()
    sys.argv = [sys.argv[0],
                "--arch", args.arch, "--reduced",
                "--steps", str(args.steps),
                "--batch", "8", "--seq", "64", "--mesh", "1x1",
                "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "100"]
    main()
