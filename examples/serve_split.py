"""End-to-end split serving: a PlannerEngine plans the split for an LM
architecture, then batched requests run through the device-stage /
edge-stage programs (the paper's deployment, with the NOMA uplink
simulated). An online deployment keeps the engine and feeds the returned
PlanState back through engine.replan() as the channel evolves — see
runtime.serve.OnlineSplitServer.

  PYTHONPATH=src python examples/serve_split.py --arch qwen1.5-0.5b
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or ["--arch", "qwen1.5-0.5b"])

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    sys.argv += ["--reduced", "--requests", "4", "--seq", "48",
                 "--new-tokens", "4"]
    main()
