"""Quickstart: plan a NOMA split-inference deployment with ECC/Li-GD.

  PYTHONPATH=src python examples/quickstart.py

Builds a 3-cell NOMA network with 12 mobile users, profiles VGG16, runs the
Li-GD planner, and compares against the paper's baselines.
"""
import jax
import jax.numpy as jnp

from repro.core import (
    GdConfig,
    baselines,
    make_env,
    make_weights,
    planner,
    profiles,
)

# 1. a radio environment: 12 users, 3 APs, 4 subchannels (Rayleigh fading,
#    nearest-AP association, paper Sec. VI.A constants)
env = make_env(jax.random.PRNGKey(0), n_users=12, n_aps=3, n_sub=4)

# 2. the model to split: VGG16's per-layer FLOPs + activation sizes
prof = profiles.vgg16()
print(f"model: {prof.name}, {prof.n_layers} layers, "
      f"{float(jnp.sum(prof.fl)) / 1e9:.2f} GFLOPs")

# 3. per-user QoS weights (omega_T = latency weight, paper eq. 19)
weights = make_weights(env.n_users, w_T=0.5)

# 4. run the Li-GD planner (paper Table I)
plan = planner.plan(env, prof, weights, GdConfig(max_iters=250))
print(f"split layer s* = {int(plan.s)} / {prof.n_layers}"
      f"  (0 = full offload, {prof.n_layers} = device-only)")
print(f"uplink subchannels: {jax.device_get(plan.sub_up)}")
print(f"tx power (W): {jax.device_get(plan.p_up).round(3)}")
print(f"edge compute units: {jax.device_get(plan.r).round(2)}")
print(f"total Li-GD iterations: {int(jnp.sum(plan.iters))}")

# 5. compare against the paper's baselines
res = planner.compare_all(env, prof, weights)
dev = res["device_only"]
print("\nmethod          mean T (ms)   mean E (mJ)   speedup   E-reduction")
for name, o in res.items():
    print(f"{name:15s} {float(jnp.mean(o.T))*1e3:10.2f} "
          f"{float(jnp.mean(o.E))*1e3:12.2f} "
          f"{float(jnp.mean(dev.T)/jnp.mean(o.T)):9.2f} "
          f"{float(jnp.mean(dev.E)/jnp.mean(o.E)):12.3f}")
