"""Shared fixtures. NOTE: CI runs this suite with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the shard_map fleet
tests (test_fleet_sharding.py) exercise a real multi-device mesh; they skip
at lower device counts. The 512-device override still belongs ONLY to the
launch/dryrun.py subprocess (which sets its own XLA_FLAGS)."""
import jax
import pytest

from repro.core import GdConfig, make_env, make_weights


@pytest.fixture(scope="session")
def small_env():
    return make_env(jax.random.PRNGKey(0), n_users=8, n_aps=2, n_sub=4)


@pytest.fixture(scope="session")
def weights(small_env):
    return make_weights(small_env.n_users, 0.5)


@pytest.fixture(scope="session")
def gd_cfg():
    return GdConfig(step_size=5e-3, max_iters=120)
