"""Shared fixtures. NOTE: device count must stay 1 here (the 512-device
XLA_FLAGS override belongs ONLY to launch/dryrun.py)."""
import jax
import pytest

from repro.core import GdConfig, make_env, make_weights


@pytest.fixture(scope="session")
def small_env():
    return make_env(jax.random.PRNGKey(0), n_users=8, n_aps=2, n_sub=4)


@pytest.fixture(scope="session")
def weights(small_env):
    return make_weights(small_env.n_users, 0.5)


@pytest.fixture(scope="session")
def gd_cfg():
    return GdConfig(step_size=5e-3, max_iters=120)
