"""Scenario subsystem: correlated fading, mobility, churn, presets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.scenarios import Scenario, ScenarioConfig, fading, mobility, presets


def _static_cfg(**kw):
    base = dict(n_users=8, n_aps=2, n_sub=4, speed_mps=0.0,
                arrival_rate_hz=0.0)
    base.update(kw)
    return ScenarioConfig(**base)


def test_episode_static_shapes_and_finite():
    sc = Scenario(_static_cfg(fading_rho=0.9))
    envs = sc.episode_list(jax.random.PRNGKey(0), 4)
    assert len(envs) == 4
    for env in envs:
        assert env.g_up.shape == (8, 2, 4)
        assert env.g_dn.shape == (2, 8, 4)
        assert bool(jnp.all(jnp.isfinite(env.g_up))) and bool(jnp.all(env.g_up > 0))
        assert bool(jnp.all((env.ap >= 0) & (env.ap < 2)))


def test_fading_marginal_is_rayleigh():
    """|h|^2 of the CN(0,1) coefficients is Exp(1): mean 1, matching the
    i.i.d. fading that make_env draws."""
    h = fading.init_coeffs(jax.random.PRNGKey(0), (64, 4, 16))
    g = fading.power_gain(h)
    assert float(jnp.mean(g)) == pytest.approx(1.0, abs=0.08)
    # AR(1) step preserves the marginal
    h2 = fading.gauss_markov_step(jax.random.PRNGKey(1), h, 0.7)
    assert float(jnp.mean(fading.power_gain(h2))) == pytest.approx(1.0, abs=0.08)


def test_fading_correlation_tracks_rho():
    """corr(|h_t|^2, |h_{t+1}|^2) = rho^2 for the Gauss-Markov process."""
    key = jax.random.PRNGKey(2)
    h = fading.init_coeffs(key, (128, 4, 16))
    for rho, lo, hi in ((0.98, 0.90, 1.0), (0.0, -0.15, 0.15)):
        h2 = fading.gauss_markov_step(jax.random.PRNGKey(3), h, rho)
        g1 = np.asarray(fading.power_gain(h)).ravel()
        g2 = np.asarray(fading.power_gain(h2)).ravel()
        corr = float(np.corrcoef(g1, g2)[0, 1])
        assert lo <= corr <= hi, (rho, corr)


def test_jakes_rho_limits():
    assert fading.jakes_rho(0.0, 0.1) == pytest.approx(1.0)
    r_slow = fading.jakes_rho(1.0, 0.1)
    r_fast = fading.jakes_rho(50.0, 0.1)
    assert 0.0 <= r_fast < r_slow <= 1.0


def test_mobility_stays_in_area_and_moves():
    cfg = _static_cfg(speed_mps=10.0, fading_rho=1.0)
    sc = Scenario(cfg)
    state = sc.init(jax.random.PRNGKey(0))
    p0 = state.mob.pos
    for i in range(5):
        state = sc.step(jax.random.PRNGKey(10 + i), state)
        assert bool(jnp.all((state.mob.pos >= 0.0) & (state.mob.pos <= cfg.side_m)))
    assert float(jnp.max(jnp.abs(state.mob.pos - p0))) > 0.0


def test_static_scenario_is_static():
    """speed 0, churn 0, rho 1 -> the environment does not change at all."""
    sc = Scenario(_static_cfg(fading_rho=1.0))
    state = sc.init(jax.random.PRNGKey(0))
    e0 = sc.env(state)
    state = sc.step(jax.random.PRNGKey(1), state)
    e1 = sc.env(state)
    np.testing.assert_allclose(np.asarray(e0.g_up), np.asarray(e1.g_up), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(e0.ap), np.asarray(e1.ap))


def test_churn_replaces_users():
    cfg = _static_cfg(arrival_rate_hz=1e4, epoch_dt_s=1.0, fading_rho=1.0)
    sc = Scenario(cfg)
    state = sc.init(jax.random.PRNGKey(0))
    p0 = np.asarray(state.mob.pos)
    state = sc.step(jax.random.PRNGKey(1), state)
    moved = np.any(np.abs(np.asarray(state.mob.pos) - p0) > 1e-6, axis=-1)
    assert moved.all()  # rate*dt >> U: every slot replaced


def test_hotspot_clustering_concentrates_users():
    cfg = ScenarioConfig(n_users=32, n_aps=2, n_sub=4, cluster_frac=1.0,
                         n_clusters=1, cluster_radius_m=10.0, speed_mps=0.0)
    uni = ScenarioConfig(n_users=32, n_aps=2, n_sub=4, cluster_frac=0.0,
                         speed_mps=0.0)
    key = jax.random.PRNGKey(4)
    pos_c = Scenario(cfg).init(key).mob.pos
    pos_u = Scenario(uni).init(key).mob.pos
    spread = lambda p: float(jnp.mean(jnp.linalg.norm(p - jnp.mean(p, 0), axis=-1)))
    assert spread(pos_c) < spread(pos_u) * 0.5


def test_presets_generate_valid_episodes():
    assert set(presets.names()) == {"dense_urban", "highway", "hotspot",
                                    "iot_massive"}
    for name in presets.names():
        cfg = presets.get(name)
        assert 0.0 <= cfg.rho <= 1.0
        sc = Scenario(cfg)
        env = next(sc.episode(jax.random.PRNGKey(5), 1))
        assert env.g_up.shape == (cfg.n_users, cfg.n_aps, cfg.n_sub)
    with pytest.raises(KeyError):
        presets.get("metaverse")


def test_scenario_cfg_read_only():
    """The jitted fleet ops close over the config at first use; mutating it
    afterwards would be silently ignored, so the attribute refuses writes."""
    sc = Scenario(_static_cfg())
    with pytest.raises(AttributeError):
        sc.cfg = _static_cfg(n_users=5)
    assert sc.cfg.n_users == 8
