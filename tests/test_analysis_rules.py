"""Auditor self-tests: every rule in the repro.analysis catalog has a
clean case (a conforming program passes) and a violating case (the defect
is flagged with an actionable message), plus the visitor's derived-VMEM /
analytic-model parity check that anchors VmemCeiling to the numbers
tests/test_kernels.py budgets against."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import make_env
from repro.kernels import ops
from repro.kernels.noma_rates import dense_tile_count, vmem_block_bytes

U, N, M = 8, 2, 4


@pytest.fixture(scope="module")
def env():
    return make_env(jax.random.PRNGKey(0), n_users=U, n_aps=N, n_sub=M)


@pytest.fixture(scope="module")
def tx(env):
    beta = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(M), (U,))
    p = jax.random.uniform(jax.random.PRNGKey(2), (U,),
                           minval=0.01, maxval=0.3)
    return beta * p[:, None]


def _kernel_fn(env, **blocks):
    def f(t):
        intra, inter = ops.noma_pairwise_up(env, t, interpret=True, **blocks)
        return intra + inter
    return f


# ---------------------------------------------------------------------------
# NoHostTransfer
# ---------------------------------------------------------------------------
def test_no_host_transfer_rule():
    rule = analysis.NoHostTransfer()
    assert analysis.audit(lambda x: x * 2.0, jnp.ones(3), rules=[rule]).ok

    def leaky(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) + 1.0,
            jax.ShapeDtypeStruct((3,), jnp.float32), x)
        return y * 2.0

    # jit-wrapped: the callback sits inside a pjit sub-jaxpr, proving the
    # visitor recurses into call params rather than only scanning top level
    bad = analysis.audit(jax.jit(leaky), jnp.ones(3), rules=[rule])
    assert not bad.ok
    assert bad.findings[0].rule == "no_host_transfer"
    assert "host round-trip" in bad.findings[0].message
    with pytest.raises(analysis.AuditError):
        bad.raise_if_failed()


# ---------------------------------------------------------------------------
# NoPairwiseIntermediate / NoGatherAbove / NoPad3D
# ---------------------------------------------------------------------------
def test_no_pairwise_intermediate_rule(env, tx):
    rule = analysis.NoPairwiseIntermediate(U)
    # the kernel path streams the pairwise tensor; (BU, BV, BM) arithmetic
    # inside the pallas body must NOT count as a materialization
    assert analysis.audit(_kernel_fn(env), tx, rules=[rule]).ok

    def materialized(g):  # (U, U, M) elementwise arithmetic
        return jnp.sum(g * 2.0 + 1.0, axis=1)

    bad = analysis.audit(materialized, jnp.ones((U, U, M)), rules=[rule])
    assert len(bad.findings) >= 2      # mul and add both flagged
    assert "backend='pallas'" in bad.findings[0].message
    # leading batch dims (vmapped fleet programs) still flag
    vbad = analysis.audit(jax.vmap(materialized),
                          jnp.ones((3, U, U, M)), rules=[rule])
    assert not vbad.ok


def test_no_gather_above_rule(env, tx):
    rule = analysis.NoGatherAbove(U)
    assert analysis.audit(_kernel_fn(env), tx, rules=[rule]).ok

    def gathered(g_up, ap):  # the g[:, ap, :] materialization li_gd dropped
        return g_up[:, ap, :]

    bad = analysis.audit(gathered, jnp.ones((U, N, M)),
                         jnp.zeros((U,), jnp.int32), rules=[rule])
    assert not bad.ok
    assert "in-kernel" in bad.findings[0].message
    # the own-gain (U, 1, M) take_along_axis stays below the bar
    own = analysis.audit(
        lambda g, ap: jnp.take_along_axis(g, ap[:, None, None], axis=1),
        jnp.ones((U, N, M)), jnp.zeros((U,), jnp.int32), rules=[rule])
    assert own.ok


def test_no_pad_3d_rule(env, tx):
    rule = analysis.NoPad3D()
    assert analysis.audit(_kernel_fn(env), tx, rules=[rule]).ok
    bad = analysis.audit(
        lambda g: jnp.pad(g, ((0, 3), (0, 0), (0, 0))),
        jnp.ones((U, N, M)), rules=[rule])
    assert not bad.ok
    assert "unpadded" in bad.findings[0].message
    # rank-2 pads (e.g. beta padding in reference code) are not the target
    assert analysis.audit(lambda b: jnp.pad(b, ((0, 3), (0, 0))),
                          jnp.ones((U, M)), rules=[rule]).ok


# ---------------------------------------------------------------------------
# VmemCeiling + the derived/analytic parity that makes it trustworthy
# ---------------------------------------------------------------------------
def test_vmem_ceiling_rule(env, tx):
    fn = _kernel_fn(env)
    assert analysis.audit(fn, tx, rules=[analysis.VmemCeiling()]).ok
    bad = analysis.audit(fn, tx, rules=[analysis.VmemCeiling(budget_bytes=64)])
    assert not bad.ok
    f = bad.findings[0]
    assert f.rule == "vmem_ceiling" and "shrink" in f.message
    assert f.detail["vmem_bytes"] > 64


def test_derived_vmem_matches_analytic_model(env, tx):
    """The visitor's per-block byte count (summed over the kernel body's
    non-SMEM refs) must equal noma_rates.vmem_block_bytes for the same
    blocks: the rule and the budget tests then share one ground truth."""
    blocks = dict(block_u=8, block_v=8, block_m=4, block_n=2)
    closed = analysis.trace(_kernel_fn(env, **blocks), tx)
    pcs = analysis.pallas_calls(closed.jaxpr)
    assert pcs, "no pallas_call in the kernel program"
    derived = max(pc.vmem_bytes for pc in pcs)
    analytic = vmem_block_bytes(8, 8, 4, 2, n_aps=env.n_aps,
                                direction="fwd", uplink=True)
    assert derived == analytic, (derived, analytic)


# ---------------------------------------------------------------------------
# SparseGrid
# ---------------------------------------------------------------------------
def test_sparse_grid_rule(env, tx):
    fn = _kernel_fn(env)
    expect = dense_tile_count(U, U)    # layout=None -> dense schedule
    assert analysis.audit(fn, tx, rules=[analysis.SparseGrid(expect)]).ok

    bad = analysis.audit(fn, tx, rules=[analysis.SparseGrid(expect + 5)])
    assert not bad.ok
    f = bad.findings[0]
    assert f.rule == "sparse_grid" and "tile list" in f.message
    assert f.detail["grid"][-1] == expect

    # a program with no tile-driven kernel at all: flagged when required,
    # tolerated when not (einsum reference programs)
    no_kernel = analysis.audit(lambda x: x * 2.0, jnp.ones(3),
                               rules=[analysis.SparseGrid(expect)])
    assert not no_kernel.ok
    assert "no tile-driven" in no_kernel.findings[0].message
    assert analysis.audit(
        lambda x: x * 2.0, jnp.ones(3),
        rules=[analysis.SparseGrid(expect, require=False)]).ok


# ---------------------------------------------------------------------------
# StableSignature
# ---------------------------------------------------------------------------
def test_stable_signature_rule():
    rule = analysis.StableSignature()
    assert analysis.audit(lambda x: x * 2.0, jnp.ones(3), rules=[rule]).ok
    # python-scalar select: the classic weak-f32 producer (the PR 3 bug
    # shape -- a weak leaf in cold output re-traces the warm program)
    bad = analysis.audit(lambda x: jnp.where(x > 0, 1.0, 0.0),
                         jnp.ones(3), rules=[rule])
    assert not bad.ok
    assert "weak-typed" in bad.findings[0].message
    assert "_strong_typed" in bad.findings[0].message


def test_stable_signature_compare():
    a = jax.eval_shape(lambda x: (x, x.sum()), jnp.ones((4, 2)))
    same = analysis.StableSignature.compare("t", a, a)
    assert same == []
    b = jax.eval_shape(lambda x: (x, x.sum().astype(jnp.int32)),
                       jnp.ones((4, 2)))
    diff = analysis.StableSignature.compare("t", a, b)
    assert diff and "recompile every epoch" in diff[0].message
    # tree-structure drift is its own finding, not a zip truncation
    c = jax.eval_shape(lambda x: (x,), jnp.ones((4, 2)))
    assert analysis.StableSignature.compare("t", a, c)


# ---------------------------------------------------------------------------
# catalog plumbing
# ---------------------------------------------------------------------------
def test_catalog_describe_and_report_roundtrip():
    for cls in analysis.CATALOG:
        assert cls.name != "rule"
        doc = (cls.__doc__ or "").strip()
        assert doc, f"{cls.__name__} has no docstring for describe()"
    bad = analysis.audit(lambda x: jnp.where(x > 0, 1.0, 0.0), jnp.ones(3),
                         rules=[analysis.StableSignature()],
                         label="weak_program")
    d = bad.to_dict()
    assert d["ok"] is False and d["programs"] == ["weak_program"]
    f = d["findings"][0]
    assert f["rule"] == "stable_signature"
    assert f["program"] == "weak_program"
    assert str(bad.findings[0]).startswith("[stable_signature] weak_program")
