"""Li-GD optimizer: projections, convergence, Corollary 2/4 behaviour.
Property-based variants live in test_core_ligd_props.py (optional
'hypothesis' dep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GdConfig,
    cold_init,
    gd_solve,
    li_gd_loop,
    make_env,
    make_weights,
    plain_gd_loop,
    planner,
    profiles,
    project_simplex_floor,
    solve,
    to_physical,
)
from repro.core.li_gd import _project
from repro.core.utility import utility


def test_gd_decreases_utility(small_env, weights, gd_cfg):
    env = small_env
    prof = profiles.nin()
    s = jnp.int32(3)
    init = _project(cold_init(env), env.radio.beta_min)
    g0 = utility(env, prof, s, to_physical(init, env), weights)
    res = gd_solve(env, prof, s, weights, init, gd_cfg)
    assert float(res.gamma) <= float(g0) + 1e-6
    assert int(res.iters) > 0


def test_stop_rules(small_env, weights):
    """Both stopping rules converge to comparable optima; 'raw' is the
    paper-parity baseline, 'pgd' (default) detects constrained optima. An
    unknown rule raises eagerly."""
    env = small_env
    prof = profiles.nin()
    s = jnp.int32(3)
    init = _project(cold_init(env), env.radio.beta_min)
    res = {}
    for rule in ("pgd", "raw"):
        cfg = GdConfig(step_size=5e-3, max_iters=120, stop_rule=rule)
        res[rule] = gd_solve(env, prof, s, weights, init, cfg)
    assert float(res["pgd"].gamma) == pytest.approx(float(res["raw"].gamma),
                                                    rel=0.05)
    with pytest.raises(ValueError):
        gd_solve(env, prof, s, weights, init,
                 GdConfig(step_size=5e-3, max_iters=10, stop_rule="newton"))


def test_gd_solve_resumes_adam_state(small_env, weights):
    """Resuming the (decayed, as the engine does) Adam moments + step count
    at a *converged* optimum stops almost immediately -- the carried state
    must not re-bias from zero and walk away."""
    env = small_env
    prof = profiles.nin()
    s = jnp.int32(0)
    cfg = GdConfig(step_size=1e-2, eps=1e-4, max_iters=600, optimizer="adam")
    init = _project(cold_init(env), env.radio.beta_min)
    first = gd_solve(env, prof, s, weights, init, cfg)
    assert int(first.iters) < cfg.max_iters  # converged, not budget-capped
    assert int(first.opt_steps) == int(first.iters)
    mom = jax.tree.map(lambda x: 0.1 * x, first.mom)
    resumed = gd_solve(env, prof, s, weights, first.norm, cfg,
                       init_mom=mom, init_steps=first.opt_steps)
    assert int(resumed.iters) <= 3, int(resumed.iters)
    assert float(resumed.gamma) <= float(first.gamma) + 1e-4
    assert int(resumed.opt_steps) == int(first.opt_steps) + int(resumed.iters)


def test_gd_loop_warm_adoption_flags(small_env, weights):
    """Online mode's per-split adoption probe: on an unchanged env the
    previous optima win the probe (used_warm mostly True) and the solve is
    cheap; with use_warm=False no split adopts and the loop is the exact
    cold Li-GD chain."""
    from repro.core import gd_loop
    env = small_env
    prof = profiles.nin()
    cfg = GdConfig(step_size=1e-2, eps=1e-4, max_iters=200, optimizer="adam")
    base = gd_loop(env, prof, weights, cfg, chain=True)
    assert not bool(jnp.any(base.used_warm))
    warm = gd_loop(env, prof, weights, cfg, warm=base.norms,
                   warm_mom=jax.tree.map(lambda x: 0.1 * x, base.moms),
                   warm_steps=base.opt_steps)
    assert float(jnp.mean(warm.used_warm.astype(jnp.float32))) >= 0.5
    assert int(warm.total_iters) <= int(base.total_iters)
    off = gd_loop(env, prof, weights, cfg, warm=base.norms, use_warm=False)
    assert not bool(jnp.any(off.used_warm))
    assert int(off.total_iters) == int(base.total_iters)
    np.testing.assert_allclose(np.asarray(off.gammas), np.asarray(base.gammas),
                               rtol=1e-6)


@pytest.mark.slow
def test_ligd_warm_start_reduces_iters(small_env, weights, gd_cfg):
    """Corollary 4: warm-started Li-GD needs fewer total iterations.
    (slow: full vgg16 split sweep, two policies.)"""
    env = small_env
    prof = profiles.vgg16()
    li = li_gd_loop(env, prof, weights, gd_cfg)
    pl = plain_gd_loop(env, prof, weights, gd_cfg)
    assert int(li.total_iters) < int(pl.total_iters)


@pytest.mark.slow
def test_ligd_per_layer_quality(small_env, weights, gd_cfg):
    """Warm starts shouldn't find (much) worse optima than cold starts.
    (slow: two full split sweeps.)"""
    env = small_env
    prof = profiles.nin()
    li = li_gd_loop(env, prof, weights, gd_cfg)
    pl = plain_gd_loop(env, prof, weights, gd_cfg)
    assert float(jnp.min(li.gammas)) <= float(jnp.min(pl.gammas)) * 1.05


def test_plan_feasible(small_env, weights, gd_cfg):
    env = small_env
    prof = profiles.nin()
    plan = solve(env, prof, weights, gd_cfg)
    rc, cc = env.radio, env.comp
    assert 0 <= int(plan.s) <= prof.n_layers
    assert bool(jnp.all((plan.sub_up >= 0) & (plan.sub_up < env.n_sub)))
    assert bool(jnp.all((plan.sub_dn >= 0) & (plan.sub_dn < env.n_sub)))
    assert bool(jnp.all((plan.p_up >= rc.p_up_min_w - 1e-9) & (plan.p_up <= rc.p_up_max_w + 1e-9)))
    assert bool(jnp.all((plan.p_dn >= rc.p_dn_min_w - 1e-9) & (plan.p_dn <= rc.p_dn_max_w + 1e-9)))
    assert bool(jnp.all((plan.r >= cc.r_min - 1e-6) & (plan.r <= cc.r_max + 1e-6)))
    assert bool(jnp.isfinite(plan.utility))
    # chosen split is the argmin of the per-layer utilities
    assert int(plan.s) == int(jnp.argmin(plan.per_layer_utility))


def test_gradient_matches_finite_difference(small_env, weights):
    """Autodiff == the paper's hand-derived gradients (spot check via FD)."""
    env = small_env
    prof = profiles.nin()
    s = jnp.int32(2)
    norm = _project(cold_init(env), env.radio.beta_min)

    def f_p(pu):
        n = dict(norm, p_up=pu)
        return utility(env, prof, s, to_physical(n, env), weights)

    g = jax.grad(f_p)(norm["p_up"])
    eps = 3e-3  # fp32: FD noise ~ ULP(f)/eps; this eps keeps it ~1e-4
    for i in range(3):
        e = jnp.zeros_like(norm["p_up"]).at[i].set(eps)
        fd = (f_p(norm["p_up"] + e) - f_p(norm["p_up"] - e)) / (2 * eps)
        assert abs(float(fd - g[i])) <= 5e-3 * max(1.0, abs(float(g[i]))), (i, fd, g[i])


def test_weight_tradeoff_monotone(small_env, gd_cfg):
    """More weight on delay => the planned delay does not increase."""
    env = small_env
    prof = profiles.vgg16()
    from repro.core import baselines
    Ts = []
    for wt in (0.2, 0.8):
        w = make_weights(env.n_users, wt)
        plan = solve(env, prof, w, gd_cfg)
        out = baselines.evaluate_plan(env, prof, plan, w)
        Ts.append(float(jnp.mean(out.T)))
    assert Ts[1] <= Ts[0] * 1.10  # small tolerance: discrete rounding noise


def test_rounding_violation_counter(small_env, weights, gd_cfg):
    plan = solve(small_env, profiles.nin(), weights, gd_cfg, rounding="paper")
    v = int(plan.rounding_violations)
    assert 0 <= v <= 2 * small_env.n_users


def test_plan_many_matches_sequential(small_env, weights, gd_cfg):
    """vmapped batched Li-GD == per-env solve (beyond-paper batching)."""
    from repro.core import make_env, profiles
    from repro.planning import PlannerEngine
    envs = [make_env(jax.random.PRNGKey(s), 8, 2, 4) for s in (0, 1)]
    prof = profiles.nin()
    engine = PlannerEngine(prof, weights=weights, cfg=gd_cfg)
    batched = engine.plan_many(envs)
    for i, env in enumerate(envs):
        single = solve(env, prof, weights, gd_cfg)
        assert int(batched.plan.s[i]) == int(single.s)
        assert abs(float(batched.plan.utility[i]) - float(single.utility)) < 1e-4
