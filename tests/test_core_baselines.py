"""Baselines + profiles sanity (paper Sec. VI comparisons)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, make_weights, planner, profiles, solve


def test_profile_counts():
    assert profiles.nin().n_layers == 9
    assert profiles.yolov2().n_layers == 17
    assert profiles.vgg16().n_layers == 24


def test_profile_invariants():
    for name, fn in profiles.PAPER_MODELS.items():
        p = fn()
        pre, suf = p.prefix_flops(), p.suffix_flops()
        np.testing.assert_allclose(
            np.asarray(pre + suf), float(jnp.sum(p.fl)), rtol=1e-6
        )
        assert float(p.w[-1]) == 0.0        # split at F: no upload
        assert float(p.m_down[-1]) == 0.0   # split at F: no download
        assert float(p.w[0]) > 0.0          # raw input has a size
        assert bool(jnp.all(p.fl >= 0))


def test_device_only_ignores_radio(small_env):
    p = profiles.nin()
    o = baselines.device_only(small_env, p)
    total = float(jnp.sum(p.fl))
    np.testing.assert_allclose(
        np.asarray(o.T), total / small_env.comp.c_device, rtol=1e-6
    )


def test_neurosurgeon_beats_endpoints_on_latency(small_env):
    """argmin over splits can't be worse than s=0 or s=F under its own model."""
    p = profiles.vgg16()
    o = baselines.neurosurgeon(small_env, p)
    dev = baselines.device_only(small_env, p)
    assert bool(jnp.all(o.T <= dev.T + 1e-9))


def test_dnn_surgery_no_faster_than_neurosurgeon(small_env):
    """Shared edge resources can only slow DNN-Surgery down."""
    p = profiles.vgg16()
    a = baselines.neurosurgeon(small_env, p)
    b = baselines.dnn_surgery(small_env, p)
    assert float(jnp.mean(b.T)) >= float(jnp.mean(a.T)) - 1e-9


def test_ecc_oma_feasible(small_env, weights, gd_cfg):
    o = baselines.ecc_oma(small_env, profiles.nin(), weights, gd_cfg)
    assert bool(jnp.all(jnp.isfinite(o.T))) and bool(jnp.all(o.T > 0))
    assert bool(jnp.all(jnp.isfinite(o.E))) and bool(jnp.all(o.E > 0))


def test_compare_all_keys(small_env, weights, gd_cfg):
    res = planner.compare_all(small_env, profiles.nin(), weights, gd_cfg)
    assert set(res) == {
        "ecc_noma", "ecc_oma", "device_only", "edge_only",
        "neurosurgeon", "dnn_surgery",
    }
    for name, o in res.items():
        assert bool(jnp.all(jnp.isfinite(o.T))), name
        assert bool(jnp.all(jnp.isfinite(o.E))), name


def test_lm_profile_extraction():
    class Cfg:
        name = "toy"
        n_layers = 4
        d_model = 64
        n_heads = 4
        n_kv_heads = 2
        d_ff = 128
        vocab_size = 1000
    p = profiles.from_arch_config(Cfg(), seq=128)
    assert p.n_layers == 4
    assert float(p.w[1]) == 128 * 64 * 16  # bf16 residual stream
    assert float(p.w[-1]) == 0.0
