"""SINR backend switch: the Pallas pairwise-kernel path must reproduce the
einsum reference (acceptance: within 1e-5) for both link directions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, make_env


def _vars(key, u, m):
    ku, kp, kq = jax.random.split(key, 3)
    beta = jax.random.dirichlet(ku, jnp.ones(m), (u,))
    p_up = jax.random.uniform(kp, (u,), minval=1e-3, maxval=0.3)
    p_dn = jax.random.uniform(kq, (u,), minval=0.1, maxval=10.0)
    return beta, p_up, p_dn


@pytest.mark.parametrize("u,n,m", [(8, 2, 4), (10, 3, 6), (16, 4, 8)])
def test_pallas_backend_matches_einsum(u, n, m):
    env = make_env(jax.random.PRNGKey(u), n_users=u, n_aps=n, n_sub=m)
    beta, p_up, p_dn = _vars(jax.random.PRNGKey(1), u, m)

    for fn, p in ((channel.uplink_sinr, p_up), (channel.downlink_sinr, p_dn)):
        ref = np.asarray(fn(env, beta, p, backend="einsum"))
        ker = np.asarray(fn(env, beta, p, backend="pallas"))
        np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5 * ref.max())


def test_pallas_backend_rates_match(small_env):
    env = small_env
    beta, p_up, p_dn = _vars(jax.random.PRNGKey(2), env.n_users, env.n_sub)
    r_ref = np.asarray(channel.uplink_rates(env, beta, p_up, backend="einsum"))
    r_ker = np.asarray(channel.uplink_rates(env, beta, p_up, backend="pallas"))
    np.testing.assert_allclose(r_ker, r_ref, rtol=1e-5, atol=1e-5 * r_ref.max())
    d_ref = np.asarray(channel.downlink_rates(env, beta, p_dn, backend="einsum"))
    d_ker = np.asarray(channel.downlink_rates(env, beta, p_dn, backend="pallas"))
    np.testing.assert_allclose(d_ker, d_ref, rtol=1e-5, atol=1e-5 * d_ref.max())


def test_set_sinr_backend_switch(small_env):
    beta, p_up, _ = _vars(jax.random.PRNGKey(3), small_env.n_users,
                          small_env.n_sub)
    ref = np.asarray(channel.uplink_sinr(small_env, beta, p_up))
    prev = channel.set_sinr_backend("pallas_interpret")
    try:
        assert prev == "einsum"
        out = np.asarray(channel.uplink_sinr(small_env, beta, p_up))
    finally:
        channel.set_sinr_backend(prev)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5 * ref.max())
    with pytest.raises(ValueError):
        channel.set_sinr_backend("cuda")
