"""Unit tests for the HLO collective parser (trip counts, ring formulas)."""
import pytest

from repro.launch.hlo_analysis import _ring_bytes, _shape_bytes, parse_hlo

SAMPLE = """\
HloModule jit_f, entry_computation_layout={()->()}, num_partitions=8

%add (x: f32[], y: f32[]) -> f32[] {
  ROOT %a = f32[] add(%x, %y)
}

%body (p: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
  %ar = f32[16,64]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[64,64]{1,0} all-gather(%y), replica_groups=[4,2]<=[8]T(1,0)
}

%cond (p: (s32[], f32[16,64])) -> pred[] {
  %c = s32[] constant(12)
  %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[16,64]) -> f32[16,64] {
  %w = (s32[], f32[16,64]) while(%t), condition=%cond, body=%body
  %rs = f32[2,64]{1,0} reduce-scatter(%a), replica_groups=[1,8]<=[8]
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,64]{1,0}") == 16 * 64 * 4
    assert _shape_bytes("(bf16[8,8], f32[4])") == 8 * 8 * 2 + 16
    assert _shape_bytes("s32[]") == 4  # scalar: one element


def test_ring_formulas():
    assert _ring_bytes("all-reduce", 100, 4) == pytest.approx(150.0)
    assert _ring_bytes("all-gather", 100, 4) == pytest.approx(75.0)
    assert _ring_bytes("reduce-scatter", 100, 4) == pytest.approx(300.0)
    assert _ring_bytes("collective-permute", 100, 4) == 100.0
    assert _ring_bytes("all-reduce", 100, 1) == 0.0


def test_parse_trip_attribution():
    r = parse_hlo(SAMPLE)
    assert r["num_partitions"] == 8
    # body collectives x12 trips + entry reduce-scatter x1
    assert r["per_kind_count"]["all-reduce"] == 12
    assert r["per_kind_count"]["all-gather"] == 12
    assert r["per_kind_count"]["reduce-scatter"] == 1
    ar_bytes = 16 * 64 * 4
    assert r["per_kind_bytes"]["all-reduce"] == 12 * ar_bytes
    assert r["n_whiles"] == 1
