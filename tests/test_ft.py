"""repro.runtime.ft: watchdog semantics, straggler window, and the
narrowed retry allowlist (PR 9: a bare RuntimeError is usually XLA
reporting a real device error -- retrying it from checkpoint masks the
failure, so only StepTimeout plus an explicit allowlist is retried)."""
import time

import pytest

from repro.runtime.ft import (
    StepTimeout,
    StragglerDetector,
    Watchdog,
    run_with_retries,
)


class TestWatchdog:
    def test_fires_after_timeout(self):
        with Watchdog(0.01) as wd:
            time.sleep(0.05)
            assert wd.fired
            with pytest.raises(StepTimeout):
                wd.check()

    def test_cancelled_on_exit(self):
        with Watchdog(0.02) as wd:
            pass
        time.sleep(0.05)             # timer must have been cancelled
        assert not wd.fired

    def test_fired_property_does_not_raise(self):
        # The serving path (faults.degrade.EpochWatchdog) reads `fired`
        # to keep the overrunning epoch's result; only check() raises.
        with Watchdog(0.01) as wd:
            time.sleep(0.05)
            assert wd.fired is True  # no exception
        assert wd.fired is True      # still readable after exit

    def test_fast_step_never_fires(self):
        with Watchdog(5.0) as wd:
            wd.check()
            assert not wd.fired


class TestStragglerDetector:
    def test_needs_window_before_flagging(self):
        det = StragglerDetector()
        # Fewer than 5 samples: even a huge outlier is not flagged.
        for _ in range(4):
            assert not det.record(100.0)
        assert det.straggler_steps == 0

    def test_flags_above_threshold_median(self):
        det = StragglerDetector(threshold=2.0)
        for _ in range(10):
            det.record(1.0)
        assert det.record(3.0)
        assert det.straggler_steps == 1
        assert not det.record(1.5)

    def test_window_slides(self):
        det = StragglerDetector(window=5)
        for _ in range(20):
            det.record(1.0)
        assert len(det.times) == 5


class TestRunWithRetries:
    def test_clean_run(self):
        steps = []
        done, retries, stragglers = run_with_retries(
            steps.append, 5, restore_fn=lambda: 0)
        assert (done, retries) == (5, 0)
        assert steps == [0, 1, 2, 3, 4]

    def test_timeout_is_retried_from_restore_point(self):
        calls = {"n": 0}

        def step(i):
            calls["n"] += 1
            if calls["n"] == 2:
                raise StepTimeout("simulated hang")

        done, retries, _ = run_with_retries(step, 3, restore_fn=lambda: 0)
        assert (done, retries) == (3, 1)
        # step 0, step 1 (hangs), restored: steps 0, 1, 2 again
        assert calls["n"] == 5

    def test_runtime_error_propagates_immediately(self):
        # The narrowed contract: a bare RuntimeError (XLA compile/OOM/
        # device error) is NOT retried and the restore_fn never runs.
        restored = []

        def step(i):
            raise RuntimeError("XLA: out of memory")

        with pytest.raises(RuntimeError, match="out of memory"):
            run_with_retries(step, 3, restore_fn=lambda: restored.append(1))
        assert restored == []

    def test_explicit_allowlist_is_retried(self):
        calls = {"n": 0}

        def step(i):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient")

        done, retries, _ = run_with_retries(
            step, 2, restore_fn=lambda: 0, retryable=(RuntimeError,))
        assert (done, retries) == (2, 1)

    def test_retry_budget_exhausts_and_raises(self):
        def step(i):
            raise StepTimeout("always hangs")

        with pytest.raises(StepTimeout):
            run_with_retries(step, 2, restore_fn=lambda: 0, max_retries=2)

    def test_allowlist_does_not_widen_to_subclasses_not_listed(self):
        # ValueError is not in the allowlist even when RuntimeError is.
        def step(i):
            raise ValueError("bad operand")

        with pytest.raises(ValueError):
            run_with_retries(step, 2, restore_fn=lambda: 0,
                             retryable=(RuntimeError,))
