"""Closed-loop integration: the online epoch program compiles once, the
measured profile moves s* under edge load, batched serving matches
sequential serving bit-for-bit, and the transfer pricing agrees between
the serving runtime and the planner."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import channel, profiles
from repro.core.types import GdConfig
from repro.models import Model
from repro.online import DecodeBatcher, EdgeBatcher, OnlineLoop, ServiceConfig, StreamConfig
from repro.planning import PlannerEngine, compile_log
from repro.runtime.serve import (
    make_split_serve,
    planned_transfer_seconds,
    transfer_seconds,
)
from repro.scenarios import Scenario, ScenarioConfig

ADAM_CFG = GdConfig(step_size=3e-2, eps=1e-4, max_iters=60, optimizer="adam")
SCEN = ScenarioConfig(n_users=8, n_aps=2, n_sub=3, fading_rho=0.95)
STREAM = StreamConfig(arrival_rate_hz=30.0, epoch_dt_s=0.02, deadline_s=0.2)
LOADED = ServiceConfig(edge_capacity=4, queue_depth=32, load_gain=8.0,
                       replan_every=5)


def _loop(feedback: bool, service: ServiceConfig = LOADED) -> OnlineLoop:
    eng = PlannerEngine(profiles.nin(), cfg=ADAM_CFG)
    return OnlineLoop(Scenario(SCEN), eng, STREAM, service,
                      feedback=feedback)


def test_steady_state_compiles_once():
    """After warmup, an entire feedback episode -- scenario, streams,
    batching, telemetry, QoS, and the measured-profile replans -- traces
    nothing: the epoch program and every planner program are reused."""
    loop = _loop(feedback=True)
    loop.reset(jax.random.PRNGKey(0))
    for _ in range(12):                       # warmup: traces epoch + replan
        loop.step_epoch()
    with compile_log() as log:
        for _ in range(12):
            loop.step_epoch()
    assert log == []


def test_closed_loop_moves_split_under_edge_load():
    """With the edge congestion-degraded, the measured profile must push
    s* off the static optimum (keep more layers local); the static arm
    planning on the same traffic must not move."""
    m_fb = _loop(feedback=True).run(jax.random.PRNGKey(0), 70, record=True)
    m_st = _loop(feedback=False).run(jax.random.PRNGKey(0), 70, record=True)
    s_fb, s_st = m_fb["history"]["s"], m_st["history"]["s"]
    assert max(m_fb["history"]["congestion"]) > 2.0   # load was induced
    assert len(set(s_st)) == 1                        # static arm is blind
    assert max(s_fb) > max(s_st)                      # feedback reacts
    # and the reaction pays: more completions per second under the same
    # offered traffic
    assert m_fb["requests_per_s"] > m_st["requests_per_s"]


def test_unloaded_loop_tracks_static_plan():
    """With load_gain=0 the edge is ideal: measured and static profiles
    agree, so the closed loop must keep the static split (no drift from
    the feedback path itself)."""
    ideal = dataclasses.replace(LOADED, load_gain=0.0)
    m_fb = _loop(True, ideal).run(jax.random.PRNGKey(1), 40, record=True)
    m_st = _loop(False, ideal).run(jax.random.PRNGKey(1), 40, record=True)
    assert m_fb["history"]["s"] == m_st["history"]["s"]


def test_loop_conserves_requests():
    loop = _loop(feedback=True)
    m = loop.run(jax.random.PRNGKey(2), 50)
    in_flight = int(jnp.sum(loop._bt.active))
    queued = int(loop._bt.q_size)
    assert m["offered"] == m["completed"] + m["dropped"] + in_flight + queued
    assert m["served"] == m["completed"]
    assert m["epochs"] == 50
    assert m["replans"] >= 50 // LOADED.replan_every


def test_planned_transfer_matches_serve_pricing():
    """serve.transfer_seconds (runtime: tokens x d_model at a rate) and
    planned_transfer_seconds (planner: prof.w[s] bits at the discrete
    plan's NOMA rate) agree for an LM profile at batch=1 -- both sides
    price the same activation."""
    arch = configs.get("qwen1.5-0.5b").reduced()
    seq = 16
    prof = profiles.from_arch_config(arch, seq=seq, batch=1)
    env = channel.make_env(jax.random.PRNGKey(3), n_users=6, n_aps=2,
                           n_sub=3)
    eng = PlannerEngine(prof, cfg=ADAM_CFG)
    plan = eng.plan(env).plan
    s_mid = arch.n_layers // 2
    plan = dataclasses.replace(plan, s=jnp.int32(s_mid))
    t_planner = np.asarray(planned_transfer_seconds(env, prof, plan))
    beta = jax.nn.one_hot(plan.sub_up, env.n_sub, dtype=env.g_up.dtype)
    rates = np.asarray(
        jnp.sum(channel.uplink_rates(env, beta, plan.p_up), -1))
    t_runtime = np.array(
        [transfer_seconds(seq, arch.d_model, r) for r in rates])
    np.testing.assert_allclose(t_planner, t_runtime, rtol=1e-6)


def test_masked_batching_matches_sequential_serving():
    """Satellite: stacked masked-slot edge serving == per-request
    sequential serving for every cut in a 3-point sweep, and the decode
    path's slot caches survive masking (a frozen slot resumes exactly)."""
    arch = configs.get("qwen1.5-0.5b").reduced()
    model = Model(arch, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    b, s_len, v = 3, 8, arch.vocab_size
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s_len), 0, v)

    # single-shot split inference, batched over slots
    for cut in (0, arch.n_layers // 2, arch.n_layers):
        progs = make_split_serve(model, params, cut)
        acts = [progs.device_fn(toks[i:i + 1]) for i in range(b)]
        eb = EdgeBatcher(b, s_len, arch.d_model, dtype=acts[0].dtype)
        buf = eb.buf
        for i, a in enumerate(acts):
            buf = eb.write(buf, i, a)
        batched = eb.run(progs.edge_fn, buf)
        seq_logits = jnp.concatenate([progs.edge_fn(a) for a in acts], 0)
        err = float(jnp.max(jnp.abs(batched - seq_logits)))
        assert err < 5e-2, (cut, err)

    # decode path: per-request reference trajectories
    refs = []
    prefill = jax.jit(lambda p, t: model.prefill(p, {"tokens": t},
                                                 s_len + 4))
    for i in range(b):
        lg, caches = prefill(params, toks[i:i + 1])
        steps = [lg[0]]
        tok = jnp.argmax(lg, -1)[:, None]
        for _ in range(2):
            lg, caches = model.decode_step(params, caches, tok)
            steps.append(lg[0])
            tok = jnp.argmax(lg, -1)[:, None]
        refs.append(steps)

    db = DecodeBatcher(model, params, capacity=b, max_len=s_len + 4)
    for i in range(b):
        pre = db.admit(i, toks[i:i + 1])
        assert float(jnp.max(jnp.abs(pre - refs[i][0]))) < 5e-2
    tok1 = jnp.stack([jnp.argmax(r[0]) for r in refs])[:, None]
    lg1 = db.step(tok1, jnp.array([True, True, True]))
    for i in range(b):
        assert float(jnp.max(jnp.abs(lg1[i] - refs[i][1]))) < 5e-2, i
    # slot 1 sits out an epoch (mask off), then resumes: its frozen cache
    # must produce the same next step as the uninterrupted reference
    tok2 = jnp.stack([jnp.argmax(r[1]) for r in refs])[:, None]
    lg2 = db.step(tok2, jnp.array([True, False, True]))
    for i in (0, 2):
        assert float(jnp.max(jnp.abs(lg2[i] - refs[i][2]))) < 5e-2, i
    lg3 = db.step(tok2, jnp.array([False, True, False]))
    assert float(jnp.max(jnp.abs(lg3[1] - refs[1][2]))) < 5e-2


def test_mid_decode_dropout_frees_slot_without_perturbing_siblings():
    """Satellite: a user departing mid-decode (link fade, app kill) is a
    permanent mask-off, not a cache teardown -- the surviving slots'
    trajectories must stay bit-for-bit on their sequential references
    through the departure, and the vacated slot must be re-admittable
    with a fresh request whose decode matches its own uninterrupted
    reference (no contamination from the departed request's frozen KV)."""
    arch = configs.get("qwen1.5-0.5b").reduced()
    model = Model(arch, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    b, s_len, v = 3, 8, arch.vocab_size
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s_len), 0, v)
    new_toks = jax.random.randint(jax.random.PRNGKey(2), (1, s_len), 0, v)

    def reference(t, n_steps):
        prefill = jax.jit(lambda p, tk: model.prefill(p, {"tokens": tk},
                                                      s_len + 6))
        lg, caches = prefill(params, t)
        steps = [lg[0]]
        tok = jnp.argmax(lg, -1)[:, None]
        for _ in range(n_steps):
            lg, caches = model.decode_step(params, caches, tok)
            steps.append(lg[0])
            tok = jnp.argmax(lg, -1)[:, None]
        return steps

    refs = [reference(toks[i:i + 1], 3) for i in range(b)]
    new_ref = reference(new_toks, 1)

    db = DecodeBatcher(model, params, capacity=b, max_len=s_len + 6)
    for i in range(b):
        pre = db.admit(i, toks[i:i + 1])
        assert float(jnp.max(jnp.abs(pre - refs[i][0]))) < 5e-2
    greedy = lambda k: jnp.stack(  # noqa: E731
        [jnp.argmax(r[k]) for r in refs])[:, None]

    # epoch 1: all three decode together
    lg1 = db.step(greedy(0), jnp.array([True, True, True]))
    for i in range(b):
        assert float(jnp.max(jnp.abs(lg1[i] - refs[i][1]))) < 5e-2, i

    # user 1 departs mid-decode: two more epochs with its lane masked off;
    # the survivors must not feel it
    for k in (1, 2):
        lg = db.step(greedy(k), jnp.array([True, False, True]))
        for i in (0, 2):
            assert float(jnp.max(jnp.abs(lg[i] - refs[i][k + 1]))) < 5e-2, i

    # the vacated slot re-admits a brand-new request: its prefill and
    # first decode step match the fresh sequential reference exactly
    pre = db.admit(1, new_toks)
    assert float(jnp.max(jnp.abs(pre - new_ref[0]))) < 5e-2
    tok_new = jnp.zeros((b, 1), greedy(0).dtype).at[1, 0].set(
        jnp.argmax(new_ref[0]))
    lg_new = db.step(tok_new, jnp.array([False, True, False]))
    assert float(jnp.max(jnp.abs(lg_new[1] - new_ref[1]))) < 5e-2
