"""repro.state: versioned serving snapshots, bit-exact crash recovery, the
flight-recorder journal, and the crash supervisor's escalation ladder
(newest snapshot -> older snapshot -> PR-9 cold start)."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.analysis.recovery_audit import _diff_leaves
from repro.core import profiles
from repro.core.types import GdConfig
from repro.faults import FaultConfig, LadderConfig
from repro.models import Model
from repro.online import DecodeBatcher, OnlineLoop, ServiceConfig, StreamConfig
from repro.planning import PlannerEngine, compile_log
from repro.scenarios import Scenario, ScenarioConfig
from repro.state import (
    CrashSupervisor,
    FlightRecorder,
    SimulatedCrash,
    SnapshotConfig,
    SnapshotIntegrityError,
    SnapshotStore,
    effective_trajectory,
    list_snapshots,
    load_snapshot,
    pack_word,
    read_journal,
    replay,
    unpack_word,
)
CFG = GdConfig(step_size=3e-2, eps=1e-4, max_iters=30, optimizer="adam")
SCEN = ScenarioConfig(n_users=6, n_aps=2, n_sub=3, fading_rho=0.95)
STREAM = StreamConfig(arrival_rate_hz=20.0, epoch_dt_s=0.02, deadline_s=0.2)
SERVICE = ServiceConfig(edge_capacity=4, queue_depth=8, load_gain=4.0,
                        replan_every=3, max_work_epochs=200)
CHAOS = FaultConfig(link_outage_rate=0.2, fade_depth=1e-6,
                    ap_outage_rate=0.05, telemetry_drop_rate=0.1,
                    telemetry_spike_rate=0.05, service_spike_rate=0.02)
T, CADENCE, CRASH_AT = 18, 6, 14
SEED = 3
KEY = jax.random.PRNGKey(SEED)


def make_loop() -> OnlineLoop:
    eng = PlannerEngine(profiles.nin(), cfg=CFG)
    return OnlineLoop(Scenario(SCEN), eng, STREAM, SERVICE, faults=CHAOS,
                      degrade=LadderConfig(quarantine_epochs=8,
                                           baseline_after=2))


def _crash_once(at: int):
    armed = [True]

    def chaos(next_epoch: int) -> None:
        if next_epoch == at and armed[0]:
            armed[0] = False
            raise SimulatedCrash(f"injected kill before epoch {at}")

    return chaos


@pytest.fixture(scope="module")
def uninterrupted():
    """The reference: T epochs with no crash, same key as every other arm."""
    loop = make_loop()
    loop.reset(KEY)
    for _ in range(T):
        loop.step_epoch()
    dev, host = loop.serving_state()
    return {"dev": jax.device_get(dev), "host": host,
            "cache": loop.engine.cache_size()}


@pytest.fixture(scope="module")
def snapped(tmp_path_factory):
    """A loop stepped to 2*CADENCE with a sync SnapshotStore on cadence:
    snapshots at CADENCE and 2*CADENCE, plus the ladder state at the cut."""
    td = str(tmp_path_factory.mktemp("snapped"))
    store = SnapshotStore(td, SnapshotConfig(every=CADENCE, keep_n=3,
                                             asynchronous=False))
    loop = make_loop()
    loop.reset(KEY)
    saved = []
    for _ in range(2 * CADENCE):
        loop.step_epoch()
        path = store.maybe_save(loop)
        if path is not None:
            saved.append(loop.host_epoch)
    assert saved == [CADENCE, 2 * CADENCE]
    return {"store": td, "saves": store.saves,
            "ladder_at_cut": loop.ladder.export_state()}


@pytest.fixture(scope="module")
def resumed(snapped):
    """Restore the 2*CADENCE snapshot into a fresh loop (a process restart:
    new engine, nothing warm) and run it to T, then past T under a compile
    log -- the data for the bit-exact and retrace-free assertions."""
    fresh = make_loop()
    fresh.reset(KEY)
    load_snapshot(snapped["store"], fresh, 2 * CADENCE)
    ladder_at_restore = fresh.ladder.export_state()
    for _ in range(T - 2 * CADENCE):
        fresh.step_epoch()
    dev, host = fresh.serving_state()
    dev = jax.device_get(dev)          # later epochs donate these buffers
    with compile_log() as log:
        for _ in range(CADENCE):
            fresh.step_epoch()
        fresh.serving_state()          # the snapshot-capture path too
    return {"dev": dev, "host": host,
            "ladder_at_restore": ladder_at_restore,
            "log": list(log), "cache": fresh.engine.cache_size()}


class TestSnapshotRoundtrip:
    def test_resume_is_bit_exact(self, resumed, uninterrupted):
        assert _diff_leaves(uninterrupted["dev"], resumed["dev"]) == []
        assert json.dumps(resumed["host"], sort_keys=True) == \
            json.dumps(uninterrupted["host"], sort_keys=True)

    def test_resume_is_retrace_free(self, resumed, uninterrupted):
        # Steady state after a restore mints zero compiles and no new
        # engine cache entries: restored leaves hit the live programs'
        # exact avals.
        assert resumed["log"] == []
        assert resumed["cache"] <= uninterrupted["cache"]

    def test_ladder_counters_survive_restore(self, resumed, snapped,
                                             uninterrupted):
        # Satellite: the degradation ladder's counters ride the snapshot
        # verbatim, and recovery latency is not double-counted across the
        # restore (the down-since watermark is preserved, so a recovery
        # spanning the crash contributes its true epoch count once).
        assert resumed["ladder_at_restore"] == snapped["ladder_at_cut"]
        assert resumed["ladder_at_restore"]["epoch"] == 2 * CADENCE
        assert resumed["host"]["ladder"] == uninterrupted["host"]["ladder"]

    def test_fingerprint_mismatch_refuses_restore(self, snapped):
        other_stream = StreamConfig(arrival_rate_hz=25.0, epoch_dt_s=0.02,
                                    deadline_s=0.2)
        eng = PlannerEngine(profiles.nin(), cfg=CFG)
        other = OnlineLoop(Scenario(SCEN), eng, other_stream, SERVICE,
                           faults=CHAOS, degrade=LadderConfig())
        with pytest.raises(SnapshotIntegrityError, match="fingerprint"):
            load_snapshot(snapped["store"], other, 2 * CADENCE)

    def test_store_cadence_and_listing(self, snapped):
        assert list_snapshots(snapped["store"]) == [CADENCE, 2 * CADENCE]
        assert snapped["saves"] == 2


@pytest.fixture(scope="module")
def crashed(tmp_path_factory):
    """A supervised, journaled run killed before epoch CRASH_AT and resumed
    from the newest snapshot."""
    td = str(tmp_path_factory.mktemp("crashed"))
    journal = os.path.join(td, "flight.jsonl")
    rec = FlightRecorder(journal)
    store = SnapshotStore(os.path.join(td, "snaps"),
                          SnapshotConfig(every=CADENCE, keep_n=3,
                                         asynchronous=False))
    sup = CrashSupervisor(make_loop, store=store, recorder=rec)
    m = sup.run(KEY, T, seed=SEED, record=True, chaos=_crash_once(CRASH_AT))
    rec.close()
    dev, host = sup.loop.serving_state()
    return {"sup": sup, "metrics": m, "dev": jax.device_get(dev),
            "host": host, "journal": journal}


class TestCrashSupervisor:
    def test_resume_matches_uninterrupted(self, crashed, uninterrupted):
        assert _diff_leaves(uninterrupted["dev"], crashed["dev"]) == []
        assert json.dumps(crashed["host"], sort_keys=True) == \
            json.dumps(uninterrupted["host"], sort_keys=True)

    def test_recovery_accounting(self, crashed):
        sup = crashed["sup"]
        # killed before epoch 14 (12 + 13 done), resumed from the snapshot
        # at 12: exactly one re-executed epoch
        assert sup.restarts == 1
        assert sup.cold_restarts == 0
        assert sup.restored_from == [2 * CADENCE]
        assert sup.recovery_epochs == (CRASH_AT - 1) - 2 * CADENCE

    def test_history_rewound_not_duplicated(self, crashed):
        hist = crashed["metrics"]["history"]
        assert all(len(col) == T for col in hist.values())

    @staticmethod
    def _crash_and_rot(at: int, dst: str, epochs: tuple[int, ...]):
        """Chaos hook: right before the kill, bit-rot the snapshots at
        ``epochs`` (corruption between the save and the crash)."""
        armed = [True]

        def chaos(next_epoch: int) -> None:
            if next_epoch == at and armed[0]:
                armed[0] = False
                for e in epochs:
                    with open(os.path.join(dst, f"snap_{e:08d}",
                                           "leaves.npz"), "wb") as f:
                        f.write(b"not a zip archive")
                raise SimulatedCrash(f"injected kill before epoch {at}")

        return chaos

    def test_corrupt_newest_escalates_to_previous(self, uninterrupted,
                                                  tmp_path):
        dst = str(tmp_path / "snaps")
        store = SnapshotStore(dst, SnapshotConfig(every=CADENCE, keep_n=3,
                                                  asynchronous=False))
        sup = CrashSupervisor(make_loop, store=store)
        sup.run(KEY, T,
                chaos=self._crash_and_rot(CRASH_AT, dst, (2 * CADENCE,)))
        assert sup.restored_from == [CADENCE]
        assert sup.corrupt_snapshots == 1
        assert sup.recovery_epochs == (CRASH_AT - 1) - CADENCE
        dev, host = sup.loop.serving_state()
        assert _diff_leaves(uninterrupted["dev"], dev) == []

    def test_all_corrupt_falls_to_cold_start(self, uninterrupted, tmp_path):
        dst = str(tmp_path / "snaps")
        store = SnapshotStore(dst, SnapshotConfig(every=CADENCE, keep_n=3,
                                                  asynchronous=False))
        sup = CrashSupervisor(make_loop, store=store)
        sup.run(KEY, T, chaos=self._crash_and_rot(
            CRASH_AT, dst, (CADENCE, 2 * CADENCE)))
        assert sup.cold_restarts == 1
        assert sup.corrupt_snapshots == 2
        assert sup.restored_from == [0]
        assert sup.recovery_epochs == CRASH_AT - 1
        # a cold restart replays deterministically from epoch 0: the final
        # state still equals the uninterrupted run's
        dev, _ = sup.loop.serving_state()
        assert _diff_leaves(uninterrupted["dev"], dev) == []


class TestJournal:
    def test_replay_reproduces_trajectory(self, crashed):
        records, clean = read_journal(crashed["journal"])
        assert clean and records
        traj = effective_trajectory(records)
        assert traj["seed"] == SEED
        assert sorted(traj["epochs"]) == list(range(1, T + 1))
        res = replay(records, make_loop)
        assert res["epochs"] == T
        assert res["divergence"] is None

    def test_tampered_word_detected_by_replay(self, crashed):
        records, _ = read_journal(crashed["journal"])
        tampered = [dict(r) for r in records]
        victim = next(r for r in tampered
                      if r["kind"] == "epoch" and r["t"] == 5)
        victim["word"] ^= 1              # flip the served s* by one
        res = replay(tampered, make_loop)
        assert res["divergence"] is not None
        assert res["divergence"]["t"] == 5

    def test_crc_tamper_truncates_read(self, crashed, tmp_path):
        path = str(tmp_path / "flight.jsonl")
        shutil.copy(crashed["journal"], path)
        with open(path) as f:
            lines = f.readlines()
        rec = json.loads(lines[4])
        rec["word"] = rec.get("word", 0) ^ 1   # crc left stale
        lines[4] = json.dumps(rec, sort_keys=True) + "\n"
        with open(path, "w") as f:
            f.writelines(lines)
        records, clean = read_journal(path)
        assert not clean
        assert len(records) == 4           # everything after the tamper is
        #                                    untrusted and dropped

    def test_torn_tail_tolerated(self, tmp_path):
        path = str(tmp_path / "flight.jsonl")
        rec = FlightRecorder(path)
        rec.record_start(0, "fp")
        rec.record_epoch(1, s=4, health=3, trigger=False, stage="normal")
        rec.close()
        with open(path, "a") as f:
            f.write('{"kind": "epoch", "t": 2, ')   # crash mid-write
        records, clean = read_journal(path)
        assert not clean
        assert [r["kind"] for r in records] == ["start", "epoch"]

    def test_restore_rewinds_rate_swaps(self):
        records = [
            {"kind": "start", "seed": 0, "fingerprint": "fp"},
            {"kind": "rates", "t": 3, "rates": {"link_outage_rate": 0.5}},
            {"kind": "rates", "t": 9, "rates": {"link_outage_rate": 0.9}},
            {"kind": "restore", "t": 10, "from": 6},
        ]
        traj = effective_trajectory(records)
        # the swap at t=9 was lost to the crash; the one at t=3 survives
        assert traj["rates"] == [(3, {"link_outage_rate": 0.5})]

    def test_pack_word_roundtrip(self):
        for health, s in ((0, 0), (3, 41), (7, 65535)):
            assert unpack_word(pack_word(health, s)) == (health, s)


def test_decode_batcher_cache_export_import_roundtrip():
    """Satellite: slot decode caches export as host copies and import back
    bit-exactly (the snapshot's batcher leg), with aval validation."""
    arch = configs.get("qwen1.5-0.5b").reduced()
    model = Model(arch, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    b, s_len = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s_len), 0,
                              arch.vocab_size)
    db = DecodeBatcher(model, params, capacity=b, max_len=s_len + 4)
    for i in range(b):
        db.admit(i, toks[i:i + 1])
    snap = db.export_caches()
    tok = jnp.zeros((b, 1), jnp.int32)
    live = np.asarray(db.step(tok, jnp.array([True, True])))
    db.import_caches(snap)                 # rewind to the exported state
    again = np.asarray(db.step(tok, jnp.array([True, True])))
    np.testing.assert_array_equal(live, again)
    with pytest.raises(ValueError, match="leaf"):
        db.import_caches(jax.tree_util.tree_map(lambda a: a[..., :1], snap))
