"""NOMA channel model invariants (paper eqs. 5-10)."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import channel, make_env


def _vars(env, key, onehot=False):
    ku, kd, kp, kq = jax.random.split(key, 4)
    u, m = env.n_users, env.n_sub
    if onehot:
        beta_up = jax.nn.one_hot(jax.random.randint(ku, (u,), 0, m), m)
        beta_dn = jax.nn.one_hot(jax.random.randint(kd, (u,), 0, m), m)
    else:
        beta_up = jax.random.dirichlet(ku, jnp.ones(m), (u,))
        beta_dn = jax.random.dirichlet(kd, jnp.ones(m), (u,))
    p_up = jax.random.uniform(kp, (u,), minval=1e-3, maxval=0.3)
    p_dn = jax.random.uniform(kq, (u,), minval=0.1, maxval=10.0)
    return beta_up, beta_dn, p_up, p_dn


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), onehot=st.booleans())
def test_rates_finite_nonneg(seed, onehot):
    key = jax.random.PRNGKey(seed)
    env = make_env(key, n_users=6, n_aps=2, n_sub=3)
    bu, bd, pu, pd = _vars(env, key, onehot)
    ru = channel.uplink_rates(env, bu, pu)
    rd = channel.downlink_rates(env, bd, pd)
    for r in (ru, rd):
        assert bool(jnp.all(jnp.isfinite(r)))
        assert bool(jnp.all(r >= 0.0))


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**31 - 1))
def test_own_power_monotone(seed):
    """Raising my tx power (others fixed) cannot lower my uplink SINR."""
    key = jax.random.PRNGKey(seed)
    env = make_env(key, n_users=6, n_aps=2, n_sub=3)
    bu, _, pu, _ = _vars(env, key)
    s0 = channel.uplink_sinr(env, bu, pu)
    pu2 = pu.at[0].mul(2.0)
    s1 = channel.uplink_sinr(env, bu, pu2)
    assert bool(jnp.all(s1[0] >= s0[0] - 1e-9))


def test_sic_strongest_user_no_intra(small_env):
    """The same-cell user with the largest own-gain on subchannel m sees no
    intra-cell interference there (it is decoded first)."""
    env = small_env
    u, m = env.n_users, env.n_sub
    beta = jnp.ones((u, m)) / m
    p = jnp.full((u,), 0.1)
    own = env.own_gain_up()
    sinr = channel.uplink_sinr(env, beta, p)
    # isolate cell 0, subchannel 0
    cell0 = env.ap == 0
    gains = jnp.where(cell0, own[:, 0], -jnp.inf)
    top = int(jnp.argmax(gains))
    # reconstruct: signal / (inter + noise) for top user should equal sinr
    inter_plus_noise = p[top] * own[top, 0] / sinr[top, 0]
    # remove noise, left = inter-cell only; verify no same-cell term by
    # zeroing other cells' power -> sinr should hit p*g/noise exactly.
    p_zero = jnp.where(cell0, p, 0.0)
    sinr_iso = channel.uplink_sinr(env, beta, p_zero)
    expected = p[top] * own[top, 0] / env.noise_up
    assert float(jnp.abs(sinr_iso[top, 0] - expected) / expected) < 1e-4
    assert float(inter_plus_noise) >= float(env.noise_up) * 0.99


def test_more_interference_lowers_sinr(small_env):
    """Adding a weaker same-cell user's power raises my denominator only if
    I am the weaker one (SIC ordering respected)."""
    env = small_env
    u, m = env.n_users, env.n_sub
    beta = jnp.ones((u, m)) / m
    p = jnp.full((u,), 0.1)
    own = env.own_gain_up()
    # pick the most populated cell so we have >= 2 users in it
    counts = jnp.bincount(env.ap, length=env.n_aps)
    target = int(jnp.argmax(counts))
    cell0 = jnp.where(env.ap == target)[0]
    assert len(cell0) >= 2
    g = own[cell0, 0]
    order = jnp.argsort(-g)
    strong, weak = int(cell0[order[0]]), int(cell0[order[1]])
    s0 = channel.uplink_sinr(env, beta, p)
    p2 = p.at[weak].mul(4.0)
    s1 = channel.uplink_sinr(env, beta, p2)
    # strong user now sees more intra-cell interference from 'weak'
    assert float(s1[strong, 0]) < float(s0[strong, 0])
    # weak user's own SINR goes up
    assert float(s1[weak, 0]) > float(s0[weak, 0])


def test_oma_rates_positive(small_env):
    env = small_env
    pu = jnp.full((env.n_users,), 0.3)
    pd = jnp.full((env.n_users,), 5.0)
    ru, rd = channel.oma_rates(env, pu, pd)
    assert bool(jnp.all(ru > 0)) and bool(jnp.all(rd > 0))


def test_env_shapes(small_env):
    env = small_env
    assert env.g_up.shape == (8, 2, 4)
    assert env.g_dn.shape == (2, 8, 4)
    assert env.own_gain_up().shape == (8, 4)
    assert env.own_gain_dn().shape == (8, 4)
    assert bool(jnp.all(env.ap >= 0)) and bool(jnp.all(env.ap < 2))
