"""NOMA channel model invariants (paper eqs. 5-10). Property-based variants
live in test_core_channel_props.py (optional 'hypothesis' dep)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import channel, make_env


def test_sic_weakest_user_no_intra(small_env):
    """Uplink SIC decodes stronger users first (paper eq. 5): the same-cell
    user with the *smallest* own-gain on subchannel m is decoded last, after
    every same-cell interferer has been cancelled, so it sees no intra-cell
    interference there."""
    env = small_env
    u, m = env.n_users, env.n_sub
    beta = jnp.ones((u, m)) / m
    p = jnp.full((u,), 0.1)
    own = env.own_gain_up()
    sinr = channel.uplink_sinr(env, beta, p)
    # isolate cell 0, subchannel 0
    cell0 = env.ap == 0
    gains = jnp.where(cell0, own[:, 0], jnp.inf)
    bottom = int(jnp.argmin(gains))
    # reconstruct: signal / (inter + noise) for bottom user should equal sinr
    inter_plus_noise = p[bottom] * own[bottom, 0] / sinr[bottom, 0]
    # remove noise, left = inter-cell only; verify no same-cell term by
    # zeroing other cells' power -> sinr should hit p*g/noise exactly.
    p_zero = jnp.where(cell0, p, 0.0)
    sinr_iso = channel.uplink_sinr(env, beta, p_zero)
    expected = p[bottom] * own[bottom, 0] / env.noise_up
    assert float(jnp.abs(sinr_iso[bottom, 0] - expected) / expected) < 1e-4
    assert float(inter_plus_noise) >= float(env.noise_up) * 0.99


def test_more_interference_lowers_sinr(small_env):
    """Adding a weaker same-cell user's power raises my denominator only if
    I am the weaker one (SIC ordering respected)."""
    env = small_env
    u, m = env.n_users, env.n_sub
    beta = jnp.ones((u, m)) / m
    p = jnp.full((u,), 0.1)
    own = env.own_gain_up()
    # pick the most populated cell so we have >= 2 users in it
    counts = jnp.bincount(env.ap, length=env.n_aps)
    target = int(jnp.argmax(counts))
    cell0 = jnp.where(env.ap == target)[0]
    assert len(cell0) >= 2
    g = own[cell0, 0]
    order = jnp.argsort(-g)
    strong, weak = int(cell0[order[0]]), int(cell0[order[1]])
    s0 = channel.uplink_sinr(env, beta, p)
    p2 = p.at[weak].mul(4.0)
    s1 = channel.uplink_sinr(env, beta, p2)
    # strong user now sees more intra-cell interference from 'weak'
    assert float(s1[strong, 0]) < float(s0[strong, 0])
    # weak user's own SINR goes up
    assert float(s1[weak, 0]) > float(s0[weak, 0])


def test_oma_rates_positive(small_env):
    env = small_env
    pu = jnp.full((env.n_users,), 0.3)
    pd = jnp.full((env.n_users,), 5.0)
    ru, rd = channel.oma_rates(env, pu, pd)
    assert bool(jnp.all(ru > 0)) and bool(jnp.all(rd > 0))


def test_env_shapes(small_env):
    env = small_env
    assert env.g_up.shape == (8, 2, 4)
    assert env.g_dn.shape == (2, 8, 4)
    assert env.own_gain_up().shape == (8, 4)
    assert env.own_gain_dn().shape == (8, 4)
    assert bool(jnp.all(env.ap >= 0)) and bool(jnp.all(env.ap < 2))
