"""Differentiable Pallas SINR: the custom_vjp pairwise kernel must produce
the same gradients as the einsum reference (acceptance: 1e-5, interpret
mode) on both links, under independent receiver/interferer padding
(block_u != block_v), and for both SIC orders -- and the pallas-backed
grad step must not materialize any (U, V, M) arithmetic intermediate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, make_env, make_weights, profiles
from repro.core.types import GdConfig, GdVars
from repro.core.utility import utility
from repro.core import li_gd
from repro.kernels import ops


def _vars(key, u, m):
    ku, kp, kq = jax.random.split(key, 3)
    beta = jax.random.dirichlet(ku, jnp.ones(m), (u,))
    p_up = jax.random.uniform(kp, (u,), minval=1e-3, maxval=0.3)
    p_dn = jax.random.uniform(kq, (u,), minval=0.1, maxval=10.0)
    return beta, p_up, p_dn


def _assert_grads_close(ga, gb):
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        a, b = np.asarray(a), np.asarray(b)
        np.testing.assert_allclose(b, a, rtol=1e-5,
                                   atol=1e-5 * max(np.abs(a).max(), 1e-30))


@pytest.mark.parametrize("u,n,m", [(8, 2, 4), (10, 3, 6), (9, 1, 12)])
def test_rates_grad_parity_both_links(u, n, m):
    env = make_env(jax.random.PRNGKey(u), n_users=u, n_aps=n, n_sub=m)
    beta, p_up, p_dn = _vars(jax.random.PRNGKey(1), u, m)
    for fn, p in ((channel.uplink_rates, p_up), (channel.downlink_rates, p_dn)):
        ge = jax.grad(lambda b, q: jnp.sum(fn(env, b, q, backend="einsum")),
                      argnums=(0, 1))(beta, p)
        gk = jax.grad(
            lambda b, q: jnp.sum(fn(env, b, q, backend="pallas_interpret")),
            argnums=(0, 1))(beta, p)
        _assert_grads_close(ge, gk)


@pytest.mark.parametrize("bu,bv", [(8, 16), (16, 8)])
@pytest.mark.parametrize("descending", [True, False])
def test_pairwise_grad_parity_mismatched_blocks(bu, bv, descending):
    """Padding the receiver (U) and interferer (V) axes independently must
    hold in the backward kernel too: U=20 with these blocks pads the axes
    to different lengths in each direction, for both SIC orders."""
    u, n, m = 20, 3, 6
    env = make_env(jax.random.PRNGKey(7), n_users=u, n_aps=n, n_sub=m)
    beta = jax.random.dirichlet(jax.random.PRNGKey(8), jnp.ones(m), (u,))
    p = jax.random.uniform(jax.random.PRNGKey(9), (u,), minval=0.01, maxval=0.3)

    pair_k = ops.noma_pairwise_up if descending else ops.noma_pairwise_dn
    sinr_e = channel.uplink_sinr if descending else channel.downlink_sinr
    own = (env.own_gain_up() if descending else env.own_gain_dn()).astype(
        jnp.float32)
    noise = env.noise_up if descending else env.noise_dn

    def loss_k(b, q):
        intra, inter = pair_k(env, b * q[:, None], interpret=True,
                              block_u=bu, block_v=bv, block_m=8)
        if not descending:
            intra = intra * own
        return jnp.sum(b * jnp.log1p(q[:, None] * own / (intra + inter + noise)))

    def loss_e(b, q):
        return jnp.sum(b * jnp.log1p(sinr_e(env, b, q, backend="einsum")))

    _assert_grads_close(jax.grad(loss_e, argnums=(0, 1))(beta, p),
                        jax.grad(loss_k, argnums=(0, 1))(beta, p))


def test_utility_grad_parity(small_env, weights):
    """jax.grad of the full paper utility matches across backends: this is
    exactly the GD hot-loop gradient."""
    env = small_env
    u, m = env.n_users, env.n_sub
    beta, p_up, p_dn = _vars(jax.random.PRNGKey(3), u, m)
    v = GdVars(beta_up=beta, beta_dn=beta, p_up=p_up, p_dn=p_dn,
               r=jnp.full((u,), 4.0))
    prof = profiles.nin()

    def loss(backend):
        return lambda vv: utility(env, prof, jnp.int32(2), vv, weights,
                                  backend=backend)

    ge = jax.grad(loss("einsum"))(v)
    gk = jax.grad(loss("pallas_interpret"))(v)
    _assert_grads_close(ge, gk)


def test_gd_solve_backend_parity(small_env, weights):
    """One full projected-GD solve traced with the Pallas backend lands on
    the einsum solve's optimum (same iterate sequence up to fp noise)."""
    cfg_e = GdConfig(max_iters=30, optimizer="adam")
    cfg_k = GdConfig(max_iters=30, optimizer="adam",
                     sinr_backend="pallas_interpret")
    prof = profiles.nin()
    init = li_gd.cold_init(small_env)
    s = jnp.int32(1)
    re = li_gd.gd_solve(small_env, prof, s, weights, init, cfg_e)
    rk = li_gd.gd_solve(small_env, prof, s, weights, init, cfg_k)
    assert int(re.iters) == int(rk.iters)
    np.testing.assert_allclose(float(rk.gamma), float(re.gamma), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(re.norm), jax.tree.leaves(rk.norm)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-5)


def test_env_gradient_semantics(small_env):
    """The kernel backend treats channel gains as constants: its env
    gradient is coherently zero (stop_gradient, never a partial mixture),
    while einsum propagates a real nonzero gain gradient."""
    env = small_env
    beta, p_up, _ = _vars(jax.random.PRNGKey(5), env.n_users, env.n_sub)

    def loss(backend):
        return lambda g_up: jnp.sum(channel.uplink_rates(
            env._replace(g_up=g_up) if hasattr(env, "_replace")
            else type(env)(g_up=g_up, g_dn=env.g_dn, ap=env.ap,
                           radio=env.radio, comp=env.comp),
            beta, p_up, backend=backend))

    ge = jax.grad(loss("einsum"))(env.g_up)
    gk = jax.grad(loss("pallas_interpret"))(env.g_up)
    assert float(jnp.max(jnp.abs(ge))) > 0.0
    np.testing.assert_array_equal(np.asarray(gk), 0.0)


def test_downlink_rates_wrapper_parity(small_env):
    """ops.noma_downlink_rates (the kernel-backed eval wrapper) reproduces
    channel.downlink_rates, like the uplink wrapper at ops.py."""
    env = small_env
    beta, _, p_dn = _vars(jax.random.PRNGKey(4), env.n_users, env.n_sub)
    r_ker = ops.noma_downlink_rates(env, beta, p_dn, interpret=True)
    r_ref = channel.downlink_rates(env, beta, p_dn, backend="einsum")
    np.testing.assert_allclose(np.asarray(r_ker), np.asarray(r_ref),
                               rtol=2e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# jaxpr discipline, via the repro.analysis rule catalog (the walkers that
# used to live here are now NoPairwiseIntermediate / NoGatherAbove / NoPad3D
# in analysis/rules.py -- tests, CLI, and CI all run one implementation):
# the pallas-backed grad step must not compute through any (U, V, M)
# arithmetic intermediate, must not gather a (V, U, M) AP-indexed gain, and
# must not pad any kernel operand.
# ---------------------------------------------------------------------------
def test_no_pairwise_intermediate_in_pallas_grad_jaxpr():
    from repro import analysis

    u, n, m = 10, 3, 6
    env = make_env(jax.random.PRNGKey(0), n_users=u, n_aps=n, n_sub=m)
    prof = profiles.nin()
    w = make_weights(u)
    v0 = GdVars(beta_up=jnp.ones((u, m)) / m, beta_dn=jnp.ones((u, m)) / m,
                p_up=jnp.full((u,), 0.1), p_dn=jnp.full((u,), 1.0),
                r=jnp.full((u,), 4.0))

    def grad_step(backend):
        return jax.grad(
            lambda v: utility(env, prof, jnp.int32(2), v, w, backend=backend))

    rules = [analysis.NoPairwiseIntermediate(u), analysis.NoGatherAbove(u),
             analysis.NoPad3D()]
    reports = {
        backend: analysis.audit(grad_step(backend), v0, rules=rules,
                                label=f"grad_step:{backend}")
        for backend in ("einsum", "pallas_interpret")
    }
    # positive control: the einsum grad does materialize pairwise tensors
    einsum_arith = [f for f in reports["einsum"].findings
                    if f.rule == "no_pairwise_intermediate"]
    assert len(einsum_arith) >= 2, reports["einsum"].findings
    # the pallas grad step is clean under all three rules
    reports["pallas_interpret"].raise_if_failed()
