"""Runtime substrate tests: optimizer, train loop, data, checkpoint, FT."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import SyntheticLM, make_batch
from repro.models import Model
from repro.optim import adamw_init, adamw_update, cosine_lr
from repro.optim.compression import (
    compress_topk,
    decompress_topk,
    error_feedback_update,
)
from repro.runtime import ft
from repro.runtime.train import init_state, make_train_step


def test_adamw_reduces_quadratic():
    p = {"w": jnp.array([3.0, -2.0, 1.5])}
    st = adamw_init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st = adamw_update(p, g, st, lr=5e-2, wd=0.0)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.2


def test_cosine_lr_shape():
    lrs = [float(cosine_lr(jnp.int32(s), base_lr=1e-3, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9]              # warmup rises
    assert lrs[99] < lrs[20]            # decays
    assert lrs[99] >= 1e-4 - 1e-9       # floor


def test_train_step_loss_decreases():
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = Model(cfg, remat=True)
    step_fn = jax.jit(make_train_step(model, n_microbatches=1, base_lr=3e-3,
                                      total_steps=30))
    state = init_state(model, jax.random.PRNGKey(0))
    losses = []
    for s in range(12):
        batch = make_batch(0, s % 2, 4, 32, cfg.vocab_size)  # repeat 2 batches
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_train_step_microbatching_equivalence():
    """grad accumulation over microbatches == single big batch (same data)."""
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = Model(cfg, remat=False)
    s1 = init_state(model, jax.random.PRNGKey(0))
    s2 = init_state(model, jax.random.PRNGKey(0))
    batch = make_batch(0, 0, 8, 32, cfg.vocab_size)
    f1 = jax.jit(make_train_step(model, n_microbatches=1))
    f2 = jax.jit(make_train_step(model, n_microbatches=4))
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-3)


def test_data_pipeline_deterministic_resumable():
    a = make_batch(7, 42, 4, 16, 100)
    b = make_batch(7, 42, 4, 16, 100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    it = SyntheticLM(7, 4, 16, 100, start_step=42)
    c = next(it)
    it.close()
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 5, tree)
    out, step = load_checkpoint(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert int(out["b"]["c"]) == 7


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"w": jnp.ones((8,))}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, jax.tree.map(lambda x: x * s, tree))
    mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    out, s = mgr.restore(tree)
    assert s == 4
    np.testing.assert_allclose(np.asarray(out["w"]), 4.0)


def test_checkpoint_elastic_restore(tmp_path):
    """Restore with different shardings (device-count change simulation)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, _ = load_checkpoint(str(tmp_path), tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]


def test_topk_compression_roundtrip():
    g = jnp.array([0.0, 5.0, -3.0, 0.1, 0.0, -7.0])
    vals, idx = compress_topk(g, k_frac=0.5)
    dec = decompress_topk(vals, idx, g.shape, g.dtype)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(jnp.array([0, 5, -3, 0, 0, -7.0])))


def test_error_feedback_preserves_mass():
    """Over steps, error feedback transmits everything eventually."""
    g = jnp.array([1.0, 0.5, 0.25, 0.1])
    residual = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(8):
        g_hat, residual = error_feedback_update(g, residual, k_frac=0.25)
        sent = sent + g_hat
    # total transmitted ~ 8x g minus bounded residual
    np.testing.assert_allclose(np.asarray(sent + residual),
                               np.asarray(8 * g), rtol=1e-5)


def test_watchdog_fires():
    with pytest.raises(ft.StepTimeout):
        with ft.Watchdog(0.05) as wd:
            time.sleep(0.15)
            wd.check()


def test_straggler_detector():
    det = ft.StragglerDetector(threshold=2.0)
    for _ in range(10):
        det.record(1.0)
    assert det.record(5.0) is True
    assert det.straggler_steps == 1


def test_run_with_retries_recovers():
    calls = {"n": 0, "restores": 0}

    def step_once(i):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected failure")

    def restore():
        calls["restores"] += 1
        return 1  # rewind to step 1

    done, retries, _ = ft.run_with_retries(step_once, 5, restore,
                                           step_timeout_s=60.0,
                                           retryable=(RuntimeError,))
    assert done == 5 and retries == 1 and calls["restores"] == 1


def test_split_serve_matches_full_forward():
    """Device-stage + edge-stage == the unsplit forward (paper's split)."""
    from repro.runtime.serve import make_split_serve
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    full, _, _ = model.train_logits(params, {"tokens": toks})
    for s in (0, 1, cfg.n_layers // 2, cfg.n_layers):
        progs = make_split_serve(model, params, s)
        act = progs.device_fn(toks)
        logits = progs.edge_fn(act)
        err = float(jnp.max(jnp.abs(logits - full)))
        assert err < 0.05, (s, err)
