"""Property-based Li-GD projection invariants (optional 'hypothesis' dep)."""
import jax
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional "
                    "'hypothesis' dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core import project_simplex_floor


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(2, 12))
def test_simplex_projection(seed, m):
    y = jax.random.normal(jax.random.PRNGKey(seed), (5, m)) * 3.0
    floor = 1e-3
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.sum(np.asarray(x), -1), 1.0, atol=1e-5)
    assert bool(jnp.all(x >= floor - 1e-6))
    # idempotent
    x2 = project_simplex_floor(x, floor)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x2), atol=1e-5)


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(2, 12),
       excess=st.floats(1.001, 50.0))
def test_simplex_projection_infeasible_floor(seed, m, excess):
    """m * floor > 1: the clamped projection must still land on the simplex
    (sum 1, nonneg) -- the regression this guards silently returned rows
    summing to 1 - m*floor + m*floor... < 1 with negative entries."""
    floor = excess / m
    y = jax.random.normal(jax.random.PRNGKey(seed), (5, m)) * 3.0
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.sum(np.asarray(x), -1), 1.0, atol=1e-5)
    assert bool(jnp.all(x >= -1e-6))
    # the clamped set is the single point ones/m
    np.testing.assert_allclose(np.asarray(x), 1.0 / m, atol=1e-5)
