"""Property-based Li-GD projection invariants (optional 'hypothesis' dep)."""
import jax
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional "
                    "'hypothesis' dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core import project_simplex_floor


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(2, 12))
def test_simplex_projection(seed, m):
    y = jax.random.normal(jax.random.PRNGKey(seed), (5, m)) * 3.0
    floor = 1e-3
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.sum(np.asarray(x), -1), 1.0, atol=1e-5)
    assert bool(jnp.all(x >= floor - 1e-6))
    # idempotent
    x2 = project_simplex_floor(x, floor)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x2), atol=1e-5)
