"""Mesh-sharded fleet planning == the vmapped path, per fleet member.

These tests need >= 8 local devices; CI forces them with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (see .github/workflows).
Without the flag they skip -- the vmap-path equivalents in
test_planning_engine.py still run everywhere.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import GdConfig, make_env, make_weights, profiles
from repro.planning import PlannerEngine
from repro.pshard import fleet_axis, fleet_mesh, shard_fleet
from repro.scenarios import Scenario, ScenarioConfig

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

FLEET = 8
ADAM_CFG = GdConfig(step_size=1e-2, eps=1e-4, max_iters=80, optimizer="adam")
# warm_rho_min=0.9: with static positions the path-loss structure keeps the
# gain correlation ~0.6-0.85 even for fully uncorrelated fading, while
# rho=0.999 fading estimates ~0.999 -- so half the fleet below lands on each
# side of the gate.
SCFG = ScenarioConfig(n_users=6, n_aps=2, n_sub=3, speed_mps=0.0,
                      arrival_rate_hz=0.0)


@pytest.fixture(scope="module")
def engines():
    w = make_weights(SCFG.n_users)
    vm = PlannerEngine(profiles.nin(), weights=w, cfg=ADAM_CFG,
                       warm_rho_min=0.9)
    return vm, vm.shard(fleet_mesh())


@pytest.fixture(scope="module")
def fleet_rollout(engines):
    """Two epochs of an 8-member fleet, planned on both paths. The second
    epoch's per-member fading rho splits the fleet across the warm gate:
    members 0-3 stay correlated (0.999), members 4-7 redraw (0.0)."""
    vm, sh = engines
    sc = Scenario(SCFG)
    states = sc.init_many(jax.random.split(jax.random.PRNGKey(0), FLEET))
    envs0 = sc.env_many(states)
    plan_vm = vm.plan_many(envs0)
    plan_sh = sh.plan_many(shard_fleet(envs0, sh.mesh))
    rho = jnp.array([0.999] * 4 + [0.0] * 4)
    states = sc.step_many(jax.random.split(jax.random.PRNGKey(1), FLEET),
                          states, rho=rho)
    envs1 = sc.env_many(states)
    warm_vm = vm.replan_many(plan_vm, envs1)
    warm_sh = sh.replan_many(plan_sh, shard_fleet(envs1, sh.mesh))
    return plan_vm, plan_sh, warm_vm, warm_sh


def _assert_members_match(a, b):
    """Per-member agreement between two batched PlanStates: same split,
    utility within tolerance, iteration counts within the couple-of-iters
    slack that different reduction orders can nudge a stopping rule by.
    Reads only the plan outputs: the sharded path *donates* the carried
    warm payload (norms/moms/steps), so those buffers are dead after the
    fixture's replan -- which is itself evidence the donation works."""
    for i in range(FLEET):
        assert int(a.plan.s[i]) == int(b.plan.s[i]), i
        assert float(a.plan.utility[i]) == pytest.approx(
            float(b.plan.utility[i]), abs=1e-4), i
        assert abs(int(a.total_iters[i]) - int(b.total_iters[i])) <= 2, i


def test_mesh_is_fleet_axis():
    mesh = fleet_mesh()
    assert fleet_axis(mesh) == "fleet"
    assert mesh.shape["fleet"] == jax.device_count()


def test_plan_many_sharded_matches_vmap(fleet_rollout):
    plan_vm, plan_sh, _, _ = fleet_rollout
    _assert_members_match(plan_vm, plan_sh)


def test_replan_many_sharded_matches_vmap(fleet_rollout):
    _, _, warm_vm, warm_sh = fleet_rollout
    _assert_members_match(warm_vm, warm_sh)


def test_warm_gate_per_member_and_agrees(fleet_rollout):
    """The in-jit rho estimate must agree across paths AND actually split
    the fleet: correlated members pass the gate, redrawn members fall below
    warm_rho_min and run the cold chain."""
    _, _, warm_vm, warm_sh = fleet_rollout
    rho_vm = jnp.asarray(warm_vm.warm_rho)
    rho_sh = jnp.asarray(warm_sh.warm_rho)
    assert rho_vm.shape == (FLEET,)
    assert jnp.max(jnp.abs(rho_vm - rho_sh)) < 1e-5
    gate = rho_vm >= 0.9
    assert bool(jnp.all(gate[:4])), rho_vm       # correlated: warm
    # Redrawn fading usually lands below the threshold, but a member whose
    # path-loss spread dominates its gains can legitimately stay above it;
    # what the test needs is both gate branches live in one fleet program.
    assert not bool(jnp.all(gate[4:])), rho_vm   # some member runs cold


def test_sharded_replan_dispatch_is_transfer_free(engines):
    """Steady-state sharded replan must enqueue with zero implicit
    transfers: state, envs, and engine constants already live on the mesh,
    and the warm gate is traced into the program (acceptance criterion:
    no host-side numpy in the dispatch path)."""
    _, sh = engines
    sc = Scenario(SCFG)
    states = sc.init_many(jax.random.split(jax.random.PRNGKey(7), FLEET))
    state = sh.plan_many(shard_fleet(sc.env_many(states), sh.mesh))
    states = sc.step_many(jax.random.split(jax.random.PRNGKey(8), FLEET),
                          states)
    envs = shard_fleet(sc.env_many(states), sh.mesh)
    state = sh.replan_many(state, envs)     # compile the warm program
    states = sc.step_many(jax.random.split(jax.random.PRNGKey(9), FLEET),
                          states)
    envs = shard_fleet(sc.env_many(states), sh.mesh)
    jax.block_until_ready((state, envs))
    w = make_weights(SCFG.n_users)   # per-call weights, made off-mesh
    jax.block_until_ready(w)
    with jax.transfer_guard("disallow"):
        # engine-held weights AND caller-passed weights must both dispatch
        # transfer-free (the latter are replicated explicitly per call)
        nxt = sh.replan_many(state, envs, weights=w)
    jax.block_until_ready(nxt)
    assert nxt.plan.s.shape == (FLEET,)


def test_mesh_is_read_only(engines):
    """The compiled fleet programs and replicated constants are lowered per
    mesh; swapping meshes must go through shard(), not attribute mutation."""
    _, sh = engines
    with pytest.raises(AttributeError):
        sh.mesh = None
    assert sh.shard(None).mesh is None


def test_mesh_engine_single_scenario_still_works(engines):
    """A mesh-attached engine must still serve single-scenario plan/replan:
    the mesh-replicated constants belong only to the sharded fleet programs,
    and an env committed to one device must not collide with them."""
    _, sh = engines
    env = jax.device_put(
        make_env(jax.random.PRNGKey(5), SCFG.n_users, SCFG.n_aps, SCFG.n_sub),
        jax.devices()[0])
    state = sh.replan(sh.plan(env), env)
    assert float(state.warm_rho) == pytest.approx(1.0, abs=1e-5)


def test_fleet_not_divisible_raises(engines):
    _, sh = engines
    sc = Scenario(SCFG)
    states = sc.init_many(jax.random.split(jax.random.PRNGKey(3), FLEET - 2))
    with pytest.raises(ValueError, match="divisible"):
        sh.plan_many(sc.env_many(states))
