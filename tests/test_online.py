"""Unit tests for the repro.online subsystem: request streams, continuous
batching, measured-profile telemetry, and the QoS monitor."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import profiles
from repro.core.types import ComputeConstants, ProfileShapeError, lam
from repro.online import (
    ContinuousBatcher,
    Observation,
    QosConfig,
    QosMonitor,
    RequestStream,
    StreamConfig,
    Telemetry,
)
from repro.online import batcher as batcherlib
from repro.online.batcher import Completions


# -- streams ---------------------------------------------------------------
def test_stream_deterministic_replay():
    """Epoch t's traffic is a function of (base_key, t) alone: two streams
    driven by the same base key produce identical counts, and replaying
    from a reset state reproduces the episode."""
    cfg = StreamConfig(arrival_rate_hz=8.0, epoch_dt_s=0.1)
    st = RequestStream(cfg, 6)
    key = jax.random.PRNGKey(3)
    s1, s2 = st.init(jax.random.PRNGKey(0)), st.init(jax.random.PRNGKey(0))
    ep1, ep2 = [], []
    for _ in range(5):
        s1, c1 = st.step(key, s1)
        s2, c2 = st.step(key, s2)
        ep1.append(np.asarray(c1))
        ep2.append(np.asarray(c2))
    np.testing.assert_array_equal(np.stack(ep1), np.stack(ep2))
    assert int(s1.offered) == int(np.sum(ep1))


def test_stream_poisson_rate_and_cap():
    """Mean arrivals approach rate*dt per active user; the per-epoch cap
    holds exactly; inactive sessions offer nothing."""
    cfg = StreamConfig(arrival_rate_hz=5.0, epoch_dt_s=0.2,
                       max_per_user_epoch=3, duty_cycle=1.0)
    st = RequestStream(cfg, 32)
    state = st.init(jax.random.PRNGKey(1))
    total, n = 0, 200
    for _ in range(n):
        state, counts = st.step(jax.random.PRNGKey(7), state)
        assert int(jnp.max(counts)) <= 3
        total += int(jnp.sum(counts))
    mean = total / (n * 32)
    # lam = 1.0, capped at 3 -> E[min(Pois(1), 3)] ~ 0.97
    assert 0.85 < mean < 1.1, mean
    quiet = RequestStream(dataclasses.replace(cfg, duty_cycle=1e-9), 32)
    qs = quiet.init(jax.random.PRNGKey(2))
    qs, counts = quiet.step(jax.random.PRNGKey(7), qs)
    assert int(jnp.sum(counts)) == 0


def test_stream_session_churn_changes_population():
    cfg = StreamConfig(session_churn_hz=5.0, epoch_dt_s=0.5, duty_cycle=0.5)
    st = RequestStream(cfg, 64)
    state = st.init(jax.random.PRNGKey(0))
    before = np.asarray(state.session)
    for _ in range(4):
        state, _ = st.step(jax.random.PRNGKey(9), state)
    assert np.any(np.asarray(state.session) != before)
    with pytest.raises(ValueError):
        RequestStream(StreamConfig(max_per_user_epoch=0), 4)
    with pytest.raises(ValueError):
        RequestStream(StreamConfig(duty_cycle=0.0), 4)


# -- batcher ---------------------------------------------------------------
def _step_batch(b, state, counts, now, service, work):
    u = len(counts)
    return b.step(state, jnp.asarray(counts, jnp.int32),
                  jnp.float32(now),
                  jnp.full((u,), service, jnp.float32),
                  jnp.full((u,), work, jnp.int32))


def test_batcher_fifo_admission_and_completion():
    """Arrivals queue FIFO, fill free slots, serve for `work` epochs, and
    complete with latency = queue wait + modeled service."""
    b = ContinuousBatcher(capacity=2, queue_depth=8, max_per_user_epoch=4)
    state = b.init()
    # 4 requests from user 0 at t=0; capacity 2 -> 2 admitted (work 2 -> 1),
    # 2 queued
    state, comp = _step_batch(b, state, [4, 0], 0.0, 0.25, 2)
    assert int(batcherlib.occupancy(state)) == 2
    assert int(batcherlib.backlog(state)) == 2
    assert not bool(jnp.any(comp.valid))
    # work hits 0: both complete (wait 0.0, serv 0.25); the queued pair is
    # still behind them this epoch (admission precedes the tick)
    state, comp = _step_batch(b, state, [0, 0], 0.1, 0.25, 2)
    assert int(jnp.sum(comp.valid)) == 2
    np.testing.assert_allclose(
        np.asarray(comp.latency)[np.asarray(comp.valid)], 0.25, atol=1e-6)
    assert int(batcherlib.occupancy(state)) == 0
    assert int(batcherlib.backlog(state)) == 2
    # the freed slots refill from the queue head: wait = 0.2 - 0.0
    state, comp = _step_batch(b, state, [0, 0], 0.2, 0.25, 2)
    assert int(batcherlib.occupancy(state)) == 2
    assert int(batcherlib.backlog(state)) == 0
    state, comp = _step_batch(b, state, [0, 0], 0.3, 0.25, 2)
    lat = np.asarray(comp.latency)[np.asarray(comp.valid)]
    np.testing.assert_allclose(lat, 0.2 + 0.25, atol=1e-5)
    assert int(state.completed) == 4


def test_batcher_drops_on_full_ring():
    b = ContinuousBatcher(capacity=1, queue_depth=2, max_per_user_epoch=4)
    state = b.init()
    # every arrival passes through the ring before admission: 2 fit the
    # depth-2 ring (one of them is admitted in the same epoch), 2 drop
    state, _ = _step_batch(b, state, [4], 0.0, 1.0, 100)
    assert int(state.dropped) == 2
    assert int(batcherlib.occupancy(state)) == 1
    assert int(batcherlib.backlog(state)) == 1
    with pytest.raises(ValueError):
        ContinuousBatcher(capacity=0, queue_depth=2, max_per_user_epoch=1)


def test_batcher_work_caps_slot_occupancy():
    """A request occupies its slot for exactly `work` epochs."""
    b = ContinuousBatcher(capacity=1, queue_depth=4, max_per_user_epoch=1)
    state = b.init()
    state, comp = _step_batch(b, state, [1], 0.0, 0.5, 3)
    for _ in range(2):
        assert int(batcherlib.occupancy(state)) == 1
        state, comp = _step_batch(b, state, [0], 0.0, 0.5, 3)
    assert bool(jnp.any(comp.valid))
    assert int(batcherlib.occupancy(state)) == 0


# -- telemetry -------------------------------------------------------------
def _obs(prof, comp, s, congestion, rate_up=1e6, rate_dn=1e6, r=4.0):
    f = prof.n_layers
    on_dev = jnp.arange(f) < s
    edge_speed = lam(jnp.float32(r), comp) * comp.c_min_edge
    t_layer = jnp.where(on_dev, prof.fl / comp.c_device,
                        prof.fl * congestion / edge_speed)
    return Observation(t_layer=t_layer,
                       t_up=prof.w[s] / rate_up,
                       rate_up=jnp.float32(rate_up),
                       rate_dn=jnp.float32(rate_dn),
                       r_units=jnp.float32(r))


def test_telemetry_congestion_flows_into_m_down_not_fl():
    """Edge congestion must not inflate fl (it would cancel out of the
    split comparison); it lands in kappa and the measured m_down."""
    prof = profiles.nin()
    comp = ComputeConstants()
    tel = Telemetry(prof, comp, decay=0.0)   # no smoothing: one-shot
    state = tel.init()
    s = jnp.int32(3)
    state = tel.update(state, s, _obs(prof, comp, 3, congestion=10.0))
    np.testing.assert_allclose(np.asarray(state.fl), np.asarray(prof.fl),
                               rtol=1e-5)
    assert float(state.kappa) == pytest.approx(10.0, rel=1e-5)
    mp = tel.profile(state)
    # measured m_down grows with the candidate suffix: congested offload is
    # penalized more the more layers it would offload
    extra = np.asarray(mp.m_down - prof.m_down)
    assert extra[0] > extra[5] > extra[-1] == 0.0
    # uncongested observation relaxes kappa back
    state = tel.update(state, s, _obs(prof, comp, 3, congestion=1.0))
    assert float(state.kappa) == pytest.approx(1.0, rel=1e-5)


def test_telemetry_ema_and_upload_repricing():
    prof = profiles.nin()
    comp = ComputeConstants()
    tel = Telemetry(prof, comp, decay=0.5)
    state = tel.init()
    s = 4
    # the upload at split 4 observed at half the modeled rate -> w[4] doubles
    slow = _obs(prof, comp, s, congestion=1.0)
    slow = slow._replace(t_up=2.0 * prof.w[s] / slow.rate_up)
    for _ in range(20):
        state = tel.update(state, jnp.int32(s), slow)
    w = np.asarray(state.w)
    assert w[s] == pytest.approx(2.0 * float(prof.w[s]), rel=1e-3)
    # only the exercised split was touched
    untouched = np.delete(np.asarray(prof.w), s)
    np.testing.assert_allclose(np.delete(w, s), untouched, rtol=1e-6)
    assert int(state.updates) == 20
    with pytest.raises(ValueError):
        Telemetry(prof, comp, decay=1.0)


def test_telemetry_profile_is_planner_compatible():
    """profile() output passes validate_like (same shapes/dtypes/name) and
    keeps stable avals across updates -- the no-recompile contract."""
    prof = profiles.nin()
    comp = ComputeConstants()
    tel = Telemetry(prof, comp)
    state = tel.init()
    mp0 = tel.profile(state)
    prof.validate_like(mp0)
    state = tel.update(state, jnp.int32(2),
                       _obs(prof, comp, 2, congestion=7.0))
    mp1 = tel.profile(state)
    assert jax.eval_shape(lambda: mp0) == jax.eval_shape(lambda: mp1)
    assert mp1.name == prof.name


def test_profile_validation_errors_are_specific():
    prof = profiles.nin()
    other = profiles.vgg16()
    with pytest.raises(ProfileShapeError, match="layers"):
        prof.validate_like(other)
    renamed = dataclasses.replace(prof, name="nin-measured")
    with pytest.raises(ProfileShapeError, match="name"):
        prof.validate_like(renamed)
    wrong_dtype = dataclasses.replace(
        prof, fl=prof.fl.astype(jnp.float64)
        if jax.config.jax_enable_x64 else prof.fl.astype(jnp.float16))
    with pytest.raises(ProfileShapeError, match="fl"):
        prof.validate_like(wrong_dtype)
    # like() repairs dtype and preserves the name
    fixed = prof.like(prof.fl.astype(jnp.float16), prof.w, prof.m_down)
    assert fixed.fl.dtype == prof.fl.dtype and fixed.name == prof.name
    tel = Telemetry(prof, ComputeConstants())
    with pytest.raises(ProfileShapeError):
        tel.init(other)


# -- qos -------------------------------------------------------------------
def _complete(latencies, users=None):
    lat = jnp.asarray(latencies, jnp.float32)
    b = lat.shape[0]
    return Completions(
        valid=jnp.ones((b,), bool),
        user=jnp.zeros((b,), jnp.int32) if users is None
        else jnp.asarray(users, jnp.int32),
        latency=lat, wait=jnp.zeros((b,), jnp.float32), serv=lat)


def test_qos_percentiles_match_numpy():
    cfg = QosConfig(window=64, p95_max_s=1e9, p50_max_s=1e9,
                    miss_rate_max=1.1)
    mon = QosMonitor(cfg, 2)
    state = mon.init()
    rng = np.random.default_rng(0)
    seen = []
    for _ in range(6):
        lats = rng.uniform(0.01, 0.9, size=5)
        seen.extend(lats)
        state, rep = mon.update(state, _complete(lats))
    ranked = np.sort(seen)
    n = len(seen)
    exp50 = ranked[int(round(0.50 * (n - 1)))]
    exp95 = ranked[int(round(0.95 * (n - 1)))]
    assert float(rep.p50) == pytest.approx(exp50, rel=1e-5)
    assert float(rep.p95) == pytest.approx(exp95, rel=1e-5)
    assert not bool(rep.trigger)


def test_qos_trigger_fires_and_cools_down():
    cfg = QosConfig(deadline_s=0.1, p95_max_s=0.2, p50_max_s=0.15,
                    miss_rate_max=0.5, window=16, cooldown_epochs=3)
    mon = QosMonitor(cfg, 4)
    state = mon.init()
    state, rep = mon.update(state, _complete([0.01, 0.02, 0.03]))
    assert not bool(rep.trigger)
    # sustained latency breach: first breach triggers, cooldown holds after
    state, rep = mon.update(state, _complete([0.9, 0.8, 0.95]))
    assert bool(rep.trigger)
    for _ in range(2):
        state, rep = mon.update(state, _complete([0.9, 0.8, 0.95]))
        assert not bool(rep.trigger)       # cooling down
    for _ in range(2):
        state, rep = mon.update(state, _complete([0.9, 0.8, 0.95]))
    assert int(state.triggers) >= 2        # re-armed and re-fired
    assert int(state.missed) > 0
    # per-user miss EMA tracked for the completing users
    state, _ = mon.update(state, _complete([0.9], users=[2]))
    assert float(state.miss[2]) > 0.0
    with pytest.raises(ValueError):
        QosMonitor(QosConfig(window=1), 2)
