"""repro.faults: seeded injectors, packed health guards, the degradation
ladder, the guaranteed-finite fallback plan, and the hardened closed loop
(plan rejection, telemetry quarantine, shedding, zero-recompile injection)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import channel, make_weights, profiles
from repro.core.types import GdConfig
from repro.faults import (
    PLAN_MASK,
    TELEMETRY_MASK,
    DegradeLadder,
    FaultConfig,
    LadderConfig,
    apply_env_faults,
    corrupt_observation,
    decode_health,
    fallback_plan,
    fault_step,
    init_fault_state,
    plan_health,
    plan_word,
    spike_service,
    split_plan_word,
    telemetry_health,
)
from repro.online import OnlineLoop, ServiceConfig, StreamConfig
from repro.online.telemetry import Observation, Telemetry
from repro.planning import PlannerEngine, compile_log
from repro.scenarios import Scenario, ScenarioConfig

ADAM_CFG = GdConfig(step_size=3e-2, eps=1e-4, max_iters=40, optimizer="adam")
SCEN = ScenarioConfig(n_users=6, n_aps=2, n_sub=3, fading_rho=0.95)
STREAM = StreamConfig(arrival_rate_hz=25.0, epoch_dt_s=0.02, deadline_s=0.2)
SERVICE = ServiceConfig(edge_capacity=4, queue_depth=16, load_gain=4.0,
                        replan_every=3, max_work_epochs=200)
CHAOS = FaultConfig(link_outage_rate=0.2, fade_depth=1e-6,
                    ap_outage_rate=0.05, telemetry_drop_rate=0.1,
                    telemetry_spike_rate=0.05, service_spike_rate=0.02)


def _env(seed=0):
    return channel.make_env(jax.random.PRNGKey(seed), n_users=6, n_aps=2,
                            n_sub=3)


def _hardened(faults=CHAOS, degrade=LadderConfig(), **kw):
    eng = PlannerEngine(profiles.nin(), cfg=ADAM_CFG)
    return OnlineLoop(Scenario(SCEN), eng, STREAM, SERVICE, faults=faults,
                      degrade=degrade, **kw)


class TestInjectors:
    def test_deterministic_from_key(self):
        rates = CHAOS.rates()
        st = init_fault_state(6, 2)
        key = jax.random.PRNGKey(42)
        s1, d1 = fault_step(rates, key, st)
        s2, d2 = fault_step(rates, key, st)
        for a, b in zip(jax.tree.leaves((s1, d1)), jax.tree.leaves((s2, d2))):
            assert jnp.array_equal(a, b)

    def test_zero_config_is_identity(self):
        rates = FaultConfig().rates()
        st = init_fault_state(6, 2)
        st, draw = fault_step(rates, jax.random.PRNGKey(0), st)
        assert not bool(jnp.any(draw.link_down))
        assert not bool(jnp.any(draw.ap_down))
        assert not bool(draw.tel_drop) and not bool(draw.tel_spike)
        env = _env()
        env2 = apply_env_faults(env, draw, rates)
        assert jnp.array_equal(env.g_up, env2.g_up)
        assert jnp.array_equal(env.g_dn, env2.g_dn)
        svc = jnp.ones((6,))
        assert jnp.array_equal(spike_service(svc, draw), svc)

    def test_markov_outage_persists(self):
        # mean_epochs >> 1: a faded user usually stays faded next epoch.
        cfg = FaultConfig(link_outage_rate=0.3, link_mean_epochs=50.0)
        rates = cfg.rates()
        st = init_fault_state(64, 2)
        key = jax.random.PRNGKey(1)
        stays = total = 0
        for i in range(60):
            prev = st.link_down
            st, _ = fault_step(rates, jax.random.fold_in(key, i), st)
            stays += int(jnp.sum(prev & st.link_down))
            total += int(jnp.sum(prev))
        assert total > 0
        assert stays / total > 0.9      # recover prob is 1/50

    def test_stationary_outage_fraction(self):
        cfg = FaultConfig(link_outage_rate=0.2, link_mean_epochs=8.0)
        rates = cfg.rates()
        st = init_fault_state(256, 2)
        key = jax.random.PRNGKey(2)
        frac = []
        for i in range(300):
            st, _ = fault_step(rates, jax.random.fold_in(key, i), st)
            if i >= 50:                  # past burn-in
                frac.append(float(jnp.mean(st.link_down)))
        assert abs(sum(frac) / len(frac) - 0.2) < 0.05

    def test_ap_blackout_zeroes_cell(self):
        rates = CHAOS.rates()
        _, draw = fault_step(rates, jax.random.PRNGKey(0),
                             init_fault_state(6, 2))
        draw = draw._replace(ap_down=jnp.array([True, False]),
                             link_down=jnp.zeros((6,), bool))
        env = apply_env_faults(_env(), draw, rates)
        assert bool(jnp.all(env.g_up[:, 0, :] == 0.0))
        assert bool(jnp.all(env.g_dn[0, :, :] == 0.0))
        assert bool(jnp.all(env.g_up[:, 1, :] > 0.0))

    def test_corrupt_observation_drop_and_spike(self):
        obs = Observation(t_layer=jnp.ones((4,)), t_up=jnp.float32(1.0),
                          rate_up=jnp.float32(1e6), rate_dn=jnp.float32(1e6),
                          r_units=jnp.float32(2.0))
        rates = CHAOS.rates()
        _, draw = fault_step(rates, jax.random.PRNGKey(0),
                             init_fault_state(6, 2))
        dropped = corrupt_observation(
            obs, draw._replace(tel_drop=jnp.bool_(True),
                               tel_spike=jnp.bool_(False)), rates)
        assert bool(jnp.all(jnp.isnan(dropped.t_layer)))
        spiked = corrupt_observation(
            obs, draw._replace(tel_drop=jnp.bool_(False),
                               tel_spike=jnp.bool_(True)), rates)
        assert jnp.allclose(spiked.t_layer,
                            obs.t_layer * CHAOS.telemetry_spike_scale)


class TestGuards:
    def _plan(self):
        eng = PlannerEngine(profiles.nin(), cfg=ADAM_CFG)
        return eng.plan(_env()).plan, _env()

    def _health(self, plan, env):
        return int(plan_health(plan, n_sub=env.n_sub,
                               p_up_max=env.radio.p_up_max_w,
                               p_dn_max=env.radio.p_dn_max_w,
                               r_max=env.comp.r_max))

    def test_clean_plan_is_healthy(self):
        plan, env = self._plan()
        assert self._health(plan, env) == 0

    def test_nan_utility_sets_plan_bit(self):
        plan, env = self._plan()
        bad = dataclasses.replace(plan, utility=jnp.float32(jnp.nan))
        h = self._health(bad, env)
        assert h & PLAN_MASK
        assert decode_health(h)["plan_utility"]

    def test_infeasible_power_sets_power_bit(self):
        plan, env = self._plan()
        bad = dataclasses.replace(
            plan, p_up=plan.p_up.at[0].set(10.0 * env.radio.p_up_max_w))
        assert decode_health(self._health(bad, env))["plan_power"]

    def test_plan_word_roundtrip(self):
        plan, env = self._plan()
        word = int(plan_word(plan, n_sub=env.n_sub,
                             p_up_max=env.radio.p_up_max_w,
                             p_dn_max=env.radio.p_dn_max_w,
                             r_max=env.comp.r_max))
        health, s = split_plan_word(word)
        assert health == 0
        assert s == int(plan.s)

    def test_telemetry_health_bits(self):
        tel = Telemetry(profiles.nin(), _env().comp, decay=0.5)
        ts = tel.init()
        assert int(telemetry_health(ts, kappa_max=100.0)) == 0
        nan_ts = ts._replace(fl=ts.fl.at[0].set(jnp.nan))
        h = int(telemetry_health(nan_ts, kappa_max=100.0))
        assert h & TELEMETRY_MASK
        assert decode_health(h)["profile"]
        hot = ts._replace(kappa=jnp.float32(1e4))
        assert decode_health(int(telemetry_health(hot, 100.0)))["kappa"]


class TestLadder:
    def test_escalation_order_and_backoff(self):
        lad = DegradeLadder(LadderConfig(baseline_after=2, backoff_base=2,
                                         backoff_max=8))
        assert lad.stage == "normal"
        lad.pre_replan(0)
        lad.post_replan(plan_ok=False, replanned=True)
        assert lad.stage == "hold" and not lad.serve_fallback
        # cooldown=2: one held epoch, then a forced cold retry
        d = lad.pre_replan(0)
        assert d.hold and not d.force
        d = lad.pre_replan(0)
        assert d.force and d.force_cold
        lad.post_replan(plan_ok=False, replanned=True)
        assert lad.stage == "baseline" and lad.serve_fallback
        assert lad.backoff == 8        # 2 -> 4 -> 8, doubling
        lad.post_replan(plan_ok=False, replanned=True)
        assert lad.backoff == 8        # capped at backoff_max

    def test_recovery_counts_epochs(self):
        lad = DegradeLadder(LadderConfig(baseline_after=2, recover_after=1,
                                         backoff_base=1))
        lad.pre_replan(0)
        lad.post_replan(plan_ok=False, replanned=True)
        lad.pre_replan(0)
        lad.pre_replan(0)
        lad.post_replan(plan_ok=True, replanned=True)
        assert lad.stage == "normal"
        m = lad.metrics()
        assert m["recoveries"] == 1
        assert m["mean_recovery_epochs"] == 2.0
        assert lad.backoff == 1        # reset to base on recovery

    def test_held_epochs_carry_no_evidence(self):
        lad = DegradeLadder(LadderConfig())
        lad.pre_replan(0)
        lad.post_replan(plan_ok=None, replanned=False)
        assert lad.stage == "normal" and lad.bad_streak == 0

    def test_quarantine_countdown(self):
        cfg = LadderConfig(quarantine_epochs=3)
        lad = DegradeLadder(cfg)
        d = lad.pre_replan(TELEMETRY_MASK)
        assert not d.use_measured
        assert lad.metrics()["quarantines"] == 1
        for _ in range(3):
            d = lad.pre_replan(0)
        assert d.use_measured          # countdown elapsed
        # re-corruption re-arms without double-counting a live quarantine
        lad.pre_replan(TELEMETRY_MASK)
        lad.pre_replan(TELEMETRY_MASK)
        assert lad.metrics()["quarantines"] == 2

    def test_timeout_escalates_without_plan_evidence(self):
        lad = DegradeLadder(LadderConfig(backoff_base=2))
        lad.on_timeout()
        assert lad.stage == "hold"
        assert lad.metrics()["watchdog_fires"] == 1


class TestFallbackPlan:
    def test_finite_under_total_blackout(self):
        env = _env()
        dead = dataclasses.replace(env, g_up=jnp.zeros_like(env.g_up),
                                   g_dn=jnp.zeros_like(env.g_dn))
        prof = profiles.nin()
        w = make_weights(env.n_users)
        # the terminal rung must be finite under ANY channel state,
        # including zero gains everywhere (full blackout)
        plan = fallback_plan(dead, prof, w, mode="device_only")
        assert bool(jnp.isfinite(plan.utility))
        assert int(plan.s) == prof.n_layers
        # the offload twin under a healthy channel
        plan = fallback_plan(env, prof, w, mode="edge_only")
        assert bool(jnp.isfinite(plan.utility))
        assert int(plan.s) == 0

    def test_aval_parity_with_engine_plan(self):
        env = _env()
        eng = PlannerEngine(profiles.nin(), cfg=ADAM_CFG)
        template = eng.plan(env).plan
        w = make_weights(env.n_users)
        fb = fallback_plan(env, profiles.nin(), w, template=template)
        ref = jax.eval_shape(lambda: template)
        got = jax.eval_shape(lambda: fb)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            assert (a.shape, a.dtype) == (b.shape, b.dtype)

    def test_rejects_unknown_mode(self):
        with pytest.raises(ValueError):
            fallback_plan(_env(), profiles.nin(), make_weights(6),
                          mode="pray")


class TestServerGuard:
    def test_nan_profile_plan_rejected_and_held(self):
        """A NaN measured profile produces a NaN-utility plan; the guarded
        server must reject it via the packed word, hold the last good
        state, and count it -- the loop's plan on the air stays finite."""
        from repro.runtime.serve import OnlineSplitServer

        env = _env()
        eng = PlannerEngine(profiles.nin(), cfg=ADAM_CFG)
        srv = OnlineSplitServer(eng, replan_every=1, guard_plans=True)
        srv.observe(env)                          # cold plan, clean
        good = srv.state
        assert srv.last_plan_ok and srv.bad_plans == 0
        p = eng.prof
        nan_prof = p.like(p.fl * jnp.nan, p.w, p.m_down)
        srv.observe(env, prof=nan_prof)
        assert srv.bad_plans == 1
        assert srv.last_plan_ok is False
        assert srv.state is good                  # held, not replaced
        assert bool(jnp.isfinite(srv.state.plan.utility))

    def test_unguarded_server_serves_the_nan(self):
        from repro.runtime.serve import OnlineSplitServer

        env = _env()
        eng = PlannerEngine(profiles.nin(), cfg=ADAM_CFG)
        srv = OnlineSplitServer(eng, replan_every=1, guard_plans=False)
        srv.observe(env)
        p = eng.prof
        srv.observe(env, prof=p.like(p.fl * jnp.nan, p.w, p.m_down))
        assert srv.bad_plans == 0                 # nothing trapped it
        assert not bool(jnp.isfinite(srv.state.plan.utility))


class TestHardenedLoop:
    def test_conserves_requests_including_shed(self):
        loop = _hardened()
        m = loop.run(jax.random.PRNGKey(2), 40)
        in_flight = int(jnp.sum(loop._bt.active))
        queued = int(loop._bt.q_size)
        assert m["offered"] == (m["completed"] + m["dropped"] + m["shed"]
                                + in_flight + queued)
        assert m["goodput"] <= m["completed"]

    def test_every_served_plan_finite_under_chaos(self):
        m = _hardened().run(jax.random.PRNGKey(7), 50, record=True)
        assert all(m["history"]["plan_finite"])

    def test_zero_fault_hardened_matches_plain(self):
        """With a zero fault config the hardened loop's traffic outcomes
        equal the plain loop's: injection is an exact identity and the
        ladder never engages."""
        plain = OnlineLoop(Scenario(SCEN),
                           PlannerEngine(profiles.nin(), cfg=ADAM_CFG),
                           STREAM, SERVICE)
        # shed_service_factor=0: admission shedding off, so the only
        # remaining differences are the (identity) injectors and guards
        hard = _hardened(faults=FaultConfig(),
                         degrade=LadderConfig(shed_service_factor=0.0))
        m_p = plain.run(jax.random.PRNGKey(3), 30, record=True)
        m_h = hard.run(jax.random.PRNGKey(3), 30, record=True)
        assert m_p["completed"] == m_h["completed"]
        assert m_p["offered"] == m_h["offered"]
        assert m_h["bad_plans"] == 0 and m_h["quarantines"] == 0
        assert m_p["history"]["s"] == m_h["history"]["s"]

    def test_rate_swap_traces_nothing(self):
        loop = _hardened()
        loop.reset(jax.random.PRNGKey(0))
        for _ in range(10):
            loop.step_epoch()
        with compile_log() as log:
            loop.set_fault_rates(FaultConfig(link_outage_rate=0.5,
                                             telemetry_drop_rate=0.3))
            for _ in range(6):
                loop.step_epoch()
        assert log == []
