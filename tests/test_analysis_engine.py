"""Engine-level audits: exact recompile counts across cold/warm/cold
dispatch (the PR 3 weak-type regression, now counted rather than inferred
from cache_size), cache-key discipline probes with a deliberately broken
engine as the positive control, the transfer-guard runtime probe, and the
full trace-only audit_engine sweep on both SINR backends."""
import jax
import jax.numpy as jnp
import pytest

from repro import analysis
from repro.core import make_env, make_weights, profiles
from repro.core.types import GdConfig
from repro.planning import PlannerEngine, compile_log

CFG = GdConfig(max_iters=25)


def _engine(**kw):
    kw.setdefault("weights", make_weights(8))
    kw.setdefault("cfg", CFG)
    return PlannerEngine(profiles.nin(), **kw)


@pytest.fixture()
def env_a():
    return make_env(jax.random.PRNGKey(1), n_users=8, n_aps=2, n_sub=4)


@pytest.fixture()
def env_b():
    return make_env(jax.random.PRNGKey(2), n_users=8, n_aps=2, n_sub=4)


def test_recompile_count_cold_warm_cold(env_a, env_b):
    """The regression the PR 3 weak-type fix bought, asserted exactly: a
    cold plan compiles once, the first replan compiles once, and every
    subsequent dispatch -- warm-on-warm, and a SECOND env of the same
    shape through both paths -- reuses those two programs. Any third
    entry in the log is a recompile leak."""
    eng = _engine()
    with compile_log() as log:
        state = eng.plan(env_a)
        state = eng.replan(state, env_a)
        state = eng.replan(state, env_a)
        s2 = eng.plan(env_b)
        s2 = eng.replan(s2, env_b)
        s2 = eng.replan(s2, env_b)
        jax.block_until_ready(s2.plan.utility)
    jax.block_until_ready(state.plan.utility)
    assert log == ["plan", "replan"], log


def test_compile_log_nested_sinks(env_a):
    """Sinks stack: an inner log sees only its own window."""
    eng = _engine()
    with compile_log() as outer:
        eng.plan(env_a)
        with compile_log() as inner:
            eng.plan(env_a)                       # cached: no compile
            eng.replan(eng.plan(env_a), env_a)    # new kind: one compile
        assert inner == ["replan"], inner
    assert outer == ["plan", "replan"], outer


def test_cache_key_discipline_clean(env_a):
    env_c = make_env(jax.random.PRNGKey(3), n_users=6, n_aps=2, n_sub=4)
    eng = _engine()
    report = analysis.CacheKeyDiscipline().probe(eng, env_a, env_c)
    assert report.ok, report.findings
    # the probe restored the engine's tunables
    assert eng.warm_rho_min == 0.5 and eng.cfg == CFG
    # and the minted keys carry the full discipline tuple
    for key in eng.cache_keys():
        assert key[0] in {"plan", "replan"}
        assert key[5] in {0.5, 0.25}              # warm_rho_min in the key


class _GateBlindEngine(PlannerEngine):
    """Deliberately broken: warm_rho_min is dropped from the cache key, so
    retuning the gate on a live engine silently reuses the stale program --
    exactly the defect CacheKeyDiscipline exists to catch."""

    def _compiled(self, kind, env):
        key = (kind, self._env_shape(env), self.cfg, self.method,
               self.rounding, self.warm_moment_decay)
        fn = self._cache.get(key)
        if fn is None:
            scratch, self._cache = self._cache, {}
            try:
                fn = super()._compiled(kind, env)
            finally:
                self._cache = scratch
            self._cache[key] = fn
        return fn


def test_cache_key_discipline_flags_gate_blind_engine(env_a):
    report = analysis.CacheKeyDiscipline().probe(
        _GateBlindEngine(profiles.nin(), weights=make_weights(8), cfg=CFG),
        env_a)
    assert not report.ok
    finding = report.findings[0]    # later steps cascade off the miss
    assert finding.rule == "cache_key_discipline"
    assert finding.detail["step"].startswith("warm_rho_min retune")
    assert "minting" in finding.message


def test_runtime_probe_clean(env_a, env_b):
    report = analysis.runtime_probe(_engine(), env_a, env_b)
    assert report.ok, report.findings


@pytest.mark.parametrize("backend", ["einsum", "pallas_interpret"])
def test_audit_engine_clean_both_backends(env_a, backend):
    eng = _engine(sinr_backend=backend)
    report = analysis.audit_engine(eng, env_a, fleet=2)
    assert report.ok, report.findings
    assert [p.split(":")[-1] for p in report.programs] == [
        "plan", "replan", "replan_many"]
    # einsum programs skip the memory-model rules; pallas programs run all
    assert ("sparse_grid" in report.rules) == (backend != "einsum")


def test_program_args_requires_prev_for_replan(env_a):
    eng = _engine()
    with pytest.raises(ValueError, match="prev"):
        eng.program_args("replan", env_a)
    # trace-only: eval_shape avals are enough to assemble the warm payload
    cold = jax.eval_shape(eng.program("plan", env_a),
                          *eng.program_args("plan", env_a))
    args = eng.program_args("replan", env_a, prev=cold)
    closed = analysis.trace(eng.program("replan", env_a), *args)
    assert closed.out_avals
