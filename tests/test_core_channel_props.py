"""Property-based NOMA channel invariants (optional 'hypothesis' dep)."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional "
                    "'hypothesis' dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core import channel, make_env


def _vars(env, key, onehot=False):
    ku, kd, kp, kq = jax.random.split(key, 4)
    u, m = env.n_users, env.n_sub
    if onehot:
        beta_up = jax.nn.one_hot(jax.random.randint(ku, (u,), 0, m), m)
        beta_dn = jax.nn.one_hot(jax.random.randint(kd, (u,), 0, m), m)
    else:
        beta_up = jax.random.dirichlet(ku, jnp.ones(m), (u,))
        beta_dn = jax.random.dirichlet(kd, jnp.ones(m), (u,))
    p_up = jax.random.uniform(kp, (u,), minval=1e-3, maxval=0.3)
    p_dn = jax.random.uniform(kq, (u,), minval=0.1, maxval=10.0)
    return beta_up, beta_dn, p_up, p_dn


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), onehot=st.booleans())
def test_rates_finite_nonneg(seed, onehot):
    key = jax.random.PRNGKey(seed)
    env = make_env(key, n_users=6, n_aps=2, n_sub=3)
    bu, bd, pu, pd = _vars(env, key, onehot)
    ru = channel.uplink_rates(env, bu, pu)
    rd = channel.downlink_rates(env, bd, pd)
    for r in (ru, rd):
        assert bool(jnp.all(jnp.isfinite(r)))
        assert bool(jnp.all(r >= 0.0))


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**31 - 1))
def test_own_power_monotone(seed):
    """Raising my tx power (others fixed) cannot lower my uplink SINR."""
    key = jax.random.PRNGKey(seed)
    env = make_env(key, n_users=6, n_aps=2, n_sub=3)
    bu, _, pu, _ = _vars(env, key)
    s0 = channel.uplink_sinr(env, bu, pu)
    pu2 = pu.at[0].mul(2.0)
    s1 = channel.uplink_sinr(env, bu, pu2)
    assert bool(jnp.all(s1[0] >= s0[0] - 1e-9))
