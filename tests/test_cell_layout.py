"""CellLayout (kernels/cells.py): the AP-sorted cell-block schedule.

Permutation invariance -- rates and gradients computed through the sorted
layout must match the UNSORTED einsum oracle after the inverse permutation
(which the ops wrappers apply internally) -- plus the structural claims:
the intra grid launches only the block-diagonal tiles (sum-of-cell-sizes^2,
proven from the lowered jaxpr's grid shapes, not trusted from the tile
count), and the tile lists are exactly the same-cell coverage (block-sparse
oracle)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, make_env
from repro.kernels import build_cell_layout, ops, ref
from repro.kernels.cells import cell_tiles


def _case(u, n, m, seed=0, ap=None):
    env = make_env(jax.random.PRNGKey(seed), n_users=u, n_aps=n, n_sub=m)
    if ap is not None:
        env = dataclasses.replace(env, ap=jnp.asarray(ap, jnp.int32))
    beta = jax.random.dirichlet(jax.random.PRNGKey(seed + 1), jnp.ones(m), (u,))
    p_up = jax.random.uniform(jax.random.PRNGKey(seed + 2), (u,),
                              minval=0.01, maxval=0.3)
    p_dn = jax.random.uniform(jax.random.PRNGKey(seed + 3), (u,),
                              minval=0.1, maxval=10.0)
    return env, beta, p_up, p_dn


def _close(got, want, tol=1e-5):
    got, want = np.asarray(got), np.asarray(want)
    np.testing.assert_allclose(got, want, rtol=tol,
                               atol=tol * max(np.abs(want).max(), 1e-30))


def _skews(u, n):
    """AP assignments with skewed cell populations: natural (nearest-AP),
    one giant cell + many empty cells, and all-one-cell (N=1 behavior on
    an N-cell env)."""
    giant = np.zeros(u, np.int32)
    giant[:: max(u // 3, 1)] = n - 1          # a few users elsewhere
    return {"natural": None, "giant": giant,
            "one_cell": np.full(u, n // 2, np.int32)}


@pytest.mark.parametrize("u,n,m", [(20, 3, 6), (13, 5, 7), (9, 1, 12)])
@pytest.mark.parametrize("skew", ["natural", "giant", "one_cell"])
@pytest.mark.parametrize("link", ["up", "dn"])
def test_layout_rates_and_grads_match_unsorted_einsum(u, n, m, skew, link):
    """THE permutation-invariance contract: both links, both SIC orders
    (uplink decodes descending, downlink ascending -- the link choice
    exercises both), skewed cell populations including one giant cell with
    empty cells and N=1. Rates AND gradients at 1e-5 against the unsorted
    einsum oracle."""
    env, beta, p_up, p_dn = _case(u, n, m, seed=u + n,
                                  ap=_skews(u, n)[skew])
    layout = build_cell_layout(env, block_u=4, block_v=4)
    fn = channel.uplink_rates if link == "up" else channel.downlink_rates
    p = p_up if link == "up" else p_dn

    _close(fn(env, beta, p, backend="pallas_interpret", layout=layout),
           fn(env, beta, p, backend="einsum"))
    ge = jax.grad(lambda b, q: jnp.sum(fn(env, b, q, backend="einsum")),
                  argnums=(0, 1))(beta, p)
    gl = jax.grad(lambda b, q: jnp.sum(
        fn(env, b, q, backend="pallas_interpret", layout=layout)),
        argnums=(0, 1))(beta, p)
    for want, got in zip(jax.tree.leaves(ge), jax.tree.leaves(gl)):
        _close(got, want)


@pytest.mark.parametrize("descending", [True, False])
@pytest.mark.parametrize("uplink", [True, False])
def test_layout_pairwise_both_sic_orders(descending, uplink):
    """Kernel-level permutation invariance for BOTH SIC orders on BOTH
    links (the channel layer only ever pairs descending-with-uplink; the
    kernels support the full matrix): sorted-domain kernels + inverse
    permutation == unsorted gather-free reference."""
    u, n, m = 14, 4, 6
    env, beta, p_up, _ = _case(u, n, m, seed=5)
    layout = build_cell_layout(env, block_u=4, block_v=4)
    tx = (beta * p_up[:, None]).astype(jnp.float32)
    senv = layout.env
    own_s = (senv.own_gain_up() if uplink else senv.own_gain_dn()).astype(
        jnp.float32)
    g_raw_s = (senv.g_up if uplink else senv.g_dn).astype(jnp.float32)
    tx_s = tx[layout.perm]
    w_intra_s = tx_s * own_s if uplink else tx_s

    from repro.kernels.noma_rates import noma_pairwise_kernel
    ki, kx = noma_pairwise_kernel(
        own_s, own_s, w_intra_s, tx_s, g_raw_s, senv.ap, senv.ap,
        descending=descending, uplink=uplink, block_u=layout.block_u,
        block_v=layout.block_v, block_m=8, block_n=2,
        tiles=(layout.tile_u, layout.tile_v), interpret=True)

    own = (env.own_gain_up() if uplink else env.own_gain_dn()).astype(
        jnp.float32)
    g_raw = (env.g_up if uplink else env.g_dn).astype(jnp.float32)
    w_intra = tx * own if uplink else tx
    gi, gx = ref.noma_pairwise_gather_free_ref(
        own, own, w_intra, tx, g_raw, env.ap, descending=descending,
        uplink=uplink)
    _close(jnp.take(ki, layout.inv, axis=0), gi)
    _close(jnp.take(kx, layout.inv, axis=0), gx)


def test_block_sparse_oracle_matches_dense_reference():
    """The tile lists cover every same-cell pair exactly once: the
    tile-restricted oracle equals the dense gather-free reference, forward
    tiles and backward tiles (same set, reordered) alike -- including when
    adjacent cells share a boundary block (non-divisible cell sizes)."""
    u, n, m = 19, 4, 5
    ap = np.sort(np.asarray([0] * 7 + [1] * 3 + [2] * 8 + [3] * 1))
    env, beta, p_up, _ = _case(u, n, m, seed=9, ap=ap)
    layout = build_cell_layout(env, block_u=4, block_v=4)
    senv = layout.env
    own = senv.own_gain_up().astype(jnp.float32)
    tx = (beta * p_up[:, None]).astype(jnp.float32)[layout.perm]
    g_raw = senv.g_up.astype(jnp.float32)

    bi, bx = ref.noma_cell_block_ref(
        own, own, tx * own, tx, g_raw, senv.ap, layout.tile_u,
        layout.tile_v, layout.block_u, layout.block_v,
        descending=True, uplink=True)
    di, dx = ref.noma_pairwise_gather_free_ref(
        own, own, tx * own, tx, g_raw, senv.ap, descending=True, uplink=True)
    _close(bi, di)
    _close(bx, dx)
    # backward list: same coverage with roles swapped
    bwd_i, _ = ref.noma_cell_block_ref(
        own, own, tx * own, tx, g_raw, senv.ap, layout.bwd_tile_v,
        layout.bwd_tile_u, layout.block_v, layout.block_u,
        descending=True, uplink=True)
    _close(bwd_i, di)


def test_cell_tiles_counts_sum_of_cell_sizes():
    """Tile counts are the per-cell block products (sum-of-cell-sizes^2
    scaling), deduped across cells sharing a boundary block."""
    ap = np.asarray([0, 0, 0, 0, 1, 1, 1, 1], np.int32)
    tu, tv, _, _ = cell_tiles(ap, 4, 4)
    assert len(tu) == 2                       # two 1x1-block cells
    ap = np.zeros(16, np.int32)               # one giant cell, 4x4 blocks
    tu, tv, _, _ = cell_tiles(ap, 4, 4)
    assert len(tu) == 16
    ap = np.asarray([0, 0, 0, 1, 1, 1], np.int32)  # boundary block shared
    tu, tv, _, _ = cell_tiles(ap, 4, 4)
    # both cells touch blocks {0, 1}: 4 tiles total, deduped (no repeats)
    assert len(tu) == 4
    assert len(set(zip(tu.tolist(), tv.tolist()))) == len(tu)
    # non-decreasing fwd order (the kernel's revisit contract)
    assert (np.diff(tu) >= 0).all()


def test_intra_grid_scales_with_cell_sizes_not_u_squared():
    """The structural acceptance criterion, proven from the LOWERED jaxpr
    via the analysis.SparseGrid rule (the grid walker that used to live
    here): with U=32 in eight 4-user cells (block 4), the intra pallas grid
    is (NM, 8) -- one diagonal tile per cell -- while the dense (no-layout)
    schedule launches (NM, 64) = (U/BU)^2 tiles. The grid shape is what the
    hardware executes; sum-of-cell-sizes^2 vs U^2 is read off directly."""
    from repro import analysis

    u, n, m = 32, 8, 8
    ap = np.repeat(np.arange(8, dtype=np.int32), 4)
    env, beta, p_up, _ = _case(u, n, m, seed=2, ap=ap)
    layout = build_cell_layout(env, block_u=4, block_v=4)
    assert layout.n_tiles == 8                # sum of (c/4)^2 = 8 * 1
    assert layout.dense_n_tiles() == (u // 4) ** 2

    tx = beta * p_up[:, None]

    def fwd(with_layout):
        def f(t):
            return ops.noma_pairwise_up(
                env, t, interpret=True, block_u=4, block_v=4, block_m=8,
                layout=layout if with_layout else None)
        return f

    # sum-of-cell-sizes^2 tiles with the layout...
    analysis.audit(fwd(True), tx, rules=[analysis.SparseGrid(8)],
                   label="pairwise:sparse").raise_if_failed()
    # ...and the dense schedule launches (U/BU)^2, so the same rule must
    # flag it against the cell-driven expectation (positive control)
    dense_report = analysis.audit(fwd(False), tx,
                                  rules=[analysis.SparseGrid(8)],
                                  label="pairwise:dense")
    assert not dense_report.ok, "dense schedule passed the sparse-grid rule"
    analysis.audit(fwd(False), tx,
                   rules=[analysis.SparseGrid(layout.dense_n_tiles())],
                   label="pairwise:dense").raise_if_failed()

    # backward follows the same layout: every intra kernel in the grad
    # jaxpr (fwd + bwd) is tile-list sized, never (U/BU)^2
    def loss(t):
        i, x = ops.noma_pairwise_up(env, t, interpret=True, block_u=4,
                                    block_v=4, block_m=8, layout=layout)
        return jnp.sum(i) + jnp.sum(x)

    analysis.audit(jax.grad(loss), tx, rules=[analysis.SparseGrid(8)],
                   label="pairwise:grad").raise_if_failed()


def test_layout_block_mismatch_raises():
    """A layout built for a different user count is refused (silent wrong
    answers otherwise); its own blocks override the call's block args."""
    env, beta, p_up, _ = _case(12, 3, 4, seed=1)
    env2, *_ = _case(10, 3, 4, seed=1)
    layout = build_cell_layout(env, block_u=4, block_v=4)
    with pytest.raises(ValueError, match="built for U="):
        ops.noma_pairwise_up(env2, beta[:10] * p_up[:10, None],
                             interpret=True, layout=layout)
    # blocks come from the layout, not the (defaulted) call args
    i1, _ = ops.noma_pairwise_up(env, beta * p_up[:, None], interpret=True,
                                 layout=layout)
    i2, _ = ops.noma_pairwise_up(env, beta * p_up[:, None], interpret=True,
                                 block_u=4, block_v=4)
    _close(i1, i2)


def test_utility_grad_with_layout(small_env, weights):
    """The full paper-utility gradient (the GD hot-loop gradient) through
    utility(..., layout=) matches einsum -- the layout threads through
    delay_energy/user_rates without perturbing the math."""
    from repro.core import profiles
    from repro.core.types import GdVars
    from repro.core.utility import utility

    env = small_env
    u, m = env.n_users, env.n_sub
    layout = build_cell_layout(env, block_u=4, block_v=4)
    beta = jax.random.dirichlet(jax.random.PRNGKey(3), jnp.ones(m), (u,))
    v = GdVars(beta_up=beta, beta_dn=beta,
               p_up=jnp.full((u,), 0.1), p_dn=jnp.full((u,), 1.0),
               r=jnp.full((u,), 4.0))
    prof = profiles.nin()

    ge = jax.grad(lambda vv: utility(env, prof, jnp.int32(2), vv, weights,
                                     backend="einsum"))(v)
    gl = jax.grad(lambda vv: utility(env, prof, jnp.int32(2), vv, weights,
                                     backend="pallas_interpret",
                                     layout=layout))(v)
    for want, got in zip(jax.tree.leaves(ge), jax.tree.leaves(gl)):
        _close(got, want)
