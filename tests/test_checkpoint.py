"""repro.checkpoint hardening (PR 10): atomic rename-aside promotion (no
crash window in which the only copy is gone), stranded-aside recovery, and
meta.json/shard validation that raises SnapshotIntegrityError instead of
silently mis-unflattening."""
import json
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    SnapshotIntegrityError,
    leaf_crc32,
    list_steps,
    load_checkpoint,
    save_checkpoint,
)


def _tree(v=0.0):
    return {"w": jnp.arange(6.0).reshape(2, 3) + v,
            "b": jnp.zeros((3,), jnp.float32),
            "step": jnp.asarray(3, jnp.int32)}


class TestAtomicPromotion:
    def test_overwrite_same_step_keeps_newest(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, _tree(0.0))
        save_checkpoint(d, 1, _tree(5.0))      # exercises rename-aside
        out, step = load_checkpoint(d, _tree())
        assert step == 1
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(_tree(5.0)["w"]))
        assert not any(n.endswith(".aside") for n in os.listdir(d))

    def test_crash_between_renames_is_recovered(self, tmp_path):
        # Simulate dying after `final -> aside` but before `tmp -> final`:
        # the only copy lives under the aside name. The next reader must
        # rename it back rather than reporting no checkpoints.
        d = str(tmp_path)
        final = save_checkpoint(d, 2, _tree(1.0))
        os.rename(final, final + ".aside")
        assert not os.path.exists(final)
        out, step = load_checkpoint(d, _tree())    # triggers _recover
        assert step == 2
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(_tree(1.0)["w"]))

    def test_superseded_aside_is_discarded(self, tmp_path):
        # Crash after `tmp -> final` but before deleting the aside: the
        # final is the NEW copy; recovery must drop the stale aside, not
        # restore it over the new data.
        d = str(tmp_path)
        final = save_checkpoint(d, 3, _tree(2.0))
        shutil.copytree(final, final + ".aside")
        assert list_steps(d) == [3]
        assert not os.path.exists(final + ".aside")
        out, _ = load_checkpoint(d, _tree())
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(_tree(2.0)["w"]))

    def test_partial_names_never_parse_as_steps(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, _tree())
        os.makedirs(os.path.join(d, "tmp.9.0"))       # stranded tmp dir
        (tmp_path / "step_12").mkdir()                # not 8 digits
        (tmp_path / "step_00000002x").mkdir()         # trailing junk
        assert list_steps(d) == [1]


class TestValidation:
    def test_structure_mismatch(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        with pytest.raises(SnapshotIntegrityError, match="leaves|treedef"):
            load_checkpoint(str(tmp_path), {"w": jnp.zeros((2, 3))})

    def test_dtype_mismatch(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        bad = _tree()
        bad["b"] = jnp.zeros((3,), jnp.int32)
        with pytest.raises(SnapshotIntegrityError, match="leaf"):
            load_checkpoint(str(tmp_path), bad)

    def test_shape_mismatch(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        bad = _tree()
        bad["w"] = jnp.zeros((3, 2))
        with pytest.raises(SnapshotIntegrityError, match="leaf"):
            load_checkpoint(str(tmp_path), bad)

    def test_truncated_shard(self, tmp_path):
        final = save_checkpoint(str(tmp_path), 1, _tree())
        shard = os.path.join(final, "shard_0.npz")
        with open(shard, "rb") as f:
            data = f.read()
        with open(shard, "wb") as f:
            f.write(data[: len(data) // 2])
        with pytest.raises(SnapshotIntegrityError):
            load_checkpoint(str(tmp_path), _tree())

    def test_meta_crc_mismatch(self, tmp_path):
        final = save_checkpoint(str(tmp_path), 1, _tree())
        mpath = os.path.join(final, "meta.json")
        with open(mpath) as f:
            meta = json.load(f)
        meta["crc32s"][0] ^= 1
        with open(mpath, "w") as f:
            json.dump(meta, f)
        with pytest.raises(SnapshotIntegrityError, match="CRC"):
            load_checkpoint(str(tmp_path), _tree())

    def test_missing_meta(self, tmp_path):
        final = save_checkpoint(str(tmp_path), 1, _tree())
        os.remove(os.path.join(final, "meta.json"))
        with pytest.raises(SnapshotIntegrityError, match="meta.json"):
            load_checkpoint(str(tmp_path), _tree())

    def test_leaf_crc_is_content_only(self):
        a = np.arange(6, dtype=np.float32).reshape(2, 3)
        assert leaf_crc32(a) == leaf_crc32(np.asfortranarray(a))
        b = a.copy()
        b[0, 0] += 1
        assert leaf_crc32(a) != leaf_crc32(b)
