"""PlannerEngine: unified single-shot / batched / online warm-start planning,
plus the simplex-projection edge cases the solver relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GdConfig,
    make_env,
    make_weights,
    profiles,
    project_simplex_floor,
    solve,
)
from repro.planning import PlannerEngine, PlanState, stack_envs
from repro.scenarios import Scenario, ScenarioConfig


ADAM_CFG = GdConfig(step_size=1e-2, eps=1e-4, max_iters=400, optimizer="adam")


@pytest.fixture(scope="module")
def engine(weights, gd_cfg):
    return PlannerEngine(profiles.nin(), weights=weights, cfg=gd_cfg)


# -- simplex projection edge cases (floors) --------------------------------
def test_simplex_floor_row_below_floor():
    """A row entirely below the floor must be lifted onto the floored simplex."""
    floor = 0.05
    y = jnp.full((3, 4), -2.0)
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.sum(np.asarray(x), -1), 1.0, atol=1e-6)
    assert bool(jnp.all(x >= floor - 1e-6))
    # symmetric input -> uniform output
    np.testing.assert_allclose(np.asarray(x), 0.25, atol=1e-6)


def test_simplex_floor_tight_budget():
    """m * floor ~ 1: almost no slack, projection must pin every entry at
    (approximately) the floor without going negative or overshooting."""
    m, floor = 4, 0.2499
    y = jax.random.normal(jax.random.PRNGKey(0), (5, m)) * 10.0
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.sum(np.asarray(x), -1), 1.0, atol=1e-5)
    assert bool(jnp.all(x >= floor - 1e-6))
    assert bool(jnp.all(x <= floor + (1.0 - m * floor) + 1e-5))


def test_simplex_floor_exact_budget():
    """m * floor == 1 exactly: the floored simplex is the single point
    x = floor * ones."""
    m = 5
    floor = 1.0 / m
    y = jax.random.normal(jax.random.PRNGKey(1), (3, m)) * 3.0
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.asarray(x), floor, atol=1e-6)


# -- engine entry points ---------------------------------------------------
def test_engine_plan_matches_solve(small_env, weights, gd_cfg, engine):
    state = engine.plan(small_env)
    ref = solve(small_env, profiles.nin(), weights, gd_cfg)
    assert isinstance(state, PlanState)
    assert int(state.plan.s) == int(ref.s)
    assert float(state.plan.utility) == pytest.approx(float(ref.utility), abs=1e-6)
    # norms carry one optimum per split point for the next epoch's warm start
    assert state.norms["beta_up"].shape[0] == profiles.nin().n_layers + 1


def test_engine_plan_many_matches_sequential(weights, gd_cfg, engine):
    envs = [make_env(jax.random.PRNGKey(s), 8, 2, 4) for s in (0, 1, 2)]
    batched = engine.plan_many(envs)
    assert batched.plan.s.shape == (3,)
    for i, env in enumerate(envs):
        single = solve(env, profiles.nin(), weights, gd_cfg)
        assert int(batched.plan.s[i]) == int(single.s)
        assert float(batched.plan.utility[i]) == pytest.approx(
            float(single.utility), abs=1e-4)


def test_engine_plan_many_accepts_stacked(weights, gd_cfg, engine):
    envs = stack_envs([make_env(jax.random.PRNGKey(s), 8, 2, 4) for s in (3, 4)])
    out = engine.plan_many(envs)
    assert out.plan.s.shape == (2,)


def test_engine_cache_reuse(gd_cfg):
    eng = PlannerEngine(profiles.nin(), cfg=gd_cfg)  # weights derived per env
    e1 = make_env(jax.random.PRNGKey(0), 8, 2, 4)
    e2 = make_env(jax.random.PRNGKey(1), 8, 2, 4)
    eng.plan(e1)
    eng.plan(e2)
    assert eng.cache_size() == 1          # same shape -> one compiled program
    eng.plan(make_env(jax.random.PRNGKey(2), 6, 2, 3))
    assert eng.cache_size() == 2          # new shape -> new program


def test_replan_identical_env_warm_equivalence(small_env):
    """Warm-start replan on an unchanged env must not need more iterations
    than the fresh plan, and must land on an optimum at least as good."""
    w = make_weights(small_env.n_users)
    eng = PlannerEngine(profiles.nin(), weights=w, cfg=ADAM_CFG)
    fresh = eng.plan(small_env)
    warm = eng.replan(fresh, small_env)
    assert int(warm.total_iters) <= int(fresh.total_iters)
    assert float(warm.plan.utility) <= float(fresh.plan.utility) + 1e-4
    assert int(warm.plan.s) == int(fresh.plan.s)


def test_replan_none_falls_back_to_plan(small_env, weights, gd_cfg, engine):
    state = engine.replan(None, small_env)
    ref = engine.plan(small_env)
    assert int(state.plan.s) == int(ref.plan.s)
    assert float(state.plan.utility) == pytest.approx(float(ref.plan.utility),
                                                      abs=1e-6)


def test_online_episode_warm_beats_cold():
    """Acceptance: across a >= 10-epoch correlated-fading episode, online
    warm-start re-planning spends strictly fewer total GD iterations than
    cold re-planning, without giving up solution quality."""
    scfg = ScenarioConfig(n_users=8, n_aps=2, n_sub=4, fading_rho=0.995,
                          speed_mps=0.0, arrival_rate_hz=0.0)
    w = make_weights(scfg.n_users)
    prof = profiles.nin()
    warm_eng = PlannerEngine(prof, weights=w, cfg=ADAM_CFG)
    cold_eng = PlannerEngine(prof, weights=w, cfg=ADAM_CFG)
    sc = Scenario(scfg)
    state = None
    cold_total = warm_total = 0
    cold_util = warm_util = 0.0
    for t, env in enumerate(sc.episode(jax.random.PRNGKey(0), 12)):
        cold = cold_eng.plan(env)
        state = warm_eng.replan(state, env)
        if t >= 1:  # epoch 0 is cold for both
            cold_total += int(cold.total_iters)
            warm_total += int(state.total_iters)
            cold_util += float(cold.plan.utility)
            warm_util += float(state.plan.utility)
    assert warm_total < cold_total
    assert warm_util <= cold_util * 1.05


def test_engine_rejects_unknown_method():
    with pytest.raises(KeyError):
        PlannerEngine(profiles.nin(), method="newton")


# -- online serving hook ---------------------------------------------------
def test_online_split_server_replan_schedule(small_env):
    from repro.runtime.serve import OnlineSplitServer

    w = make_weights(small_env.n_users)
    eng = PlannerEngine(profiles.nin(), weights=w, cfg=ADAM_CFG)
    srv = OnlineSplitServer(eng, replan_every=2)
    scfg = ScenarioConfig(n_users=8, n_aps=2, n_sub=4, fading_rho=0.99,
                          speed_mps=0.0, arrival_rate_hz=0.0)
    sc = Scenario(scfg)
    for env in sc.episode(jax.random.PRNGKey(1), 5):
        srv.observe(env)
    assert srv.epoch == 5
    # replans at epochs 0, 2, 4; the first one must have re-cut
    assert srv.state is not None
    assert 1 <= srv.recuts <= 3
    assert srv.split_layer == int(srv.state.plan.s)
    assert srv.total_iters > 0
    with pytest.raises(ValueError):
        OnlineSplitServer(eng, replan_every=0)
