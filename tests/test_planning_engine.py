"""PlannerEngine: unified single-shot / batched / online warm-start planning,
plus the simplex-projection edge cases the solver relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GdConfig,
    make_env,
    make_weights,
    profiles,
    project_simplex_floor,
    solve,
)
from repro.planning import (
    PlannerEngine,
    PlanState,
    compile_log,
    member,
    stack_envs,
)
from repro.scenarios import Scenario, ScenarioConfig


ADAM_CFG = GdConfig(step_size=1e-2, eps=1e-4, max_iters=400, optimizer="adam")


@pytest.fixture(scope="module")
def engine(weights, gd_cfg):
    return PlannerEngine(profiles.nin(), weights=weights, cfg=gd_cfg)


# -- simplex projection edge cases (floors) --------------------------------
def test_simplex_floor_row_below_floor():
    """A row entirely below the floor must be lifted onto the floored simplex."""
    floor = 0.05
    y = jnp.full((3, 4), -2.0)
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.sum(np.asarray(x), -1), 1.0, atol=1e-6)
    assert bool(jnp.all(x >= floor - 1e-6))
    # symmetric input -> uniform output
    np.testing.assert_allclose(np.asarray(x), 0.25, atol=1e-6)


def test_simplex_floor_tight_budget():
    """m * floor ~ 1: almost no slack, projection must pin every entry at
    (approximately) the floor without going negative or overshooting."""
    m, floor = 4, 0.2499
    y = jax.random.normal(jax.random.PRNGKey(0), (5, m)) * 10.0
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.sum(np.asarray(x), -1), 1.0, atol=1e-5)
    assert bool(jnp.all(x >= floor - 1e-6))
    assert bool(jnp.all(x <= floor + (1.0 - m * floor) + 1e-5))


def test_simplex_floor_exact_budget():
    """m * floor == 1 exactly: the floored simplex is the single point
    x = floor * ones."""
    m = 5
    floor = 1.0 / m
    y = jax.random.normal(jax.random.PRNGKey(1), (3, m)) * 3.0
    x = project_simplex_floor(y, floor)
    np.testing.assert_allclose(np.asarray(x), floor, atol=1e-6)


def test_simplex_floor_infeasible_budget():
    """m * floor > 1 (Corollary 1's feasibility violated): the effective
    floor is clamped to 1/m, so the output stays on the simplex instead of
    silently summing to the negative residual budget."""
    m = 4
    for floor in (0.3, 1.0, 7.5):
        y = jax.random.normal(jax.random.PRNGKey(2), (6, m)) * 5.0
        x = project_simplex_floor(y, floor)
        np.testing.assert_allclose(np.sum(np.asarray(x), -1), 1.0, atol=1e-5)
        assert bool(jnp.all(x >= 0.0))
        np.testing.assert_allclose(np.asarray(x), 1.0 / m, atol=1e-5)


# -- engine entry points ---------------------------------------------------
def test_engine_plan_matches_solve(small_env, weights, gd_cfg, engine):
    state = engine.plan(small_env)
    ref = solve(small_env, profiles.nin(), weights, gd_cfg)
    assert isinstance(state, PlanState)
    assert int(state.plan.s) == int(ref.s)
    assert float(state.plan.utility) == pytest.approx(float(ref.utility), abs=1e-6)
    # norms carry one optimum per split point for the next epoch's warm start
    assert state.norms["beta_up"].shape[0] == profiles.nin().n_layers + 1


def test_engine_plan_many_matches_sequential(weights, gd_cfg, engine):
    envs = [make_env(jax.random.PRNGKey(s), 8, 2, 4) for s in (0, 1, 2)]
    batched = engine.plan_many(envs)
    assert batched.plan.s.shape == (3,)
    for i, env in enumerate(envs):
        single = solve(env, profiles.nin(), weights, gd_cfg)
        assert int(batched.plan.s[i]) == int(single.s)
        assert float(batched.plan.utility[i]) == pytest.approx(
            float(single.utility), abs=1e-4)


def test_engine_plan_many_accepts_stacked(weights, gd_cfg, engine):
    envs = stack_envs([make_env(jax.random.PRNGKey(s), 8, 2, 4) for s in (3, 4)])
    out = engine.plan_many(envs)
    assert out.plan.s.shape == (2,)


def test_engine_cache_reuse(gd_cfg):
    eng = PlannerEngine(profiles.nin(), cfg=gd_cfg)  # weights derived per env
    e1 = make_env(jax.random.PRNGKey(0), 8, 2, 4)
    e2 = make_env(jax.random.PRNGKey(1), 8, 2, 4)
    eng.plan(e1)
    eng.plan(e2)
    assert eng.cache_size() == 1          # same shape -> one compiled program
    eng.plan(make_env(jax.random.PRNGKey(2), 6, 2, 3))
    assert eng.cache_size() == 2          # new shape -> new program


def test_engine_pallas_backend_matches_einsum_plan(small_env, weights):
    """Acceptance: PlannerEngine(sinr_backend='pallas').plan(env) returns the
    same split/allocation as the einsum engine on a small env ('pallas'
    resolves to interpret mode on CPU)."""
    cfg = GdConfig(max_iters=40, optimizer="adam")
    e_ein = PlannerEngine(profiles.nin(), weights=weights, cfg=cfg)
    e_pal = PlannerEngine(profiles.nin(), weights=weights, cfg=cfg,
                          sinr_backend="pallas")
    assert e_ein.sinr_backend == "einsum" and e_pal.sinr_backend == "pallas"
    s1 = e_ein.plan(small_env)
    s2 = e_pal.plan(small_env)
    assert int(s1.plan.s) == int(s2.plan.s)
    np.testing.assert_array_equal(np.asarray(s1.plan.sub_up),
                                  np.asarray(s2.plan.sub_up))
    np.testing.assert_array_equal(np.asarray(s1.plan.sub_dn),
                                  np.asarray(s2.plan.sub_dn))
    for a, b in ((s1.plan.p_up, s2.plan.p_up), (s1.plan.p_dn, s2.plan.p_dn),
                 (s1.plan.r, s2.plan.r)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-3,
                                   atol=1e-4)
    np.testing.assert_allclose(float(s2.plan.utility), float(s1.plan.utility),
                               rtol=1e-4)


def test_engine_pallas_backend_fleet_paths(weights):
    """The custom_vjp'd pallas_call must stay batchable: plan_many and
    replan_many (the vmapped fleet paths) with sinr_backend='pallas' agree
    with the einsum fleet programs per member."""
    cfg = GdConfig(max_iters=25, optimizer="adam")
    e_pal = PlannerEngine(profiles.nin(), weights=weights, cfg=cfg,
                          sinr_backend="pallas")
    e_ein = PlannerEngine(profiles.nin(), weights=weights, cfg=cfg)
    envs = stack_envs([make_env(jax.random.PRNGKey(s), 8, 2, 4)
                       for s in (0, 1)])
    sp = e_pal.plan_many(envs)
    se = e_ein.plan_many(envs)
    np.testing.assert_array_equal(np.asarray(sp.plan.s), np.asarray(se.plan.s))
    np.testing.assert_allclose(np.asarray(sp.plan.utility),
                               np.asarray(se.plan.utility), rtol=1e-4)
    envs2 = stack_envs([make_env(jax.random.PRNGKey(s), 8, 2, 4)
                        for s in (2, 3)])
    rp = e_pal.replan_many(sp, envs2)
    re = e_ein.replan_many(se, envs2)
    np.testing.assert_array_equal(np.asarray(rp.plan.s), np.asarray(re.plan.s))
    np.testing.assert_allclose(np.asarray(rp.plan.utility),
                               np.asarray(re.plan.utility), rtol=1e-4)


def test_engine_backend_cache_keys(small_env, weights):
    """Compiled programs keep the backend they were traced with: flipping
    the channel-module global must neither retrace nor change a cached
    engine program's results, while a differing engine backend mints a new
    cache key instead of mutating the live one."""
    import dataclasses

    from repro.core import channel

    cfg = GdConfig(max_iters=25, optimizer="adam")
    eng = PlannerEngine(profiles.nin(), weights=weights, cfg=cfg)
    ref = eng.plan(small_env)
    assert eng.cache_size() == 1
    prev = channel.set_sinr_backend("pallas_interpret")
    try:
        again = eng.plan(small_env)
    finally:
        channel.set_sinr_backend(prev)
    assert eng.cache_size() == 1          # global switch: no new program
    np.testing.assert_allclose(float(again.plan.utility),
                               float(ref.plan.utility))
    # a different engine backend is a different cache key (cfg is in the key)
    eng.cfg = dataclasses.replace(cfg, sinr_backend="pallas_interpret")
    pal = eng.plan(small_env)
    assert eng.cache_size() == 2
    np.testing.assert_allclose(float(pal.plan.utility),
                               float(ref.plan.utility), rtol=1e-4)
    with pytest.raises(ValueError, match="sinr_backend"):
        PlannerEngine(profiles.nin(), cfg=cfg, sinr_backend="cuda")


def test_replan_identical_env_warm_equivalence(small_env):
    """Warm-start replan on an unchanged env must not need more iterations
    than the fresh plan, and must land on an optimum at least as good."""
    w = make_weights(small_env.n_users)
    eng = PlannerEngine(profiles.nin(), weights=w, cfg=ADAM_CFG)
    fresh = eng.plan(small_env)
    warm = eng.replan(fresh, small_env)
    assert int(warm.total_iters) <= int(fresh.total_iters)
    assert float(warm.plan.utility) <= float(fresh.plan.utility) + 1e-4
    assert int(warm.plan.s) == int(fresh.plan.s)


def test_replan_none_falls_back_to_plan(small_env, weights, gd_cfg, engine):
    state = engine.replan(None, small_env)
    ref = engine.plan(small_env)
    assert int(state.plan.s) == int(ref.plan.s)
    assert float(state.plan.utility) == pytest.approx(float(ref.plan.utility),
                                                      abs=1e-6)


@pytest.mark.slow
def test_online_episode_warm_beats_cold():
    """Acceptance: across a >= 10-epoch correlated-fading episode, online
    warm-start re-planning spends strictly fewer total GD iterations than
    cold re-planning, without giving up solution quality. (slow: 12-epoch
    episode solved twice.)"""
    scfg = ScenarioConfig(n_users=8, n_aps=2, n_sub=4, fading_rho=0.995,
                          speed_mps=0.0, arrival_rate_hz=0.0)
    w = make_weights(scfg.n_users)
    prof = profiles.nin()
    warm_eng = PlannerEngine(prof, weights=w, cfg=ADAM_CFG)
    cold_eng = PlannerEngine(prof, weights=w, cfg=ADAM_CFG)
    sc = Scenario(scfg)
    state = None
    cold_total = warm_total = 0
    cold_util = warm_util = 0.0
    for t, env in enumerate(sc.episode(jax.random.PRNGKey(0), 12)):
        cold = cold_eng.plan(env)
        state = warm_eng.replan(state, env)
        if t >= 1:  # epoch 0 is cold for both
            cold_total += int(cold.total_iters)
            warm_total += int(state.total_iters)
            cold_util += float(cold.plan.utility)
            warm_util += float(state.plan.utility)
    assert warm_total < cold_total
    assert warm_util <= cold_util * 1.05


@pytest.mark.slow
def test_replan_warm_vs_cold_regression_rho095():
    """Regression for the PR 1 warm-start defect: at rho = 0.95 (below the
    old ~0.99 break-even) warm replan must still spend no more GD iterations
    than cold re-planning, at equal-or-better utility. (slow: 6-epoch episode
    solved twice; the benchmark --quick smoke covers the same property.)"""
    scfg = ScenarioConfig(n_users=8, n_aps=2, n_sub=4, fading_rho=0.95,
                          speed_mps=0.0, arrival_rate_hz=0.0)
    w = make_weights(scfg.n_users)
    prof = profiles.nin()
    warm_eng = PlannerEngine(prof, weights=w, cfg=ADAM_CFG)
    cold_eng = PlannerEngine(prof, weights=w, cfg=ADAM_CFG)
    sc = Scenario(scfg)
    state = None
    cold_total = warm_total = 0
    cold_util = warm_util = 0.0
    for t, env in enumerate(sc.episode(jax.random.PRNGKey(1), 6)):
        cold = cold_eng.plan(env)
        state = warm_eng.replan(state, env)
        if t >= 1:  # epoch 0 is cold for both
            cold_total += int(cold.total_iters)
            warm_total += int(state.total_iters)
            cold_util += float(cold.plan.utility)
            warm_util += float(state.plan.utility)
    assert warm_total <= cold_total, (warm_total, cold_total)
    assert warm_util <= cold_util + 1e-3, (warm_util, cold_util)
    # and the warm engine must actually have used its temporal state
    assert warm_total < cold_total


@pytest.mark.slow
def test_replan_many_matches_sequential():
    """Batched warm-start replan over a stacked fleet == per-scenario
    sequential replan, epoch by epoch (same s*, utility, and iteration
    counts). (slow: compiles both the fleet and per-member programs.)"""
    scfg = ScenarioConfig(n_users=8, n_aps=2, n_sub=4, fading_rho=0.97,
                          speed_mps=0.0, arrival_rate_hz=0.0)
    fleet = 8
    w = make_weights(scfg.n_users)
    prof = profiles.nin()
    fleet_eng = PlannerEngine(prof, weights=w, cfg=ADAM_CFG)
    seq_eng = PlannerEngine(prof, weights=w, cfg=ADAM_CFG)
    sc = Scenario(scfg)
    states = sc.init_many(jax.random.split(jax.random.PRNGKey(4), fleet))
    batched, seq = None, [None] * fleet
    for t in range(3):
        envs = sc.env_many(states)
        batched = fleet_eng.replan_many(batched, envs)
        assert batched.plan.s.shape == (fleet,)
        for i in range(fleet):
            seq[i] = seq_eng.replan(seq[i], member(envs, i))
            assert int(batched.plan.s[i]) == int(seq[i].plan.s), (t, i)
            assert int(batched.total_iters[i]) == int(seq[i].total_iters), (t, i)
            assert float(batched.plan.utility[i]) == pytest.approx(
                float(seq[i].plan.utility), abs=1e-4), (t, i)
        states = sc.step_many(jax.random.split(jax.random.PRNGKey(100 + t),
                                               fleet), states)


def test_replan_many_none_and_shape_checks():
    prof = profiles.nin()
    eng = PlannerEngine(prof, cfg=ADAM_CFG)
    envs = stack_envs([make_env(jax.random.PRNGKey(s), 8, 2, 4) for s in range(2)])
    state = eng.replan_many(None, envs)          # falls back to plan_many
    assert state.plan.s.shape == (2,)
    bad = stack_envs([make_env(jax.random.PRNGKey(9), 6, 2, 4) for _ in range(2)])
    with pytest.raises(ValueError):
        eng.replan_many(state, bad)
    with pytest.raises(ValueError):
        eng.replan_many(state, [])


def test_shape_guard_batched_vs_single_states(small_env):
    """The guards must read the network shape off the right trailing dims
    for both state layouts: a fleet state handed to replan() (and a single
    state handed to replan_many()) is told exactly what to use instead --
    not given a garbled (U, M) mismatch from misread leading dims."""
    from repro.planning import WarmStateShapeError

    eng = PlannerEngine(profiles.nin(), cfg=ADAM_CFG)
    envs = stack_envs([make_env(jax.random.PRNGKey(s), 8, 2, 4) for s in range(2)])
    fleet_state = eng.plan_many(envs)
    single_state = eng.plan(small_env)
    with pytest.raises(WarmStateShapeError, match="replan_many"):
        eng.replan(fleet_state, small_env)
    with pytest.raises(WarmStateShapeError, match="plan_many|replan\\(\\)"):
        eng.replan_many(single_state, envs)
    # fleet size mismatch: 2-member state vs 3-member envs
    envs3 = stack_envs([make_env(jax.random.PRNGKey(s), 8, 2, 4)
                        for s in (5, 6, 7)])
    with pytest.raises(WarmStateShapeError, match="fleet of 2"):
        eng.replan_many(fleet_state, envs3)
    # single-scenario (U, M) mismatch keeps its message
    with pytest.raises(WarmStateShapeError, match="users"):
        eng.replan(single_state, make_env(jax.random.PRNGKey(3), 6, 2, 4))
    # an unbatched env is told to use replan()/plan(), not misread
    with pytest.raises(WarmStateShapeError, match="use replan\\(\\)"):
        eng.replan_many(fleet_state, small_env)
    with pytest.raises(ValueError, match="use plan\\(\\)"):
        eng.plan_many(small_env)


@pytest.fixture(scope="module")
def adam_engine(weights):
    return PlannerEngine(profiles.nin(), weights=weights, cfg=ADAM_CFG)


def test_replan_exposes_in_jit_rho_estimate(small_env, adam_engine):
    """PlanState.warm_rho is the gate's traced correlation estimate: None
    from a cold plan, ~1 when the env repeats, and low for a fresh draw."""
    eng = adam_engine
    fresh = eng.plan(small_env)
    assert fresh.warm_rho is None
    warm = eng.replan(fresh, small_env)
    assert float(warm.warm_rho) == pytest.approx(1.0, abs=1e-5)
    other = eng.replan(fresh, make_env(jax.random.PRNGKey(11), 8, 2, 4))
    assert 0.0 <= float(other.warm_rho) < 1.0


def test_replan_dispatch_no_host_transfer(small_env, adam_engine):
    """Acceptance: replan and replan_many enqueue with zero host-side numpy
    -- the rho gate, moment decay, and warm payload are all device ops, so
    dispatch survives jax.transfer_guard('disallow') once compiled."""
    eng = adam_engine
    # make_env leaves the radio/comp constants as python floats; a device-
    # resident pipeline (Scenario.env_many is jitted) has them on device
    # already, so place them once before the guarded dispatch.
    env2 = jax.device_put(make_env(jax.random.PRNGKey(21), 8, 2, 4))
    state = eng.replan(eng.plan(small_env), jax.device_put(small_env))
    envs = stack_envs([small_env, env2])
    fleet = eng.replan_many(eng.plan_many(envs), envs)
    jax.block_until_ready((state, fleet))
    with jax.transfer_guard("disallow"):
        state2 = eng.replan(state, env2)
        fleet2 = eng.replan_many(fleet, envs)
    jax.block_until_ready((state2, fleet2))
    assert float(state2.warm_rho) >= 0.0
    assert fleet2.warm_rho.shape == (2,)


def test_replan_rho_threshold_one_equals_cold(small_env):
    """warm_rho_min=1.0: the correlation estimate is (almost surely) below
    threshold, so replan runs the exact cold Li-GD chain -- same split, same
    utility, same iteration count as a fresh plan()."""
    w = make_weights(small_env.n_users)
    eng = PlannerEngine(profiles.nin(), weights=w, cfg=ADAM_CFG,
                        warm_rho_min=1.0)
    first = eng.plan(small_env)
    env2 = make_env(jax.random.PRNGKey(42), 8, 2, 4)  # uncorrelated draw
    warm = eng.replan(first, env2)
    ref = eng.plan(env2)
    assert int(warm.total_iters) == int(ref.total_iters)
    assert int(warm.plan.s) == int(ref.plan.s)
    assert float(warm.plan.utility) == pytest.approx(float(ref.plan.utility),
                                                     abs=1e-6)


def test_gate_retune_recompiles(small_env):
    """warm_rho_min is a trace-time constant of the compiled replan program,
    so retuning it on a live engine must compile a fresh program (cache key)
    and actually change the gate -- not silently keep the old threshold."""
    w = make_weights(small_env.n_users)
    eng = PlannerEngine(profiles.nin(), weights=w, cfg=ADAM_CFG,
                        warm_rho_min=0.0)
    first = eng.plan(small_env)
    env2 = make_env(jax.random.PRNGKey(42), 8, 2, 4)  # uncorrelated draw
    eng.replan(first, env2)                           # gate open at 0.0
    n = eng.cache_size()
    eng.warm_rho_min = 1.0
    gated = eng.replan(first, env2)
    assert eng.cache_size() == n + 1
    # threshold 1.0 now gates the stale start off: exact cold Li-GD chain
    ref = eng.plan(env2)
    assert int(gated.total_iters) == int(ref.total_iters)
    assert float(gated.plan.utility) == pytest.approx(
        float(ref.plan.utility), abs=1e-6)


def test_engine_rejects_unknown_method():
    with pytest.raises(KeyError):
        PlannerEngine(profiles.nin(), method="newton")
    with pytest.raises(ValueError):
        PlannerEngine(profiles.nin(), warm_rho_min=1.5)
    with pytest.raises(ValueError):
        PlannerEngine(profiles.nin(), warm_moment_decay=-0.1)


# -- online serving hook ---------------------------------------------------
def test_online_split_server_replan_schedule(small_env):
    from repro.runtime.serve import OnlineSplitServer

    w = make_weights(small_env.n_users)
    eng = PlannerEngine(profiles.nin(), weights=w, cfg=ADAM_CFG)
    srv = OnlineSplitServer(eng, replan_every=2)
    scfg = ScenarioConfig(n_users=8, n_aps=2, n_sub=4, fading_rho=0.99,
                          speed_mps=0.0, arrival_rate_hz=0.0)
    sc = Scenario(scfg)
    for env in sc.episode(jax.random.PRNGKey(1), 5):
        srv.observe(env)
    assert srv.epoch == 5
    # replans at epochs 0, 2, 4; the first one must have re-cut
    assert srv.state is not None
    assert 1 <= srv.recuts <= 3
    assert srv.split_layer == int(srv.state.plan.s)
    assert srv.total_iters > 0
    with pytest.raises(ValueError):
        OnlineSplitServer(eng, replan_every=0)


def test_online_split_server_shape_change_resets_cold(small_env):
    """A network shape change mid-serve (user churn beyond slot replacement)
    must not raise: observe() resets the warm state and re-plans cold, as the
    engine docstring promises."""
    from repro.runtime.serve import OnlineSplitServer

    eng = PlannerEngine(profiles.nin(), cfg=ADAM_CFG)
    srv = OnlineSplitServer(eng, replan_every=1)
    srv.observe(small_env)                                  # (8, 2, 4)
    assert srv.cold_resets == 0
    grown = make_env(jax.random.PRNGKey(5), 10, 2, 4)       # U changed
    srv.observe(grown)                                      # must not raise
    assert srv.cold_resets == 1
    assert srv.state is not None
    assert srv.state.norms["beta_up"].shape[1:] == (10, 4)
    srv.observe(make_env(jax.random.PRNGKey(6), 10, 2, 4))  # warm again
    assert srv.cold_resets == 1
    assert srv.epoch == 3
    # the metrics() view agrees with the attribute counters and carries the
    # control-plane totals the online loop reports
    m = srv.metrics()
    assert m["cold_resets"] == 1 and m["epoch"] == 3
    assert m["replans"] == 3 and m["forced_replans"] == 0
    assert m["split_layer"] == int(srv.state.plan.s)
    assert m["total_iters"] == srv.total_iters > 0


def test_online_split_server_forced_and_measured_replans(small_env):
    """QoS-forced replans run off-schedule and are counted separately; a
    measured profile (ModelProfile.like) reuses the compiled replan program;
    an incompatible profile raises ProfileShapeError before dispatch."""
    import dataclasses

    from repro.core.types import ProfileShapeError
    from repro.runtime.serve import OnlineSplitServer

    prof = profiles.nin()
    eng = PlannerEngine(prof, cfg=ADAM_CFG)
    srv = OnlineSplitServer(eng, replan_every=4)
    srv.observe(small_env)                        # epoch 0: scheduled
    srv.observe(small_env)                        # epoch 1: no replan
    assert srv.metrics()["replans"] == 1
    srv.observe(small_env, force=True)            # epoch 2: forced (traces)
    measured = prof.like(prof.fl * 2.0, prof.w, prof.m_down)
    with compile_log() as log:
        srv.observe(small_env, prof=measured, force=True)  # epoch 3: forced
    assert log == []                              # same compiled program
    m = srv.metrics()
    assert m["replans"] == 3 and m["forced_replans"] == 2
    bad = dataclasses.replace(prof, fl=prof.fl[:-1])
    with pytest.raises(ProfileShapeError):
        srv.observe(small_env, prof=bad, force=True)
