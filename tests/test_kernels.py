"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel, make_env
from repro.kernels import ops, ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,sk,h,kv,hd,causal,window",
    [
        (1, 32, 32, 4, 4, 32, True, 0),
        (2, 64, 64, 4, 2, 32, True, 0),
        (2, 48, 48, 8, 1, 64, True, 0),       # MQA, non-multiple seq (pads)
        (1, 64, 64, 4, 2, 32, False, 0),      # bidirectional
        (1, 64, 64, 4, 4, 32, True, 16),      # local window
        (1, 8, 64, 4, 2, 32, True, 0),        # short q vs long kv (decode-ish)
    ],
)
def test_flash_attention_sweep(dtype, b, sq, sk, h, kv, hd, causal, window):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, sq, h, hd)).astype(dtype)
    k = jax.random.normal(keys[1], (b, sk, kv, hd)).astype(dtype)
    v = jax.random.normal(keys[2], (b, sk, kv, hd)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=16, block_k=16, interpret=True)
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    r = ref.flash_attention_ref(qf, kf, vf, group=g, causal=causal,
                                window=window)
    r = r.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(r, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize(
    "b,s,w,bs,bw,with_h0",
    [
        (1, 32, 64, 8, 32, False),
        (2, 40, 96, 16, 32, True),     # non-multiple seq (pads)
        (3, 128, 128, 64, 128, True),
        (2, 16, 200, 16, 128, False),  # width pads
    ],
)
def test_rg_lru_sweep(b, s, w, bs, bw, with_h0):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    log_a = -jnp.abs(jax.random.normal(keys[0], (b, s, w)))
    bb = jax.random.normal(keys[1], (b, s, w))
    h0 = jax.random.normal(keys[2], (b, w)) if with_h0 else None
    out = ops.rg_lru(log_a, bb, h0, interpret=True, block_s=bs, block_w=bw)
    r = ref.rg_lru_ref(log_a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize(
    "u,n,m,bu,bm",
    [
        (6, 2, 4, 4, 4),
        (10, 3, 6, 4, 8),    # non-divisible users + subchannels
        (16, 4, 8, 8, 8),
        (9, 2, 12, 8, 8),    # non-divisible M too (12 % 8 != 0)
    ],
)
def test_noma_rates_sweep(u, n, m, bu, bm):
    env = make_env(jax.random.PRNGKey(2), n_users=u, n_aps=n, n_sub=m)
    key = jax.random.PRNGKey(3)
    beta = jax.random.dirichlet(key, jnp.ones(m), (u,))
    p = jax.random.uniform(jax.random.PRNGKey(4), (u,), minval=0.01, maxval=0.3)
    out = ops.noma_uplink_rates(env, beta, p, interpret=True,
                                block_u=bu, block_v=bu, block_m=bm)
    r = channel.uplink_rates(env, beta, p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-5,
                               atol=1e-3)


@pytest.mark.parametrize("bu,bv", [(8, 16), (16, 8)])
def test_noma_rates_mismatched_blocks(bu, bv):
    """Receiver (U) and interferer (V) tiles are padded independently: with
    U=20, block_u=8 pads the receiver axis to 24, which a block_v=16 grid
    cannot tile -- the regression this guards produced NaN/garbage whenever
    block_v != block_u."""
    u, n, m = 20, 3, 6
    env = make_env(jax.random.PRNGKey(7), n_users=u, n_aps=n, n_sub=m)
    beta = jax.random.dirichlet(jax.random.PRNGKey(8), jnp.ones(m), (u,))
    p = jax.random.uniform(jax.random.PRNGKey(9), (u,), minval=0.01, maxval=0.3)
    out = ops.noma_uplink_rates(env, beta, p, interpret=True,
                                block_u=bu, block_v=bv, block_m=8)
    r = channel.uplink_rates(env, beta, p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-5,
                               atol=1e-3)


@pytest.mark.parametrize("bu,bv", [(8, 16), (16, 8)])
def test_noma_pairwise_dn_mismatched_blocks(bu, bv):
    """Downlink decomposition under block_u != block_v matches the einsum
    reference end-to-end (SINR level)."""
    u, n, m = 20, 3, 6
    env = make_env(jax.random.PRNGKey(10), n_users=u, n_aps=n, n_sub=m)
    beta = jax.random.dirichlet(jax.random.PRNGKey(11), jnp.ones(m), (u,))
    p = jax.random.uniform(jax.random.PRNGKey(12), (u,), minval=0.1, maxval=10.0)
    ref_sinr = channel.downlink_sinr(env, beta, p, backend="einsum")
    intra, inter = ops.noma_pairwise_dn(env, beta * p[:, None], interpret=True,
                                        block_u=bu, block_v=bv, block_m=8)
    own = env.own_gain_dn()
    ker_sinr = p[:, None] * own / (intra * own + inter + env.noise_dn)
    np.testing.assert_allclose(np.asarray(ker_sinr), np.asarray(ref_sinr),
                               rtol=1e-5, atol=1e-5 * float(np.max(ref_sinr)))


def test_noma_pairwise_oracle_matches_channel_decomposition(small_env):
    """The kernel's (intra, inter) decomposition reproduces uplink_sinr."""
    env = small_env
    u, m = env.n_users, env.n_sub
    beta = jnp.ones((u, m)) / m
    p = jnp.full((u,), 0.2)
    own = env.own_gain_up().astype(jnp.float32)
    tx = beta * p[:, None]
    g_vu = env.g_up[:, env.ap, :].astype(jnp.float32)
    same = env.same_cell()
    intra, inter = ref.noma_pairwise_ref(own, own, tx * own, tx, g_vu, same,
                                         descending=True)
    sinr = p[:, None] * own / (intra + inter + env.noise_up)
    np.testing.assert_allclose(
        np.asarray(sinr), np.asarray(channel.uplink_sinr(env, beta, p)),
        rtol=1e-4,
    )


def _gather_free_case(u, n, m, seed=0):
    env = make_env(jax.random.PRNGKey(seed), n_users=u, n_aps=n, n_sub=m)
    beta = jax.random.dirichlet(jax.random.PRNGKey(seed + 1), jnp.ones(m), (u,))
    p = jax.random.uniform(jax.random.PRNGKey(seed + 2), (u,),
                           minval=0.01, maxval=0.3)
    tx = (beta * p[:, None]).astype(jnp.float32)
    own_up = env.own_gain_up().astype(jnp.float32)
    own_dn = env.own_gain_dn().astype(jnp.float32)
    return env, tx, own_up, own_dn


@pytest.mark.parametrize("u,n,m,bu,bv,bm,bn", [
    (10, 3, 6, 4, 8, 8, 2),    # non-divisible U/V/M, mismatched block_u/block_v
    (20, 3, 6, 16, 8, 8, 8),   # block_n > n_aps (clamped in-kernel)
    (13, 5, 7, 8, 4, 128, 4),  # non-divisible N too (5 % 4 != 0)
    (12, 13, 6, 8, 8, 8, 8),   # non-divisible N at block 8 (13 % 8 != 0)
])
@pytest.mark.parametrize("uplink", [True, False])
@pytest.mark.parametrize("descending", [True, False])
@pytest.mark.parametrize("ap_mode", ["iota", "onehot"])
def test_noma_gather_free_parity(u, n, m, bu, bv, bm, bn, uplink, descending,
                                 ap_mode):
    """The gather-free cell-block kernels (raw gains + int32 AP ids in, AP
    selection and same_cell derived in-kernel, N-tiled accumulators) match
    BOTH oracles at 1e-5: the old gathered-kernel reference (explicit
    g_vu = g[*, ap, *] + same mask -- the math the pre-gather kernel
    computed) and the gather-free reference, for both links, both SIC
    orders, and both AP-structure modes -- including N not divisible by
    block_n, where boundary N blocks are iota-masked."""
    from repro.kernels.noma_rates import noma_pairwise_kernel

    env, tx, own_up, own_dn = _gather_free_case(u, n, m, seed=u + n)
    own = own_up if uplink else own_dn
    g_raw = (env.g_up if uplink else env.g_dn).astype(jnp.float32)
    w_intra = tx * own if uplink else tx

    ki, kx = noma_pairwise_kernel(own, own, w_intra, tx, g_raw, env.ap,
                                  env.ap, descending=descending,
                                  uplink=uplink, block_u=bu, block_v=bv,
                                  block_m=bm, block_n=bn, ap_mode=ap_mode,
                                  interpret=True)
    gi, gx = ref.noma_pairwise_gather_free_ref(own, own, w_intra, tx, g_raw,
                                               env.ap, descending=descending,
                                               uplink=uplink)
    g_vu = (env.g_up[:, env.ap, :] if uplink
            else env.g_dn[env.ap, :, :]).astype(jnp.float32)
    oi, ox = ref.noma_pairwise_ref(own, own, w_intra, tx, g_vu,
                                   env.same_cell(), descending=descending)
    for got, want in ((ki, gi), (kx, gx), (ki, oi), (kx, ox)):
        got, want = np.asarray(got), np.asarray(want)
        np.testing.assert_allclose(
            got, want, rtol=1e-5, atol=1e-5 * max(np.abs(want).max(), 1e-30))


@pytest.mark.parametrize("uplink", [True, False])
@pytest.mark.parametrize("ap_mode", ["iota", "onehot"])
def test_noma_gather_free_single_cell_inter_is_exactly_zero(uplink, ap_mode):
    """N=1: every user shares the one AP, so the inter-cell term must be
    EXACTLY zero (the in-kernel other-cell mask is identically false),
    not merely small."""
    from repro.kernels.noma_rates import noma_pairwise_kernel

    env, tx, own_up, own_dn = _gather_free_case(9, 1, 12, seed=3)
    own = own_up if uplink else own_dn
    g_raw = (env.g_up if uplink else env.g_dn).astype(jnp.float32)
    w_intra = tx * own if uplink else tx
    _, inter = noma_pairwise_kernel(own, own, w_intra, tx, g_raw, env.ap,
                                    env.ap, descending=uplink, uplink=uplink,
                                    block_u=8, block_v=8, block_m=8,
                                    ap_mode=ap_mode, interpret=True)
    np.testing.assert_array_equal(np.asarray(inter), 0.0)


def test_autotune_candidates_fit_vmem_ceiling():
    """Every (BU, BV, BM, BN) configuration the kernel_bench autotuner is
    allowed to pick stays under the 16 MB VMEM ceiling -- for both
    directions and both links, and INDEPENDENT of the total AP count: the
    budget at n_aps=4096 must equal the budget at n_aps=16 (the N-tiled
    accumulators are (BN, BM) blocks, so n_aps only clamps BN)."""
    from repro.kernels.noma_rates import (AUTOTUNE_BLOCKS,
                                          VMEM_CEILING_BYTES,
                                          vmem_block_bytes)

    for bu, bv, bm, bn in AUTOTUNE_BLOCKS:
        budgets = {}
        for n_aps in (16, 1024, 4096):
            for direction in ("fwd", "bwd"):
                for uplink in (True, False):
                    b = vmem_block_bytes(bu, bv, bm, bn, n_aps=n_aps,
                                         direction=direction, uplink=uplink)
                    assert b < VMEM_CEILING_BYTES, (
                        (bu, bv, bm, bn), n_aps, direction, uplink, b)
                    budgets.setdefault((direction, uplink), set()).add(b)
        for key, vals in budgets.items():
            assert len(vals) == 1, ((bu, bv, bm, bn), key, vals)
