"""Per-arch smoke tests (reduced configs): forward/train shapes, no NaNs,
prefill+decode == parallel forward, MoE sorted == dense under ample capacity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model

ARCH_NAMES = configs.all_names()


def _batch(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frontend"] = 0.1 * jax.random.normal(
            key, (B, 24, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        batch["frontend"] = 0.1 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward(name):
    cfg = configs.get(name).reduced()
    m = Model(cfg, remat=False, moe_capacity=8.0)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, _, aux = m.train_logits(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, m.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_train_step_smoke(name):
    """One SGD step on the reduced config: loss finite and decreasing-ish."""
    cfg = configs.get(name).reduced()
    m = Model(cfg, remat=True, moe_capacity=8.0)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1), B=2, S=16)
    tgt = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, _, aux = m.train_logits(p, batch)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], -1).mean()
        return nll + 0.01 * aux

    l0, g = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    l1 = loss_fn(params2)
    assert bool(jnp.isfinite(l1))
    assert float(l1) < float(l0) + 0.05  # a gradient step shouldn't blow up


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_decode_matches_forward(name):
    cfg = configs.get(name).reduced()
    # ample capacity -> no token drops -> decode must match parallel forward
    m = Model(cfg, remat=False, moe_capacity=16.0)
    params = m.init(jax.random.PRNGKey(0))
    B, S, k = 2, 16, 4
    batch = _batch(cfg, jax.random.PRNGKey(1), B=B, S=S)
    toks = batch["tokens"]
    full, _, _ = m.train_logits(params, batch)
    pre = dict(batch, tokens=toks[:, : S - k])
    logits, caches = m.prefill(params, pre, max_len=S + 8)
    scale = float(jnp.max(jnp.abs(full)))
    tol = 0.05 * max(1.0, scale)
    if cfg.top_k == 1:
        # top-1 routing is discontinuous: a bf16-level logit difference
        # between the decode path (single-pass softmax) and the parallel
        # path (online softmax) can flip an expert. Bounded, not a bug.
        tol *= 3.0
    assert float(jnp.max(jnp.abs(logits - full[:, S - k - 1]))) < tol
    for i in range(k):
        logits, caches = m.decode_step(params, caches, toks[:, S - k + i : S - k + i + 1])
        err = float(jnp.max(jnp.abs(logits - full[:, S - k + i])))
        assert err < tol, (name, i, err)


def test_moe_sorted_matches_dense():
    cfg = configs.get("deepseek-moe-16b").reduced()
    from repro.models import moe as moe_mod
    from repro.models.layers import init_params
    key = jax.random.PRNGKey(0)
    p = init_params(moe_mod.moe_defs(cfg), key)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)
                                ).astype(jnp.bfloat16)
    y_sorted, aux_s = moe_mod.moe_apply(p, x, cfg, impl="sorted",
                                        capacity_factor=float(cfg.n_experts))
    y_dense, aux_d = moe_mod.moe_apply(p, x, cfg, impl="dense")
    np.testing.assert_allclose(np.asarray(y_sorted, np.float32),
                               np.asarray(y_dense, np.float32),
                               atol=0.03, rtol=0.05)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_stage_lists():
    assert [s.kind for s in Model(configs.get("qwen2-1.5b")).stages] == ["attn"]
    rg = Model(configs.get("recurrentgemma-9b")).stages
    assert sum(s.n_layers for s in rg) == 38
    assert rg[0].kind == "rec" and rg[0].n_layers == 2
    xl = Model(configs.get("xlstm-125m")).stages
    assert sum(s.n_layers for s in xl) == 12
    assert {s.kind for s in xl} == {"mlstm", "slstm"}
    vl = Model(configs.get("llama-3.2-vision-11b")).stages
    assert sum(s.n_layers for s in vl) == 40
    assert sum(s.n_layers for s in vl if s.kind == "cross") == 8
    ws = Model(configs.get("whisper-small")).stages
    assert [s.kind for s in ws] == ["enc", "dec"]
    ds = Model(configs.get("deepseek-moe-16b")).stages
    assert ds[0].moe is False and ds[0].n_layers == 1
    assert ds[1].moe is True and ds[1].n_layers == 27


@pytest.mark.slow
def test_long_context_ring_cache():
    """Local-window ring cache: decoding far past the window stays finite and
    uses only window-sized memory. (slow: ~2 min of step-by-step decode)"""
    cfg = configs.get("recurrentgemma-9b").reduced()
    m = Model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    B = 1
    caches = m.make_caches(B, max_len=256)
    # window is reduced to 64; decode 100 steps (past the window)
    tok = jnp.ones((B, 1), jnp.int32)
    for _ in range(80):
        logits, caches = m.decode_step(params, caches, tok)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache sizes stayed window-bounded for the attn stages
    for st, spec in zip(caches["stages"], m.stages):
        if spec.cache == "kv" and spec.window:
            assert st["kv"]["k"].shape[2] == min(spec.window, 256)
