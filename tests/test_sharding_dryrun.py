"""Sharding rules + a miniature multi-device dry-run in a subprocess
(the 8-device XLA flag must not leak into this test process)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import batch_spec, spec_for

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_mesh(shape, axes):
    # AbstractMesh: rule resolution only needs mesh.shape (1 real device here).
    # jax < 0.5 takes a single ((name, size), ...) tuple instead of (shape, axes).
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def test_spec_for_divisibility():
    mesh = make_mesh((1, 4), ("data", "model"))
    # divisible: sharded
    assert spec_for(mesh, ("embed", "mlp"), (64, 128)) == P(None, "model")
    # non-divisible: replicated
    assert spec_for(mesh, ("embed", "mlp"), (64, 6)) == P(None, None)
    # vocab over model
    assert spec_for(mesh, ("vocab", "embed"), (512, 64)) == P("model", None)


def test_spec_for_no_duplicate_axis():
    mesh = make_mesh((1, 4), ("data", "model"))
    # MoE weights: experts and mlp both want 'model'; experts wins
    sp = spec_for(mesh, ("experts", "embed", "mlp"), (8, 64, 128))
    assert sp == P("model", None, None)


def test_batch_spec():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    assert batch_spec(mesh, (8, 128)) == P(("pod", "data"), None)
    # batch=1: unshardable -> spill to sequence
    sp = batch_spec(mesh, (1, 128), seq_dim=1)
    assert sp == P(None, "data")


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Lower+compile a reduced arch on a fake 8-device (2,4) mesh in a
    subprocess; assert memory/cost analysis and collective parse work."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax
        from repro import configs
        from repro.models import Model
        from repro.launch.mesh import make_mesh
        from repro.launch.hlo_analysis import parse_hlo
        from repro.runtime.train import init_state, jit_train_step

        cfg = configs.get("qwen1.5-0.5b").reduced()
        model = Model(cfg, remat=True)
        mesh = make_mesh((2, 4), ("data", "model"))
        make, _ = jit_train_step(model, mesh)
        specs = {
            "tokens": jax.ShapeDtypeStruct((4, 32), jax.numpy.int32),
            "targets": jax.ShapeDtypeStruct((4, 32), jax.numpy.int32),
        }
        state_shapes = jax.eval_shape(
            lambda: init_state(model, jax.random.PRNGKey(0)))
        with mesh:
            lowered = make(specs).lower(state_shapes, specs)
            compiled = lowered.compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # jax < 0.5: one dict per program
            ca = ca[0]
        hlo = parse_hlo(compiled.as_text())
        print(json.dumps({
            "flops": ca.get("flops", 0.0),
            "colls": hlo["collective_bytes_ring"],
            "n_whiles": hlo["n_whiles"],
            "partitions": hlo["num_partitions"],
        }))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["partitions"] == 8
    assert res["flops"] > 0
    assert res["n_whiles"] >= 2          # fwd + bwd scan loops
    assert res["colls"] > 0              # TP all-reduces exist
