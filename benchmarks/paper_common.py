"""Shared setup for the paper-figure benchmarks (Sec. VI experimental set,
scaled to CPU: the paper's 1250 users / 250 subchannels Monte-Carlo is run
at reduced but proportional scale; densities and ratios follow Sec. VI.A)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (
    GdConfig,
    baselines,
    make_env,
    make_weights,
    planner,
    profiles,
)

CFG = GdConfig(step_size=5e-3, max_iters=250)
W_T = 0.5          # equal tradeoff weights unless a figure sweeps them
N_SEEDS = 3        # Monte-Carlo channel draws per point


def mean_outcomes(n_users, n_aps, n_sub, prof, w_T=W_T, seeds=N_SEEDS,
                  methods=("ecc_noma", "ecc_oma", "device_only", "edge_only",
                           "neurosurgeon", "dnn_surgery")):
    """Average T/E per method over Monte-Carlo channel realizations."""
    acc: dict = {m: {"T": 0.0, "E": 0.0} for m in methods}
    for s in range(seeds):
        env = make_env(jax.random.PRNGKey(1000 + s), n_users, n_aps, n_sub)
        w = make_weights(env.n_users, w_T)
        res = planner.compare_all(env, prof, w, CFG)
        for m in methods:
            acc[m]["T"] += float(jnp.mean(res[m].T)) / seeds
            acc[m]["E"] += float(jnp.mean(res[m].E)) / seeds
    return acc


# Every emit() call also appends machine-readable rows here so the harness
# (benchmarks/run.py) can write the BENCH_<n>.json perf-trajectory artifact.
ROWS: list[dict] = []


def emit(name: str, rows: list[tuple], meta: dict | None = None,
         audit: dict | None = None):
    """CSV rows: (label, value, derived-annotation) or (label, value,
    derived, row_meta) -- a 4th dict entry attaches per-row key/values
    (e.g. timing spread, tuning-table entries) on top of the shared meta.
    meta: extra key/values attached to every JSON row (e.g. kernel layout +
    block sizes) so BENCH_<n>.json artifacts stay comparable across kernel
    redesigns. Per-row meta wins on key collisions.
    audit: a repro.analysis verdict for the program these rows measure
    (e.g. audit_meta(report)), stamped as the rows' 'audit' field -- perf
    numbers in the artifact then carry the proof that the program they
    timed still satisfies the kernel invariants. A per-row 'audit' in
    row_meta overrides it (the autotune table audits per candidate)."""
    for r in rows:
        label, val, derived = r[0], r[1], r[2]
        row_meta = r[3] if len(r) > 3 else None
        print(f"{name},{label},{val:.6g},{derived}")
        row = {"bench": name, "label": label, "value": float(val),
               "derived": derived}
        if meta:
            row.update(meta)
        if audit is not None:
            row["audit"] = audit
        if row_meta:
            row.update(row_meta)
        ROWS.append(row)


def audit_meta(report) -> dict:
    """Compress an analysis.AuditReport into the artifact's audit field:
    verdict, the rules that ran, and the findings (if any) as strings."""
    d = {"ok": report.ok, "rules": list(report.rules)}
    if report.findings:
        d["findings"] = [str(f) for f in report.findings]
    return d
