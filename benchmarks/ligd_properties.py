"""Corollaries 2-5 as measurements:
  Cor.2 convergence: GD iterations always terminate under the eps rules;
  Cor.3/4 complexity: Li-GD total iterations << cold-start GD (warm starts);
  Cor.5 rounding error: relaxed-vs-rounded utility gap, vs the paper bound.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (
    GdConfig,
    baselines,
    li_gd_loop,
    make_env,
    make_weights,
    plain_gd_loop,
    planner,
    profiles,
    solve,
)
from repro.core.utility import utility
from repro.core.types import GdVars
from benchmarks.paper_common import emit


def run():
    t0 = time.time()
    rows = []
    cfg = GdConfig(step_size=5e-3, max_iters=300)
    for pname, fn in profiles.PAPER_MODELS.items():
        prof = fn()
        li_total, gd_total, gap_rel = 0.0, 0.0, 0.0
        seeds = 3
        for s in range(seeds):
            env = make_env(jax.random.PRNGKey(2000 + s), 12, 3, 4)
            w = make_weights(env.n_users, 0.5)
            li = li_gd_loop(env, prof, w, cfg)
            gd = plain_gd_loop(env, prof, w, cfg)
            li_total += float(li.total_iters) / seeds
            gd_total += float(gd.total_iters) / seeds
            plan = solve(env, prof, w, cfg)
            disc = baselines.evaluate_plan(env, prof, plan, w)
            disc_u = float(jnp.sum(w.w_T * disc.T + w.w_E * disc.E))
            gap_rel += (disc_u - float(plan.utility)) / abs(float(plan.utility)) / seeds
        rows.append((f"{pname}:ligd_total_iters", li_total,
                     "Cor.4: < cold-start GD"))
        rows.append((f"{pname}:gd_total_iters", gd_total, "cold-start baseline"))
        rows.append((f"{pname}:iter_reduction", gd_total / max(li_total, 1),
                     "Cor.4 speedup factor"))
        rows.append((f"{pname}:rounding_gap_rel", gap_rel,
                     "Cor.5: bounded rounding error (relaxed->discrete)"))
    emit("ligd_properties", rows)
    print(f"ligd_properties,elapsed_s,{time.time()-t0:.1f},wall-clock")


if __name__ == "__main__":
    run()
