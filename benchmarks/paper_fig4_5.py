"""Fig.4 / Fig.5: ECC vs Neurosurgeon and DNN-Surgery, normalized to
Neurosurgeon (paper Sec VI.B second comparison)."""
import time

from repro.core import profiles
from benchmarks.paper_common import emit, mean_outcomes


def run():
    t0 = time.time()
    rows = []
    for pname, fn in profiles.PAPER_MODELS.items():
        prof = fn()
        acc = mean_outcomes(12, 3, 4, prof)
        ns_T, ns_E = acc["neurosurgeon"]["T"], acc["neurosurgeon"]["E"]
        for m in ("ecc_noma", "ecc_oma", "dnn_surgery"):
            rows.append((f"{pname}:{m}:latency_vs_neurosurgeon",
                         ns_T / acc[m]["T"],
                         "paper: ECC ~ DNN-surgery <~ 1, ECC-NOMA > 1"))
            rows.append((f"{pname}:{m}:energy_vs_neurosurgeon",
                         ns_E / acc[m]["E"],
                         "paper: ECC 1.5-1.7x, DNN-surgery 1.3-1.49x"))
    emit("fig4_5", rows)
    print(f"fig4_5,elapsed_s,{time.time()-t0:.1f},wall-clock")


if __name__ == "__main__":
    run()
