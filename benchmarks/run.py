"""Benchmark harness: one module per paper table/figure + roofline + kernels.
Prints ``name,label,value,derived`` CSV lines and writes a machine-readable
``BENCH_<n>.json`` artifact (per-benchmark rows + git SHA) so the perf
trajectory is tracked across PRs. Rows may carry extra metadata keys via
``paper_common.emit(..., meta=...)`` -- the noma kernel rows record the
kernel layout (gathered in BENCH_1, gather_free from BENCH_2 on) and the
block sizes, so artifacts stay comparable across kernel redesigns.

  PYTHONPATH=src python -m benchmarks.run [--only fig2_3,...] [--json PATH]
"""
import argparse
import json
import pathlib
import re
import subprocess
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _git_sha() -> str:
    """HEAD short SHA, '-dirty'-suffixed when the tree has local changes --
    a clean SHA must be able to reproduce the recorded rows."""
    try:
        sha = subprocess.run(
            ["git", "-C", str(_ROOT), "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
        ).stdout.strip()
        # Exclude the harness's own artifacts: a fresh BENCH_<n>.json from a
        # previous run must not mark a clean source tree dirty.
        dirty = subprocess.run(
            ["git", "-C", str(_ROOT), "status", "--porcelain", "--",
             ":!BENCH_*.json"],
            capture_output=True, text=True, check=True,
        ).stdout.strip()
        return f"{sha}-dirty" if dirty else sha
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _next_bench_path() -> pathlib.Path:
    """Auto-number the artifact: BENCH_<n>.json with n = 1 + max existing."""
    taken = [int(m.group(1)) for p in _ROOT.glob("BENCH_*.json")
             if (m := re.fullmatch(r"BENCH_(\d+)\.json", p.name))]
    return _ROOT / f"BENCH_{max(taken, default=0) + 1}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--json", default=None,
                    help="path for the JSON artifact (default: auto-numbered "
                         "BENCH_<n>.json in the repo root)")
    args = ap.parse_args()

    from benchmarks import (
        chaos_serve,
        kernel_bench,
        ligd_properties,
        online_serve,
        paper_common,
        paper_fig2_3,
        paper_fig4_5,
        paper_fig6_11,
        recovery_serve,
        roofline_report,
    )

    paper_common.ROWS.clear()    # one artifact per invocation, never stale
    all_benches = {
        "fig2_3": paper_fig2_3.run,
        "fig4_5": paper_fig4_5.run,
        "fig6_11": paper_fig6_11.run,
        "ligd_properties": ligd_properties.run,
        "kernel_bench": kernel_bench.run,
        "roofline": roofline_report.run,
        "online_serve": online_serve.run,
        "chaos_serve": chaos_serve.run,
        "recovery_serve": recovery_serve.run,
    }
    chosen = (args.only.split(",") if args.only else list(all_benches))
    t0 = time.time()
    errors = []
    print("name,label,value,derived")
    for name in chosen:
        try:
            all_benches[name]()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},ERROR,0,{type(e).__name__}: {e}", file=sys.stderr)
            print(f"{name},error,0,{type(e).__name__}")
            errors.append({"bench": name, "error": f"{type(e).__name__}: {e}"})
    elapsed = time.time() - t0
    print(f"total,elapsed_s,{elapsed:.1f},all benchmarks")

    out = pathlib.Path(args.json) if args.json else _next_bench_path()
    out.write_text(json.dumps({
        "schema": 1,
        "git_sha": _git_sha(),
        "benches": chosen,
        "elapsed_s": round(elapsed, 1),
        "rows": paper_common.ROWS,
        "errors": errors,
    }, indent=1) + "\n")
    print(f"wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
