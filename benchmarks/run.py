"""Benchmark harness: one module per paper table/figure + roofline + kernels.
Prints ``name,label,value,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--only fig2_3,...]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args()

    from benchmarks import (
        kernel_bench,
        ligd_properties,
        paper_fig2_3,
        paper_fig4_5,
        paper_fig6_11,
        roofline_report,
    )

    all_benches = {
        "fig2_3": paper_fig2_3.run,
        "fig4_5": paper_fig4_5.run,
        "fig6_11": paper_fig6_11.run,
        "ligd_properties": ligd_properties.run,
        "kernel_bench": kernel_bench.run,
        "roofline": roofline_report.run,
    }
    chosen = (args.only.split(",") if args.only else list(all_benches))
    t0 = time.time()
    print("name,label,value,derived")
    for name in chosen:
        try:
            all_benches[name]()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},ERROR,0,{type(e).__name__}: {e}", file=sys.stderr)
            print(f"{name},error,0,{type(e).__name__}")
    print(f"total,elapsed_s,{time.time()-t0:.1f},all benchmarks")


if __name__ == "__main__":
    main()
