"""Closed-loop serving benchmark: requests/sec vs concurrent users.

Each concurrency point runs the SAME traffic and the SAME time-evolving
scenario through two arms of repro.online.OnlineLoop:

  static  -- the planner prices the edge with the static profile (open
             loop: what the paper's offline planner would keep doing)
  closed  -- telemetry feeds the measured profile back every scheduled
             replan, and QoS breaches force off-schedule replans

The edge degrades with load (ServiceConfig.load_gain inflates the suffix
compute by 1 + gain * (occupancy + backlog) / capacity), which the static
profile cannot see: its s* stays put while the queue saturates. The
closed loop's measured profile re-prices edge compute, s* rises (keep
more layers on device) and completions/sec recover. Rows carry the full
decision record: scheduled + QoS-forced replan counts, the s* trajectory
(run-length encoded), tail latencies and deadline misses -- plus the
repro.analysis audit verdict for the measured-profile replan program the
closed arm dispatches.

  PYTHONPATH=src python -m benchmarks.online_serve            # 3 points
  PYTHONPATH=src python -m benchmarks.online_serve --quick    # CI smoke
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.paper_common import audit_meta, emit
from repro.analysis import audit_online_replan
from repro.core import make_env, profiles
from repro.core.types import GdConfig
from repro.online import OnlineLoop, ServiceConfig, StreamConfig
from repro.planning import PlannerEngine
from repro.scenarios import Scenario, ScenarioConfig

CFG = GdConfig(step_size=3e-2, eps=1e-4, max_iters=60, optimizer="adam")
STREAM = StreamConfig(arrival_rate_hz=30.0, epoch_dt_s=0.02, deadline_s=0.2)
SERVICE = ServiceConfig(edge_capacity=4, queue_depth=32, load_gain=8.0,
                        replan_every=5)


def _rle(xs: list[int]) -> list[list[int]]:
    """Run-length encode a trajectory: [[value, run], ...]."""
    out: list[list[int]] = []
    for x in xs:
        if out and out[-1][0] == x:
            out[-1][1] += 1
        else:
            out.append([int(x), 1])
    return out


def _episode(n_users: int, feedback: bool, n_epochs: int, seed: int) -> dict:
    eng = PlannerEngine(profiles.nin(), cfg=CFG)
    scen = Scenario(ScenarioConfig(n_users=n_users, n_aps=2, n_sub=3,
                                   fading_rho=0.95))
    loop = OnlineLoop(scen, eng, STREAM, SERVICE, feedback=feedback)
    return loop.run(jax.random.PRNGKey(seed), n_epochs, record=True)


def run(quick: bool = False) -> None:
    users = (6,) if quick else (4, 8, 12)
    n_epochs = 30 if quick else 70

    # The audit verdict travels with the perf rows: the closed arm's replan
    # program, traced at measured-profile avals, against the base rules.
    audit_eng = PlannerEngine(profiles.nin(), cfg=CFG)
    audit_env = make_env(jax.random.PRNGKey(0), n_users=users[0], n_aps=2,
                         n_sub=3)
    audit = audit_meta(audit_online_replan(audit_eng, audit_env,
                                           label="online_serve"))

    rows = []
    per_point: dict[int, dict[str, dict]] = {}
    for u in users:
        per_point[u] = {}
        for feedback in (False, True):
            arm = "closed" if feedback else "static"
            m = _episode(u, feedback, n_epochs, seed=7)
            per_point[u][arm] = m
            h = m["history"]
            rows.append((
                f"u{u}:{arm}:requests_per_s", m["requests_per_s"],
                "completions/sec under load-degraded edge; closed arm "
                "replans on the measured profile",
                {
                    "n_users": u, "arm": arm, "epochs": m["epochs"],
                    "offered_per_s": m["offered_per_s"],
                    "dropped": m["dropped"],
                    "deadline_missed": m["deadline_missed"],
                    "p50_s": h["p50"][-1], "p95_s": h["p95"][-1],
                    "miss_rate": h["miss_rate"][-1],
                    "replans": m["replans"],
                    "forced_replans": m["forced_replans"],
                    "qos_triggers": m["qos_triggers"],
                    "peak_congestion": max(h["congestion"]),
                    "s_trajectory": _rle(h["s"]),
                },
            ))

    # The claim the artifact exists to record: under induced edge load the
    # closed loop's split trajectory leaves the static optimum and pays.
    for u in users:
        st, cl = per_point[u]["static"], per_point[u]["closed"]
        s_moved = max(cl["history"]["s"]) > max(st["history"]["s"])
        gain = (cl["requests_per_s"] / st["requests_per_s"]
                if st["requests_per_s"] > 0 else float("inf"))
        rows.append((
            f"u{u}:closed_over_static", gain,
            "requests/sec ratio; s* diverged from static plan: "
            f"{s_moved}",
            {"n_users": u, "s_diverged": bool(s_moved),
             "static_s": _rle(st["history"]["s"]),
             "closed_s": _rle(cl["history"]["s"])},
        ))

    emit("online_serve", rows,
         meta={"arrival_rate_hz": STREAM.arrival_rate_hz,
               "epoch_dt_s": STREAM.epoch_dt_s,
               "deadline_s": STREAM.deadline_s,
               "edge_capacity": SERVICE.edge_capacity,
               "load_gain": SERVICE.load_gain,
               "replan_every": SERVICE.replan_every},
         audit=audit)

    # Sanity gates (benchmark fails loudly rather than record a dead loop):
    # every closed-arm point must have replanned, and at least one point
    # must show the measured profile moving s* off the static optimum.
    for u in users:
        assert per_point[u]["closed"]["replans"] >= n_epochs // \
            SERVICE.replan_every, (u, per_point[u]["closed"]["replans"])
    assert any(max(per_point[u]["closed"]["history"]["s"])
               > max(per_point[u]["static"]["history"]["s"])
               for u in users), "closed-loop s* never left the static plan"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one concurrency point, fewer epochs (CI smoke)")
    args = ap.parse_args()
    print("name,label,value,derived")
    run(quick=args.quick)


if __name__ == "__main__":
    main()
