"""Online re-planning benchmark: warm vs cold GD iterations across
time-correlated fading episodes (Corollary 4's warm-start argument applied
across time instead of across split points).

Default mode sweeps the epoch-to-epoch fading correlation rho over a *fleet*
of scenarios evolving in parallel: every epoch the whole fleet is solved
twice -- cold (PlannerEngine.plan_many, a fresh Li-GD plan per member, as the
paper would re-run per realization) and warm (PlannerEngine.replan_many, each
split point resuming the previous epoch's optimum + Adam state when it beats
the fresh chain carry). One compiled program serves every rho level because
the fleet shapes are static. --verify additionally re-plans each member
sequentially with PlannerEngine.replan and checks the batched path agrees.

  PYTHONPATH=src python benchmarks/online_replan.py
  PYTHONPATH=src python benchmarks/online_replan.py --rhos 0.9 0.99 0.999 --fleet 8
  PYTHONPATH=src python benchmarks/online_replan.py --preset iot_massive --episode
  PYTHONPATH=src python benchmarks/online_replan.py --quick   # CI smoke
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/online_replan.py --mesh --fleet 8

--mesh attaches a fleet mesh over all local devices: plan_many/replan_many
then run shard_map over the fleet axis (one scenario shard per device, the
carried warm state donated in place) instead of a single-device vmap.

--episode keeps PR 1's single-scenario preset episode mode (plan vs replan
per epoch on one correlated trajectory).

--quick additionally asserts the dispatch path is device-resident: a warm
replan_many must enqueue under jax.transfer_guard("disallow") -- any host
numpy left in the warm gate would raise -- and return before the solver
finishes (async dispatch, completion only at block_until_ready).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import GdConfig, make_weights, profiles
from repro.planning import PlannerEngine, member
from repro.pshard import fleet_mesh, shard_fleet
from repro.scenarios import Scenario, ScenarioConfig, presets


def _profile(name: str):
    return {"nin": profiles.nin, "vgg16": profiles.vgg16,
            "yolov2": profiles.yolov2}[name]()


def run_sweep(rhos, fleet, n_epochs, seed, prof_name, cfg, scfg,
              verify=False, mesh=None) -> list[dict]:
    prof = _profile(prof_name)
    w = make_weights(scfg.n_users)
    warm_eng = PlannerEngine(prof, weights=w, cfg=cfg, mesh=mesh)
    cold_eng = PlannerEngine(prof, weights=w, cfg=cfg, mesh=mesh)
    seq_eng = PlannerEngine(prof, weights=w, cfg=cfg)  # per-member reference
    sc = Scenario(scfg)

    out = []
    for rho in rhos:
        keys = jax.random.split(jax.random.PRNGKey(seed), fleet)
        states = sc.init_many(keys)
        fleet_state, seq_states = None, [None] * fleet
        cold_it = warm_it = 0
        cold_util = warm_util = 0.0
        rho_est = 0.0
        mismatches = 0
        key = jax.random.PRNGKey(seed + 1)
        for t in range(n_epochs):
            envs = sc.env_many(states)
            if mesh is not None:
                # place the fleet on the mesh once per epoch; otherwise every
                # sharded call re-copies it from the default device
                envs = shard_fleet(envs, mesh)
            # epoch 0 is cold for both engines (replan_many(None) == plan_many),
            # so the cold baseline is only solved for the counted epochs
            cold = cold_eng.plan_many(envs) if t >= 1 else None
            fleet_state = warm_eng.replan_many(fleet_state, envs)
            if verify:
                for i in range(fleet):
                    seq_states[i] = seq_eng.replan(seq_states[i],
                                                   member(envs, i))
                    same_s = int(seq_states[i].plan.s) == int(fleet_state.plan.s[i])
                    du = abs(float(seq_states[i].plan.utility)
                             - float(fleet_state.plan.utility[i]))
                    di = abs(int(seq_states[i].total_iters)
                             - int(fleet_state.total_iters[i]))
                    # di tolerance: vmap may reorder reductions in the last
                    # ulp, nudging a stopping rule by an iteration or two
                    if not same_s or du > 1e-4 or di > 2:
                        mismatches += 1
            if t >= 1:  # epoch 0 is cold for both engines
                cold_it += int(jnp.sum(cold.total_iters))
                warm_it += int(jnp.sum(fleet_state.total_iters))
                cold_util += float(jnp.sum(cold.plan.utility))
                warm_util += float(jnp.sum(fleet_state.plan.utility))
                # mean of the in-jit gate estimate across members and epochs
                rho_est += float(jnp.mean(fleet_state.warm_rho))
            key, k_step = jax.random.split(key)
            step_keys = jax.random.split(k_step, fleet)
            states = sc.step_many(step_keys, states,
                                  rho=jnp.full((fleet,), rho))
        out.append({
            "rho": rho, "fleet": fleet, "epochs": n_epochs,
            "cold_iters": cold_it, "warm_iters": warm_it,
            "cold_util": cold_util, "warm_util": warm_util,
            "rho_est": rho_est / max(n_epochs - 1, 1),
            "mismatches": mismatches if verify else None,
        })
    return out


def check_async_dispatch(prof_name, cfg, scfg, fleet, mesh=None) -> None:
    """--quick acceptance: a warm replan must *enqueue* without any blocking
    host transfer. The warm gate, moment decay, and solver all live inside
    the compiled program, so dispatch under jax.transfer_guard('disallow')
    must not raise (any host-side numpy would) and must return before the
    solve completes (block_until_ready does the waiting)."""
    prof = _profile(prof_name)
    w = make_weights(scfg.n_users)
    eng = PlannerEngine(prof, weights=w, cfg=cfg, mesh=mesh)
    sc = Scenario(scfg)

    def fleet_envs(states):
        envs = sc.env_many(states)
        # Place the fleet explicitly on the mesh: steady-state dispatch then
        # needs no transfers at all (the carried state and the engine
        # constants already live there).
        return envs if mesh is None else shard_fleet(envs, mesh)

    key = jax.random.PRNGKey(123)
    states = sc.init_many(jax.random.split(key, fleet))
    state = eng.replan_many(None, fleet_envs(states))       # compile cold
    states = sc.step_many(jax.random.split(jax.random.PRNGKey(124), fleet),
                          states)
    state = eng.replan_many(state, fleet_envs(states))      # compile warm
    jax.block_until_ready(state)
    states = sc.step_many(jax.random.split(jax.random.PRNGKey(125), fleet),
                          states)
    envs = fleet_envs(states)
    jax.block_until_ready(envs)

    t0 = time.perf_counter()
    with jax.transfer_guard("disallow"):
        nxt = eng.replan_many(state, envs)
        probe = nxt.total_iters
        pending = not probe.is_ready() if hasattr(probe, "is_ready") else None
    t_dispatch = time.perf_counter() - t0
    jax.block_until_ready(nxt)
    t_total = time.perf_counter() - t0
    print(f"[async] warm replan_many dispatch {t_dispatch * 1e3:.2f} ms, "
          f"completion {t_total * 1e3:.2f} ms, pending at dispatch: {pending}")
    # The transfer guard above is the hard 'no blocking host transfer'
    # assertion. For async-ness: pending=True proves dispatch returned with
    # the solve still in flight. pending=False alone is not damning -- a
    # fast solve (small t_total) can win the race against the probe, and on
    # a loaded runner the OS can preempt us between dispatch and the probe.
    # Blocking is only proven when the solve is slow AND the dispatch call
    # itself consumed that time.
    if pending is False and t_total >= 0.25 and t_dispatch > 0.5 * t_total:
        raise SystemExit("FAIL: warm replan dispatch blocked until completion "
                         f"(dispatch {t_dispatch:.3f}s vs total {t_total:.3f}s)")


def run_episode(preset: str, n_epochs: int, seed: int, prof_name: str,
                cfg: GdConfig) -> dict:
    scfg = presets.get(preset)
    prof = _profile(prof_name)
    w = make_weights(scfg.n_users)
    warm_eng = PlannerEngine(prof, weights=w, cfg=cfg)
    cold_eng = PlannerEngine(prof, weights=w, cfg=cfg)

    sc = Scenario(scfg)
    rows, state = [], None
    for t, env in enumerate(sc.episode(jax.random.PRNGKey(seed), n_epochs)):
        cold = cold_eng.plan(env)
        state = warm_eng.replan(state, env)
        rows.append({
            "epoch": t,
            "cold_iters": int(cold.total_iters),
            "warm_iters": int(state.total_iters),
            "cold_s": int(cold.plan.s),
            "warm_s": int(state.plan.s),
            "cold_util": float(cold.plan.utility),
            "warm_util": float(state.plan.utility),
        })
    return {"preset": preset, "rho": scfg.rho, "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rhos", type=float, nargs="+",
                    default=[0.9, 0.99, 0.999])
    ap.add_argument("--fleet", type=int, default=8)
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--aps", type=int, default=2)
    ap.add_argument("--subs", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile", default="nin", choices=("nin", "vgg16", "yolov2"))
    ap.add_argument("--step-size", type=float, default=1e-2)
    ap.add_argument("--eps", type=float, default=1e-4)
    ap.add_argument("--max-iters", type=int, default=200)
    ap.add_argument("--verify", action="store_true",
                    help="check replan_many against sequential replan")
    ap.add_argument("--episode", action="store_true",
                    help="single-scenario preset episode mode (PR 1 report)")
    ap.add_argument("--preset", default="iot_massive", choices=presets.names())
    ap.add_argument("--mesh", action="store_true",
                    help="shard the fleet over all local devices (shard_map)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny fleet, 3 epochs, one rho, --verify, "
                         "plus the async-dispatch (no host transfer) check")
    args = ap.parse_args()

    cfg = GdConfig(step_size=args.step_size, eps=args.eps,
                   max_iters=args.max_iters, optimizer="adam")

    if args.episode:
        out = run_episode(args.preset, args.epochs, args.seed, args.profile, cfg)
        print(f"preset={out['preset']}  epoch-to-epoch fading rho={out['rho']:.4f}")
        print(f"{'epoch':>5} {'cold_it':>8} {'warm_it':>8} {'s_cold':>6} {'s_warm':>6}"
              f" {'util_cold':>10} {'util_warm':>10}")
        for r in out["rows"]:
            print(f"{r['epoch']:5d} {r['cold_iters']:8d} {r['warm_iters']:8d}"
                  f" {r['cold_s']:6d} {r['warm_s']:6d}"
                  f" {r['cold_util']:10.4f} {r['warm_util']:10.4f}")
        # epoch 0 is cold for both engines; the online gain is epochs >= 1
        cold_total = sum(r["cold_iters"] for r in out["rows"][1:])
        warm_total = sum(r["warm_iters"] for r in out["rows"][1:])
        print(f"\ntotals (epochs 1..{len(out['rows']) - 1}): "
              f"cold={cold_total}  warm={warm_total}  "
              f"reduction={100.0 * (1 - warm_total / max(cold_total, 1)):.1f}%")
        return

    rhos, fleet, epochs, verify = (args.rhos, args.fleet, args.epochs,
                                   args.verify)
    if args.quick:
        rhos, fleet, epochs, verify = [0.95], 4, 3, True
    mesh = None
    if args.mesh:
        mesh = fleet_mesh()
        if args.quick and fleet % jax.device_count() != 0:
            # round the smoke fleet up to a whole number of shards
            fleet = jax.device_count() * -(-fleet // jax.device_count())
        if fleet % jax.device_count() != 0:
            raise SystemExit(f"--mesh needs fleet ({fleet}) divisible by the "
                             f"device count ({jax.device_count()}); set "
                             "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                             "or pick a matching --fleet")
        print(f"mesh: {jax.device_count()} devices over axis "
              f"'{mesh.axis_names[0]}' (shard_map fleet path)")
    scfg = ScenarioConfig(n_users=args.users, n_aps=args.aps, n_sub=args.subs,
                          speed_mps=0.0, arrival_rate_hz=0.0)
    rows = run_sweep(rhos, fleet, epochs, args.seed, args.profile, cfg, scfg,
                     verify=verify, mesh=mesh)
    print(f"fleet={fleet} x {epochs} epochs, U={args.users} N={args.aps} "
          f"M={args.subs}, profile={args.profile} (totals over epochs >= 1)")
    print(f"{'rho':>7} {'rho_est':>8} {'cold_it':>9} {'warm_it':>9} {'saved':>7} "
          f"{'util_cold':>11} {'util_warm':>11}" + ("  mismatch" if verify else ""))
    ok = True
    for r in rows:
        saved = 100.0 * (1 - r["warm_iters"] / max(r["cold_iters"], 1))
        line = (f"{r['rho']:7.3f} {r['rho_est']:8.4f}"
                f" {r['cold_iters']:9d} {r['warm_iters']:9d}"
                f" {saved:6.1f}% {r['cold_util']:11.4f} {r['warm_util']:11.4f}")
        if verify:
            line += f"  {r['mismatches']:8d}"
            ok = ok and r["mismatches"] == 0
        print(line)
        ok = ok and r["warm_iters"] <= r["cold_iters"]
        # acceptance is iterations saved at equal-or-better utility (cost:
        # lower is better); 1% headroom absorbs plateau-stopping noise
        ok = ok and r["warm_util"] <= r["cold_util"] * 1.01
    if args.quick:
        check_async_dispatch(args.profile, cfg, scfg, fleet, mesh=mesh)
    if verify and not ok:
        raise SystemExit("FAIL: warm > cold iterations, warm utility worse "
                         "than cold, or batched/sequential replan mismatch")
    print("OK" if ok else "WARN: warm lost to cold (iterations or utility) "
          "somewhere")


if __name__ == "__main__":
    main()
