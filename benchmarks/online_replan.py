"""Online re-planning benchmark: warm vs cold GD iterations across a
time-correlated fading episode (Corollary 4's warm-start argument applied
across time instead of across split points).

For every epoch of a scenario episode we solve the full split-point sweep
twice: cold (a fresh Li-GD plan, as the paper would re-run per realization)
and warm (PlannerEngine.replan, starting every split from the previous
epoch's normalized optimum). Reported: per-epoch iteration counts, totals,
and the chosen split trajectory.

  PYTHONPATH=src python benchmarks/online_replan.py --preset iot_massive
"""
from __future__ import annotations

import argparse

import jax

from repro.core import GdConfig, make_weights, profiles
from repro.planning import PlannerEngine
from repro.scenarios import Scenario, presets


def run_episode(preset: str, n_epochs: int, seed: int, prof_name: str,
                cfg: GdConfig) -> dict:
    scfg = presets.get(preset)
    prof = {"nin": profiles.nin, "vgg16": profiles.vgg16,
            "yolov2": profiles.yolov2}[prof_name]()
    w = make_weights(scfg.n_users)
    warm_eng = PlannerEngine(prof, weights=w, cfg=cfg)
    cold_eng = PlannerEngine(prof, weights=w, cfg=cfg)

    sc = Scenario(scfg)
    rows, state = [], None
    for t, env in enumerate(sc.episode(jax.random.PRNGKey(seed), n_epochs)):
        cold = cold_eng.plan(env)
        state = warm_eng.replan(state, env)
        rows.append({
            "epoch": t,
            "cold_iters": int(cold.total_iters),
            "warm_iters": int(state.total_iters),
            "cold_s": int(cold.plan.s),
            "warm_s": int(state.plan.s),
            "cold_util": float(cold.plan.utility),
            "warm_util": float(state.plan.utility),
        })
    return {"preset": preset, "rho": scfg.rho, "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="iot_massive", choices=presets.names())
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile", default="nin", choices=("nin", "vgg16", "yolov2"))
    ap.add_argument("--step-size", type=float, default=1e-2)
    ap.add_argument("--eps", type=float, default=1e-5)
    ap.add_argument("--max-iters", type=int, default=400)
    args = ap.parse_args()

    cfg = GdConfig(step_size=args.step_size, eps=args.eps,
                   max_iters=args.max_iters, optimizer="adam")
    out = run_episode(args.preset, args.epochs, args.seed, args.profile, cfg)

    print(f"preset={out['preset']}  epoch-to-epoch fading rho={out['rho']:.4f}")
    print(f"{'epoch':>5} {'cold_it':>8} {'warm_it':>8} {'s_cold':>6} {'s_warm':>6}"
          f" {'util_cold':>10} {'util_warm':>10}")
    for r in out["rows"]:
        print(f"{r['epoch']:5d} {r['cold_iters']:8d} {r['warm_iters']:8d}"
              f" {r['cold_s']:6d} {r['warm_s']:6d}"
              f" {r['cold_util']:10.4f} {r['warm_util']:10.4f}")
    # epoch 0 is cold for both engines; the online gain is epochs >= 1
    cold_total = sum(r["cold_iters"] for r in out["rows"][1:])
    warm_total = sum(r["warm_iters"] for r in out["rows"][1:])
    print(f"\ntotals (epochs 1..{len(out['rows']) - 1}): "
          f"cold={cold_total}  warm={warm_total}  "
          f"reduction={100.0 * (1 - warm_total / max(cold_total, 1)):.1f}%")


if __name__ == "__main__":
    main()
