"""Chaos serving benchmark: goodput and availability under fault injection.

Each (link-outage rate, fault mix) point runs the SAME traffic and the
SAME time-evolving scenario through three arms of repro.online.OnlineLoop:

  closed    -- measured-profile feedback AND the degradation ladder
               (plan guards, telemetry quarantine, admission shedding,
               baseline fallback): the hardened loop this PR ships
  static    -- ladder on, feedback off: how much of the resilience is the
               ladder alone, without measured-profile replans
  no_ladder -- feedback on, ladder off: PR 8's loop under the same faults

The fault mixes compose the injector catalog (repro.faults.injectors):
deep fades riding a Gilbert-Elliott link process, whole-cell AP
blackouts, telemetry dropout/corruption, and service-time spikes. The
headline metric is goodput/sec -- finite, in-deadline completions -- not
raw completions: a NaN service time "completes" in one epoch, so the
unguarded arm's completion counter is inflated by requests that never
really ran (the rows record both so the artifact shows the gap).
Availability is the fraction of epochs a finite plan was on the air;
recovery stats come from the ladder's own counters.

  PYTHONPATH=src python -m benchmarks.chaos_serve            # full sweep
  PYTHONPATH=src python -m benchmarks.chaos_serve --quick    # CI smoke
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.paper_common import audit_meta, emit
from repro.analysis import audit_faults, guard_trace_audit
from repro.core import profiles
from repro.core.types import GdConfig
from repro.online import (
    FaultConfig,
    LadderConfig,
    OnlineLoop,
    ServiceConfig,
    StreamConfig,
)
from repro.planning import PlannerEngine
from repro.scenarios import Scenario, ScenarioConfig

CFG = GdConfig(step_size=3e-2, eps=1e-4, max_iters=60, optimizer="adam")
STREAM = StreamConfig(arrival_rate_hz=30.0, epoch_dt_s=0.02, deadline_s=0.2)
SERVICE = ServiceConfig(edge_capacity=4, queue_depth=32, load_gain=4.0,
                        replan_every=5, max_work_epochs=200)
LADDER = LadderConfig(quarantine_epochs=15, baseline_after=2)

# The acceptance operating point: 20% of epochs in link outage.
GATE_OUTAGE = 0.2


def _mix(name: str, outage: float) -> FaultConfig:
    """Fault mixes over the injector catalog; ``outage`` scales the
    Gilbert-Elliott link process (fades mix) and rides along in full."""
    if name == "fades":
        return FaultConfig(link_outage_rate=outage, fade_depth=1e-6,
                           ap_outage_rate=0.05)
    if name == "telemetry":
        return FaultConfig(telemetry_drop_rate=0.1,
                           telemetry_spike_rate=0.05,
                           service_spike_rate=0.02)
    if name == "full":
        return FaultConfig(link_outage_rate=outage, fade_depth=1e-6,
                           ap_outage_rate=0.05, telemetry_drop_rate=0.1,
                           telemetry_spike_rate=0.05, service_spike_rate=0.02)
    raise ValueError(name)


ARMS = {
    "closed": dict(feedback=True, degrade=LADDER),
    "static": dict(feedback=False, degrade=LADDER),
    "no_ladder": dict(feedback=True, degrade=None),
}


def _episode(arm: str, faults: FaultConfig, n_epochs: int,
             seed: int) -> dict:
    eng = PlannerEngine(profiles.nin(), cfg=CFG)
    scen = Scenario(ScenarioConfig(n_users=6, n_aps=2, n_sub=3,
                                   fading_rho=0.95))
    loop = OnlineLoop(scen, eng, STREAM, SERVICE, faults=faults, **ARMS[arm])
    return loop.run(jax.random.PRNGKey(seed), n_epochs, record=True)


def run(quick: bool = False) -> None:
    outages = (GATE_OUTAGE,) if quick else (0.0, 0.1, GATE_OUTAGE)
    mixes = ("full",) if quick else ("fades", "telemetry", "full")
    n_epochs = 40 if quick else 120

    # The audit verdict travels with the perf rows: the hardened epoch
    # program under injection + the plan-word guard against NoHostTransfer;
    # the full run adds the executing chaos-loop probe (zero steady-state
    # recompiles, rate swap mints no cache keys, served plan stays finite).
    report = (guard_trace_audit(label="chaos_serve") if quick
              else audit_faults(label="chaos_serve"))
    audit = audit_meta(report)

    rows = []
    per_point: dict[tuple, dict] = {}
    for outage in outages:
        for mix in mixes:
            # The outage axis only moves the link process; sweeping it
            # under the telemetry-only mix would rerun identical episodes.
            if mix == "telemetry" and outage != outages[-1]:
                continue
            faults = _mix(mix, outage)
            for arm in ARMS:
                m = _episode(arm, faults, n_epochs, seed=7)
                per_point[(outage, mix, arm)] = m
                h = m["history"]
                availability = (sum(h["plan_finite"])
                                / max(len(h["plan_finite"]), 1))
                extra = {
                    "outage": outage, "mix": mix, "arm": arm,
                    "epochs": m["epochs"],
                    "completed": m["completed"], "goodput": m["goodput"],
                    "requests_per_s": m["requests_per_s"],
                    "dropped": m["dropped"], "shed": m["shed"],
                    "deadline_missed": m["deadline_missed"],
                    "availability": availability,
                    "bad_plans": m.get("bad_plans", 0),
                    "faulted_epochs": sum(1 for f in h["faulted"] if f),
                }
                if "ladder_stage" in m:      # laddered arms only
                    extra.update({
                        "quarantines": m["quarantines"],
                        "holds": m["holds"],
                        "baseline_fallbacks": m["baseline_fallbacks"],
                        "cold_replans": m["ladder_cold_replans"],
                        "recoveries": m["recoveries"],
                        "mean_recovery_epochs": m["mean_recovery_epochs"],
                    })
                rows.append((
                    f"out{outage:g}:{mix}:{arm}:goodput_per_s",
                    m["goodput_per_s"],
                    "finite in-deadline completions/sec under fault "
                    "injection (raw completions inflate on NaN service)",
                    extra,
                ))

    # The claim the artifact exists to record: at the 20%-outage operating
    # point the ladder keeps goodput up and every served plan finite while
    # the unguarded loop collapses.
    gate_mix = mixes[-1]                     # "full" in both modes
    for outage in outages:
        cl = per_point[(outage, gate_mix, "closed")]
        nl = per_point[(outage, gate_mix, "no_ladder")]
        ratio = (cl["goodput_per_s"] / nl["goodput_per_s"]
                 if nl["goodput_per_s"] > 0 else float("inf"))
        rows.append((
            f"out{outage:g}:{gate_mix}:ladder_over_no_ladder", ratio,
            "goodput/sec ratio, hardened over unguarded; no-ladder served "
            f"non-finite plans: {not all(nl['history']['plan_finite'])}",
            {"outage": outage, "mix": gate_mix,
             "closed_goodput_per_s": cl["goodput_per_s"],
             "no_ladder_goodput_per_s": nl["goodput_per_s"],
             "no_ladder_availability":
                 sum(nl["history"]["plan_finite"])
                 / max(len(nl["history"]["plan_finite"]), 1)},
        ))

    emit("chaos_serve", rows,
         meta={"arrival_rate_hz": STREAM.arrival_rate_hz,
               "epoch_dt_s": STREAM.epoch_dt_s,
               "deadline_s": STREAM.deadline_s,
               "edge_capacity": SERVICE.edge_capacity,
               "load_gain": SERVICE.load_gain,
               "replan_every": SERVICE.replan_every,
               "quarantine_epochs": LADDER.quarantine_epochs,
               "baseline_after": LADDER.baseline_after},
         audit=audit)

    # Sanity gates (fail loudly rather than record a dead chaos loop):
    # the hardened arm must never put a non-finite plan on the air, and at
    # the 20%-outage full mix its goodput must be >= 2x the unguarded arm.
    for (outage, mix, arm), m in per_point.items():
        if arm != "no_ladder":
            assert all(m["history"]["plan_finite"]), \
                (outage, mix, arm, "non-finite plan served")
    cl = per_point[(GATE_OUTAGE, gate_mix, "closed")]
    nl = per_point[(GATE_OUTAGE, gate_mix, "no_ladder")]
    assert cl["goodput_per_s"] >= 2.0 * nl["goodput_per_s"], \
        (cl["goodput_per_s"], nl["goodput_per_s"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="gate operating point only, fewer epochs (CI smoke)")
    args = ap.parse_args()
    print("name,label,value,derived")
    run(quick=args.quick)


if __name__ == "__main__":
    main()
