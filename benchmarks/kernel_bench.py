"""Kernel microbenchmarks: interpret-mode correctness timing is meaningless
for TPU perf, so we report (a) oracle wall-time on CPU as a sanity number
and (b) the analytic VMEM working set + HBM traffic per kernel block, which
is what the TPU schedule is designed around.

The gradient section covers the paper-scale GD hot loop (U in {256, 625,
1250}, M=250): one value_and_grad step of the summed user rates, einsum vs
the custom_vjp Pallas kernel. The einsum backward materializes pairwise
(U, V, M) temporaries; the GATHER-FREE kernel path consumes the raw
(U, N, M) channel state (AP selection + same_cell folded in-kernel via the
AP one-hot), so its per-grad-step data at rest is O(U*N*M) -- the N-sweep
rows quantify that against the previous layout's ~3.2 GB g_vu gather +
block-padded copy (BENCH_1) and against einsum's compute temporaries.
Every noma row carries kernel_layout/blocks metadata in BENCH_<n>.json so
the trajectory across kernel redesigns stays comparable. Measured CPU
times are emitted where feasible (einsum at U=64 and -- full mode only --
U=256 with M=250; interpret-mode kernel at the U=64 smoke size, swept over
the AP count); the paper-scale rows are analytic. --quick trims the
measured rows to the smoke sizes for CI but keeps a 2-point N-sweep.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import channel, make_env
from repro.kernels import ops, ref
from repro.kernels.noma_rates import vmem_block_bytes
from benchmarks.paper_common import emit

# VPU-aligned tiles of the deployed schedule (DESIGN.md Sec. 4).
BU = BV = 8
BM = 128
# Tiles of the measured interpret-mode grad rows (coarser: interpret mode
# pays per-block Python dispatch, so the smoke sizes use bigger blocks).
MEAS_BLOCKS = (32, 32, 128)
# Metadata stamped on the noma rows of the JSON artifact: BENCH_1 recorded
# the gathered (V, U, M) layout, BENCH_2+ the gather-free raw-gain layout.
# Rows measured/derived at other tile sizes carry their own blocks entry;
# einsum rows (no kernel involved) carry layout=einsum and no blocks.
NOMA_KERNEL_META = {"kernel_layout": "gather_free", "blocks": list((BU, BV, BM))}
NOMA_MEAS_META = {"kernel_layout": "gather_free", "blocks": list(MEAS_BLOCKS)}
NOMA_EINSUM_META = {"kernel_layout": "einsum"}
NOMA_GATHERED_META = {"kernel_layout": "gathered", "blocks": list((BU, BV, BM))}


def _time(f, *args, n=3):
    jax.block_until_ready(f(*args))          # warm up once, block on all outputs
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n * 1e6


def _grad_step(env, backend, blocks=None):
    """jitted value_and_grad of the summed rates -- one GD hot-loop step."""
    if blocks is None:
        def loss(beta, p_up, p_dn):
            r_up = channel.uplink_rates(env, beta, p_up, backend=backend)
            r_dn = channel.downlink_rates(env, beta, p_dn, backend=backend)
            return jnp.sum(r_up) + jnp.sum(r_dn)
    else:
        # Same loss as the einsum branch, assembled by the kernel-backed
        # rate wrappers so the two rows time gradients of one function.
        # The wrappers are unjitted (PR 5): this jit is the only one.
        bu, bv, bm = blocks

        def loss(beta, p_up, p_dn):
            r_up = ops.noma_uplink_rates(env, beta, p_up, interpret=True,
                                         block_u=bu, block_v=bv, block_m=bm)
            r_dn = ops.noma_downlink_rates(env, beta, p_dn, interpret=True,
                                           block_u=bu, block_v=bv, block_m=bm)
            return jnp.sum(r_up) + jnp.sum(r_dn)

    return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))


def _kernel_peak_bytes(u: int, n: int, m: int) -> float:
    """Gather-free per-grad-step data at rest: the raw fp32 gains for both
    links (the custom_vjp residuals alias them -- nothing pairwise is
    saved) + the AP one-hot + the own-gain maps. No (V, U, M) gather, no
    block-padded copy: boundary blocks are masked in-kernel."""
    raw_gains = 2.0 * u * n * m * 4
    onehot = float(u) * n * 4
    own = 2.0 * u * m * 4
    return raw_gains + onehot + own


def _grad_rows(quick: bool):
    """Returns (einsum_rows, kernel_rows, gathered_rows, measured_rows) so
    each group carries accurate layout/blocks metadata in the artifact."""
    einsum_rows, kernel_rows, gathered_rows, meas_rows = [], [], [], []
    m_paper = 250
    # Analytic peak-memory at paper scale: the einsum grad step builds the
    # pairwise mask, its masked product, and the transposed backward product
    # as full (U, V, M) fp32 temporaries (one uplink + one downlink set).
    # The gather-free kernel path holds only the O(U*N*M) raw channel state
    # -- swept over the AP count N, since N (not U) now scales the gain
    # operand -- streamed through VMEM in both directions.
    for u in (256, 625, 1250):
        uvm = float(u) * u * m_paper * 4
        einsum_rows.append((f"noma_grad:einsum_peak_bytes:u{u}", 3 * uvm,
                            "(U,V,M) fp32 mask+product+bwd temporaries per link"))
        for n in (1, 4, 16, 64):
            kernel_rows.append((f"noma_grad:kernel_peak_bytes:u{u}_n{n}",
                                _kernel_peak_bytes(u, n, m_paper),
                                "raw (U,N,M) gains both links + one-hot + own; "
                                "no gather, no padded copy"))
    # The old gathered layout (BENCH_1 baseline) for the drop computation:
    # g_vu gather + its block-padded kernel copy at U=1250.
    u = 1250
    uvm = float(u) * u * m_paper * 4
    up = -(-u // BU) * BU
    uvm_pad = float(-(-u // BV) * BV) * up * (-(-m_paper // BM) * BM) * 4
    gathered_rows.append(("noma_grad:gathered_layout_peak_bytes:u1250",
                          uvm + uvm_pad,
                          "BENCH_1 layout: g_vu gather + block-padded copy "
                          "(retired by the gather-free kernels)"))
    gathered_rows.append(("noma_grad:data_at_rest_drop_ratio:u1250_n16",
                          (uvm + uvm_pad) / _kernel_peak_bytes(u, 16, m_paper),
                          "gathered ~3.2GB over gather-free O(U*N*M) at N=16"))

    # Per-block VMEM budget incl. the raw-gain term: linear in N, so the
    # N-sweep shows how far the AP count can grow before a block alone
    # threatens the ~16MB VMEM ceiling. Reported per (direction, link) --
    # the max over the kernels each direction launches; the composed paths
    # (uplink fwd, downlink bwd) split the gain into a separate per-AP
    # kernel, the fused paths (downlink fwd, uplink bwd) carry it in the
    # pairwise kernel itself.
    for n in (1, 4, 16, 64):
        for direction in ("fwd", "bwd"):
            for is_up, link in ((True, "up"), (False, "dn")):
                b = vmem_block_bytes(BU, BV, BM, n, direction, uplink=is_up)
                fused = (direction == "fwd") != is_up
                kernel_rows.append(
                    (f"noma_grad:{direction}_{link}_vmem_block_bytes:n{n}",
                     float(b),
                     f"(BU,BV,BM)=({BU},{BV},{BM}), N={n}, "
                     f"{'fused' if fused else 'per-AP composed'} path"))

    # Measured grad-step wall time. The einsum step is real CPU XLA (same
    # env shapes as BENCH_1: N=4 at the U=64 smoke size, N=8 at U=256); the
    # kernel step runs the Pallas bodies in interpret mode, so it is a
    # correctness/dispatch sanity number, not a perf claim. The kernel row
    # is swept over N (the gain-block dimension of the gather-free layout).
    meas = [(64, 4, 64)] if quick else [(64, 4, 64), (256, 8, 250)]
    n_sweep = (1, 4) if quick else (1, 4, 16)
    for u, n_aps_e, m in meas:
        beta = jnp.ones((u, m)) / m
        p_up = jnp.full((u,), 0.2)
        p_dn = jnp.full((u,), 1.0)
        reps = 1 if u >= 256 else 2
        env = make_env(jax.random.PRNGKey(5), u, n_aps_e, m)
        us_e = _time(_grad_step(env, "einsum"), beta, p_up, p_dn, n=reps)
        einsum_rows.append((f"noma_grad:einsum_step_us:u{u}_m{m}", us_e,
                            "CPU XLA value_and_grad, both links"))
        if u <= 64:
            for n_aps in n_sweep:
                env_n = make_env(jax.random.PRNGKey(5), u, n_aps, m)
                us_k = _time(_grad_step(env_n, None, blocks=MEAS_BLOCKS),
                             beta, p_up, p_dn, n=reps)
                meas_rows.append(
                    (f"noma_grad:kernel_step_us:u{u}_m{m}_n{n_aps}", us_k,
                     "CPU interpret custom_vjp (sanity, not perf)"))
    return einsum_rows, kernel_rows, gathered_rows, meas_rows


def run(quick: bool = False):
    rows = []
    # flash attention: block VMEM working set
    bq = bk = 128
    hd = 128
    vmem = (bq * hd + 2 * bk * hd) * 2 + (bq * hd + 2 * bq) * 4 + bq * bk * 4
    rows.append(("flash_attention:vmem_block_bytes", float(vmem),
                 f"bq={bq},bk={bk},hd={hd}: fits 16MB VMEM"))
    rows.append(("flash_attention:arith_intensity",
                 (2 * bq * bk * hd * 2) / float(vmem),
                 "FLOPs/byte per block >> 0.24 (v5e ridge) -> MXU-bound"))
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), jnp.bfloat16)
    us = _time(lambda a, b, c: ops.flash_attention(a, b, c, interpret=True,
                                                   block_q=64, block_k=64),
               q, k, v, n=2)
    rows.append(("flash_attention:interpret_us", us, "CPU interpret (sanity)"))

    # rg_lru
    la = -jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4, 512, 128)))
    b = jax.random.normal(jax.random.PRNGKey(4), (4, 512, 128))
    us = _time(lambda x, y: ops.rg_lru(x, y, interpret=True), la, b, n=2)
    rows.append(("rg_lru:interpret_us", us, "CPU interpret (sanity)"))
    rows.append(("rg_lru:vmem_block_bytes",
                 float((8 * 256 * 128 * 2 + 8 * 128) * 4),
                 "(bb,bs,bw)=(8,256,128) fp32 in+out+carry"))
    emit("kernel_bench", rows)

    # noma rates at paper-relevant tile (jitted entry: direct eager caller)
    noma_rows = []
    env = make_env(jax.random.PRNGKey(5), 16, 4, 8)
    beta = jnp.ones((16, 8)) / 8
    p = jnp.full((16,), 0.2)
    us = _time(lambda e, bb, pp: ops.noma_uplink_rates_jit(e, bb, pp,
                                                           interpret=True),
               env, beta, p, n=2)
    noma_rows.append(("noma_rates:interpret_us", us, "CPU interpret (sanity)"))
    noma_rows.append(("noma_rates:paper_scale_uvm_tensor_GB",
                      1250 * 1250 * 250 * 4 / 1e9,
                      "naive (U,V,M) fp32 the kernel avoids materializing"))

    einsum_rows, kernel_rows, gathered_rows, meas_rows = _grad_rows(quick)
    emit("kernel_bench", noma_rows + kernel_rows, meta=NOMA_KERNEL_META)
    emit("kernel_bench", gathered_rows, meta=NOMA_GATHERED_META)
    emit("kernel_bench", meas_rows, meta=NOMA_MEAS_META)
    emit("kernel_bench", einsum_rows, meta=NOMA_EINSUM_META)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-size measured rows only (CI)")
    run(quick=ap.parse_args().quick)
