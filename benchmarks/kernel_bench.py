"""Kernel microbenchmarks: interpret-mode correctness timing is meaningless
for TPU perf, so we report (a) oracle wall-time on CPU as a sanity number
and (b) the analytic VMEM working set + HBM traffic per kernel block, which
is what the TPU schedule is designed around.

The gradient section covers the paper-scale GD hot loop (U in {256, 625,
1250}, M=250): one value_and_grad step of the summed user rates, einsum vs
the custom_vjp Pallas kernels. The einsum backward materializes pairwise
(U, V, M) temporaries; the CELL-BLOCK kernel path consumes the raw
(U, N, M) channel state plus the int32 AP ids (AP selection + same_cell
are in-kernel id compares), N-tiles every gain-carrying accumulator (per-
block VMEM is a function of BN only -- the large-N sweep shows N=4096
fitting the exact budget N=16 uses), and with a CellLayout restricts the
intra/SIC grid to same-cell block-diagonal tiles (sum-of-cell-sizes^2
pairwise work, not U^2).

Timing discipline: _time reports best-of-n AND median-of-n with the
spread, and every measured row carries the full stats as row metadata --
autotune selections are made off the median, never a single noisy minimum.
The (BU, BV, BM, BN) autotune sweep times the interpret-mode grad step
over AUTOTUNE_BLOCKS (2 candidates under --quick), records the whole
tuning table in the artifact, and stamps the selected row. ap_mode (iota
id-compare vs streamed one-hot MXU contraction) is profiled the same way.
Every noma row carries kernel_layout/blocks metadata in BENCH_<n>.json so
the trajectory across kernel redesigns stays comparable (BENCH_1 gathered,
BENCH_2 gather_free, BENCH_3+ cell_block). --quick trims the measured rows
to the smoke sizes for CI but keeps a 2-point N-sweep and a 2-point
autotune sweep.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import analysis
from repro.core import channel, make_env
from repro.kernels import build_cell_layout, ops
from repro.kernels.noma_rates import (AUTOTUNE_BLOCKS, VMEM_CEILING_BYTES,
                                      dense_tile_count, max_vmem_block_bytes,
                                      vmem_block_bytes)
from benchmarks.paper_common import audit_meta, emit

# VPU-aligned tiles of the deployed schedule (DESIGN.md Sec. 4).
BU = BV = 8
BM = 128
BN = 8
# Tiles of the measured interpret-mode grad rows (coarser: interpret mode
# pays per-block Python dispatch, so the smoke sizes use bigger blocks).
MEAS_BLOCKS = (32, 32, 128, 8)
# Metadata stamped on the noma rows of the JSON artifact: BENCH_1 recorded
# the gathered (V, U, M) layout, BENCH_2 the gather-free one-hot layout,
# BENCH_3+ the cell-block layout (N-tiled accumulators + block-diagonal
# intra tiles from a CellLayout). Rows measured/derived at other tile sizes
# carry their own blocks entry; einsum rows (no kernel involved) carry
# layout=einsum and no blocks.
NOMA_KERNEL_META = {"kernel_layout": "cell_block",
                    "blocks": list((BU, BV, BM, BN))}
NOMA_MEAS_META = {"kernel_layout": "cell_block", "blocks": list(MEAS_BLOCKS)}
NOMA_EINSUM_META = {"kernel_layout": "einsum"}
NOMA_GATHERED_META = {"kernel_layout": "gathered", "blocks": list((BU, BV, BM))}

# Smoke size for the measured interpret-mode sweeps (autotune + ap_mode):
# big enough that block sizes change the schedule, small enough that the
# per-block Python dispatch of interpret mode stays tractable on CPU.
SMOKE_U, SMOKE_N, SMOKE_M = 48, 6, 32


def _time(f, *args, n=3):
    """best/median/spread timing stats over n timed reps (after one
    blocking warm-up that absorbs compilation). spread_pct is
    (worst - best) / median: the autotuner gates on medians and records
    the spread so a single noisy minimum can never pick the winner."""
    jax.block_until_ready(f(*args))          # warm up once, block on all outputs
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    best = times[0]
    median = times[len(times) // 2]
    return {"best_us": best, "median_us": median,
            "spread_pct": 100.0 * (times[-1] - best) / max(median, 1e-9),
            "reps": n}


def _stats_meta(stats):
    """Per-row metadata for a measured row: the full timing stats."""
    return {k: (round(v, 3) if isinstance(v, float) else v)
            for k, v in stats.items()}


def _grad_step(env, backend, blocks=None, layout=None, ap_mode="iota"):
    """jitted value_and_grad of the summed rates -- one GD hot-loop step."""
    if blocks is None:
        def loss(beta, p_up, p_dn):
            r_up = channel.uplink_rates(env, beta, p_up, backend=backend)
            r_dn = channel.downlink_rates(env, beta, p_dn, backend=backend)
            return jnp.sum(r_up) + jnp.sum(r_dn)
    else:
        # Same loss as the einsum branch, assembled by the kernel-backed
        # rate wrappers so the two rows time gradients of one function.
        # The wrappers are unjitted (PR 5): this jit is the only one.
        bu, bv, bm, bn = blocks

        def loss(beta, p_up, p_dn):
            r_up = ops.noma_uplink_rates(env, beta, p_up, interpret=True,
                                         block_u=bu, block_v=bv, block_m=bm,
                                         block_n=bn, layout=layout,
                                         ap_mode=ap_mode)
            r_dn = ops.noma_downlink_rates(env, beta, p_dn, interpret=True,
                                           block_u=bu, block_v=bv,
                                           block_m=bm, block_n=bn,
                                           layout=layout, ap_mode=ap_mode)
            return jnp.sum(r_up) + jnp.sum(r_dn)

    return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))


def _kernel_peak_bytes(u: int, n: int, m: int) -> float:
    """Cell-block per-grad-step data at rest: the raw fp32 gains for both
    links (the custom_vjp residuals alias them -- nothing pairwise is
    saved) + the int32 AP ids (no O(U*N) one-hot under ap_mode=iota) + the
    own-gain maps. No (V, U, M) gather, no block-padded copy: boundary
    blocks are masked in-kernel."""
    raw_gains = 2.0 * u * n * m * 4
    ap_ids = float(u) * 4
    own = 2.0 * u * m * 4
    return raw_gains + ap_ids + own


def _autotune_rows(quick: bool):
    """Measured (BU, BV, BM, BN) sweep: interpret-mode grad step per
    candidate at the smoke size, cell-block layout. Returns the rows (one
    per candidate, full timing stats as row meta, vmem-filtered) plus a
    selected-winner row -- the artifact carries the whole tuning table, so
    future PRs can compare like-for-like before re-tuning."""
    env = make_env(jax.random.PRNGKey(11), SMOKE_U, SMOKE_N, SMOKE_M)
    beta = jnp.ones((SMOKE_U, SMOKE_M)) / SMOKE_M
    p_up = jnp.full((SMOKE_U,), 0.2)
    p_dn = jnp.full((SMOKE_U,), 1.0)
    candidates = AUTOTUNE_BLOCKS[:2] if quick else AUTOTUNE_BLOCKS
    rows, table = [], []
    for blocks in candidates:
        bu, bv, bm, bn = blocks
        vmem = max_vmem_block_bytes(bu, bv, bm, bn, n_aps=SMOKE_N)
        if vmem >= VMEM_CEILING_BYTES:
            rows.append((f"noma_autotune:skipped:bu{bu}_bv{bv}_bm{bm}_bn{bn}",
                         float(vmem), "over VMEM ceiling, not timed",
                         {"blocks": list(blocks)}))
            continue
        layout = build_cell_layout(env, block_u=bu, block_v=bv)
        step = _grad_step(env, None, blocks=blocks, layout=layout)
        # Every timed candidate is audited against the memory-model rules
        # before it can win: the traced program must keep each kernel block
        # under the VMEM budget and launch exactly the layout's tile list.
        report = analysis.audit(
            step, beta, p_up, p_dn,
            rules=[analysis.VmemCeiling(),
                   analysis.SparseGrid(layout.n_tiles)],
            label=f"autotune:bu{bu}_bv{bv}_bm{bm}_bn{bn}")
        stats = _time(step, beta, p_up, p_dn, n=2 if quick else 3)
        meta = {"blocks": list(blocks), "vmem_block_bytes": float(vmem),
                "audit": audit_meta(report), **_stats_meta(stats)}
        rows.append((f"noma_autotune:step_us:bu{bu}_bv{bv}_bm{bm}_bn{bn}",
                     stats["median_us"],
                     f"interpret grad step, U={SMOKE_U} N={SMOKE_N} "
                     f"M={SMOKE_M} (median of {stats['reps']})", meta))
        if report.ok:   # a rule-violating candidate can never be the winner
            table.append((stats["median_us"], blocks, meta))
    if table:
        best_us, best_blocks, best_meta = min(table, key=lambda t: t[0])
        rows.append(("noma_autotune:selected_us", best_us,
                     f"winner {best_blocks} by median-of-n",
                     {**best_meta, "selected": True}))
    return rows


def _ap_mode_rows(quick: bool):
    """ap_mode profile at the smoke size: 'iota' derives the AP one-hot
    block in-kernel from the int32 ids (no O(U*N) HBM operand at all --
    the SMEM-resident scalar-prefetch tile lists already index every block
    load); 'onehot' streams the PR-5 style (U, N) one-hot for the MXU
    contraction layout. Both stay available behind the kernel flag; the
    measured winner is stamped so the default is an artifact-recorded
    choice, not folklore."""
    env = make_env(jax.random.PRNGKey(12), SMOKE_U, SMOKE_N, SMOKE_M)
    layout = build_cell_layout(env, block_u=MEAS_BLOCKS[0],
                               block_v=MEAS_BLOCKS[1])
    beta = jnp.ones((SMOKE_U, SMOKE_M)) / SMOKE_M
    p_up = jnp.full((SMOKE_U,), 0.2)
    p_dn = jnp.full((SMOKE_U,), 1.0)
    rows, timed = [], {}
    for mode in ("iota", "onehot"):
        stats = _time(_grad_step(env, None, blocks=MEAS_BLOCKS,
                                 layout=layout, ap_mode=mode),
                      beta, p_up, p_dn, n=2 if quick else 3)
        timed[mode] = stats["median_us"]
        rows.append((f"noma_ap_mode:step_us:{mode}", stats["median_us"],
                     "interpret grad step (median)", _stats_meta(stats)))
    winner = min(timed, key=timed.get)
    rows.append((f"noma_ap_mode:selected:{winner}", timed[winner],
                 "kernel-flag default candidate; iota also removes the "
                 "O(U*N) one-hot from HBM entirely",
                 {"selected": True, "ap_mode": winner}))
    return rows


def _grad_rows(quick: bool):
    """Returns (einsum_rows, kernel_rows, gathered_rows, measured_rows) so
    each group carries accurate layout/blocks metadata in the artifact."""
    einsum_rows, kernel_rows, gathered_rows, meas_rows = [], [], [], []
    m_paper = 250
    # Analytic peak-memory at paper scale: the einsum grad step builds the
    # pairwise mask, its masked product, and the transposed backward product
    # as full (U, V, M) fp32 temporaries (one uplink + one downlink set).
    # The cell-block kernel path holds only the O(U*N*M) raw channel state
    # -- swept over the AP count N, since N (not U) now scales the gain
    # operand -- streamed through VMEM in both directions.
    for u in (256, 625, 1250):
        uvm = float(u) * u * m_paper * 4
        einsum_rows.append((f"noma_grad:einsum_peak_bytes:u{u}", 3 * uvm,
                            "(U,V,M) fp32 mask+product+bwd temporaries per link"))
        for n in (1, 4, 16, 64):
            kernel_rows.append((f"noma_grad:kernel_peak_bytes:u{u}_n{n}",
                                _kernel_peak_bytes(u, n, m_paper),
                                "raw (U,N,M) gains both links + int32 ap ids "
                                "+ own; no gather, no one-hot, no padded copy"))
    # The old gathered layout (BENCH_1 baseline) for the drop computation:
    # g_vu gather + its block-padded kernel copy at U=1250.
    u = 1250
    uvm = float(u) * u * m_paper * 4
    up = -(-u // BU) * BU
    uvm_pad = float(-(-u // BV) * BV) * up * (-(-m_paper // BM) * BM) * 4
    gathered_rows.append(("noma_grad:gathered_layout_peak_bytes:u1250",
                          uvm + uvm_pad,
                          "BENCH_1 layout: g_vu gather + block-padded copy "
                          "(retired by the gather-free kernels)"))
    gathered_rows.append(("noma_grad:data_at_rest_drop_ratio:u1250_n16",
                          (uvm + uvm_pad) / _kernel_peak_bytes(u, 16, m_paper),
                          "gathered ~3.2GB over cell-block O(U*N*M) at N=16"))

    # Per-block VMEM budget: with the N-tiled accumulators every term is a
    # function of the BLOCK sizes only, so the large-N sweep is flat --
    # N=4096 fits the exact budget N=16 uses (n_aps only clamps BN). This
    # is the massive-connectivity headline: the AP count stopped being a
    # VMEM term at all (the BENCH_2 budget grew ~4 KiB per AP).
    for n in (16, 64, 256, 1024, 4096):
        for direction in ("fwd", "bwd"):
            for is_up, link in ((True, "up"), (False, "dn")):
                b = vmem_block_bytes(BU, BV, BM, BN, n_aps=n,
                                     direction=direction, uplink=is_up)
                kernel_rows.append(
                    (f"noma_grad:{direction}_{link}_vmem_block_bytes:n{n}",
                     float(b),
                     f"(BU,BV,BM,BN)=({BU},{BV},{BM},{BN}); O(BN) budget, "
                     "independent of total N"))

    # Measured grad-step wall time. The einsum step is real CPU XLA (same
    # env shapes as BENCH_1: N=4 at the U=64 smoke size, N=8 at U=256); the
    # kernel step runs the Pallas bodies in interpret mode, so it is a
    # correctness/dispatch sanity number, not a perf claim. The kernel row
    # is swept over N (the gain-block dimension); non-divisible N=13
    # exercises the iota-masked boundary N block in a measured row.
    meas = [(64, 4, 64)] if quick else [(64, 4, 64), (256, 8, 250)]
    n_sweep = (1, 4) if quick else (1, 4, 13, 16)
    for u, n_aps_e, m in meas:
        beta = jnp.ones((u, m)) / m
        p_up = jnp.full((u,), 0.2)
        p_dn = jnp.full((u,), 1.0)
        reps = 1 if u >= 256 else 2
        env = make_env(jax.random.PRNGKey(5), u, n_aps_e, m)
        st_e = _time(_grad_step(env, "einsum"), beta, p_up, p_dn, n=reps)
        einsum_rows.append((f"noma_grad:einsum_step_us:u{u}_m{m}",
                            st_e["median_us"],
                            "CPU XLA value_and_grad, both links (median)",
                            _stats_meta(st_e)))
        if u <= 64:
            for n_aps in n_sweep:
                env_n = make_env(jax.random.PRNGKey(5), u, n_aps, m)
                layout = build_cell_layout(env_n, block_u=MEAS_BLOCKS[0],
                                           block_v=MEAS_BLOCKS[1])
                st_k = _time(_grad_step(env_n, None, blocks=MEAS_BLOCKS,
                                        layout=layout),
                             beta, p_up, p_dn, n=reps)
                meas_rows.append(
                    (f"noma_grad:kernel_step_us:u{u}_m{m}_n{n_aps}",
                     st_k["median_us"],
                     "CPU interpret custom_vjp, cell-block layout "
                     "(sanity, not perf; median)",
                     {**_stats_meta(st_k), "n_tiles": layout.n_tiles}))
    return einsum_rows, kernel_rows, gathered_rows, meas_rows


def run(quick: bool = False):
    rows = []
    # flash attention: block VMEM working set
    bq = bk = 128
    hd = 128
    vmem = (bq * hd + 2 * bk * hd) * 2 + (bq * hd + 2 * bq) * 4 + bq * bk * 4
    rows.append(("flash_attention:vmem_block_bytes", float(vmem),
                 f"bq={bq},bk={bk},hd={hd}: fits 16MB VMEM"))
    rows.append(("flash_attention:arith_intensity",
                 (2 * bq * bk * hd * 2) / float(vmem),
                 "FLOPs/byte per block >> 0.24 (v5e ridge) -> MXU-bound"))
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), jnp.bfloat16)
    st = _time(lambda a, b, c: ops.flash_attention(a, b, c, interpret=True,
                                                   block_q=64, block_k=64),
               q, k, v, n=2)
    rows.append(("flash_attention:interpret_us", st["median_us"],
                 "CPU interpret (sanity)", _stats_meta(st)))

    # rg_lru
    la = -jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4, 512, 128)))
    b = jax.random.normal(jax.random.PRNGKey(4), (4, 512, 128))
    st = _time(lambda x, y: ops.rg_lru(x, y, interpret=True), la, b, n=2)
    rows.append(("rg_lru:interpret_us", st["median_us"],
                 "CPU interpret (sanity)", _stats_meta(st)))
    rows.append(("rg_lru:vmem_block_bytes",
                 float((8 * 256 * 128 * 2 + 8 * 128) * 4),
                 "(bb,bs,bw)=(8,256,128) fp32 in+out+carry"))
    emit("kernel_bench", rows)

    # noma rates at paper-relevant tile (jitted entry: direct eager caller)
    noma_rows = []
    env = make_env(jax.random.PRNGKey(5), 16, 4, 8)
    beta = jnp.ones((16, 8)) / 8
    p = jnp.full((16,), 0.2)
    rates_fn = lambda e, bb, pp: ops.noma_uplink_rates_jit(e, bb, pp,  # noqa: E731
                                                           interpret=True)
    st = _time(rates_fn, env, beta, p, n=2)
    noma_rows.append(("noma_rates:interpret_us", st["median_us"],
                      "CPU interpret (sanity)", _stats_meta(st)))
    noma_rows.append(("noma_rates:paper_scale_uvm_tensor_GB",
                      1250 * 1250 * 250 * 4 / 1e9,
                      "naive (U,V,M) fp32 the kernel avoids materializing"))
    # The artifact's noma rows carry the invariant verdict for the program
    # they measure (dense schedule: layout=None -> dense_tile_count tiles).
    noma_audit = audit_meta(analysis.audit(
        rates_fn, env, beta, p,
        rules=[analysis.VmemCeiling(),
               analysis.SparseGrid(dense_tile_count(16, 16))],
        label="noma_rates_jit"))

    einsum_rows, kernel_rows, gathered_rows, meas_rows = _grad_rows(quick)
    emit("kernel_bench", noma_rows + kernel_rows, meta=NOMA_KERNEL_META,
         audit=noma_audit)
    emit("kernel_bench", gathered_rows, meta=NOMA_GATHERED_META)
    emit("kernel_bench", meas_rows, meta=NOMA_MEAS_META)
    emit("kernel_bench", einsum_rows, meta=NOMA_EINSUM_META)
    emit("kernel_bench", _autotune_rows(quick), meta=NOMA_KERNEL_META)
    emit("kernel_bench", _ap_mode_rows(quick), meta=NOMA_MEAS_META)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-size measured rows only (CI)")
    run(quick=ap.parse_args().quick)
