"""Kernel microbenchmarks: interpret-mode correctness timing is meaningless
for TPU perf, so we report (a) oracle wall-time on CPU as a sanity number
and (b) the analytic VMEM working set + HBM traffic per kernel block, which
is what the TPU schedule is designed around.

The gradient section covers the paper-scale GD hot loop (U in {256, 625,
1250}, M=250): one value_and_grad step of the summed user rates, einsum vs
the custom_vjp Pallas kernel. The einsum backward materializes pairwise
(U, V, M) temporaries; the kernel path streams them block-by-block in both
directions, so its analytic peak is the HBM-resident g_vu input alone.
Measured CPU times are emitted where feasible (einsum at U=64 and -- full
mode only -- U=256 with M=250; interpret-mode kernel only at the U=64
smoke size); the three paper-scale rows are analytic. --quick trims to
the smoke size for CI.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import channel, make_env
from repro.kernels import ops, ref
from repro.kernels.noma_rates import vmem_block_bytes
from benchmarks.paper_common import emit

# VPU-aligned tiles of the deployed schedule (DESIGN.md Sec. 4).
BU = BV = 8
BM = 128


def _time(f, *args, n=3):
    jax.block_until_ready(f(*args))          # warm up once, block on all outputs
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n * 1e6


def _grad_step(env, backend, blocks=None):
    """jitted value_and_grad of the summed rates -- one GD hot-loop step."""
    if blocks is None:
        def loss(beta, p_up, p_dn):
            r_up = channel.uplink_rates(env, beta, p_up, backend=backend)
            r_dn = channel.downlink_rates(env, beta, p_dn, backend=backend)
            return jnp.sum(r_up) + jnp.sum(r_dn)
    else:
        # Same loss as the einsum branch, assembled by the kernel-backed
        # rate wrappers so the two rows time gradients of one function.
        bu, bv, bm = blocks

        def loss(beta, p_up, p_dn):
            r_up = ops.noma_uplink_rates(env, beta, p_up, interpret=True,
                                         block_u=bu, block_v=bv, block_m=bm)
            r_dn = ops.noma_downlink_rates(env, beta, p_dn, interpret=True,
                                           block_u=bu, block_v=bv, block_m=bm)
            return jnp.sum(r_up) + jnp.sum(r_dn)

    return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))


def _grad_rows(quick: bool):
    rows = []
    m_paper = 250
    # Analytic peak-memory at paper scale: the einsum grad step builds the
    # pairwise mask, its masked product, and the transposed backward product
    # as full (U, V, M) fp32 temporaries (one uplink + one downlink set);
    # the kernel path's pairwise-sized buffers are the HBM-resident g_vu
    # gather plus its block-padded copy (paper dims are not block multiples;
    # XLA may fuse gather+pad into one buffer, so 2x is the conservative
    # bound) -- streamed through VMEM in both directions, never a pairwise
    # compute temporary.
    for u in (256, 625, 1250):
        uvm = float(u) * u * m_paper * 4
        up = -(-u // BU) * BU
        uvm_pad = float(-(-u // BV) * BV) * up * (-(-m_paper // BM) * BM) * 4
        rows.append((f"noma_grad:einsum_peak_bytes:u{u}", 3 * uvm,
                     "(U,V,M) fp32 mask+product+bwd temporaries per link"))
        rows.append((f"noma_grad:kernel_peak_bytes:u{u}", uvm + uvm_pad,
                     "g_vu gather + block-padded kernel copy; no pairwise "
                     "compute temporary"))
    fwd = vmem_block_bytes(BU, BV, BM, "fwd")
    bwd = vmem_block_bytes(BU, BV, BM, "bwd")
    rows.append(("noma_grad:fwd_vmem_block_bytes", float(fwd),
                 f"(BU,BV,BM)=({BU},{BV},{BM}) inputs+scratch+out, fp32"))
    rows.append(("noma_grad:bwd_vmem_block_bytes", float(bwd),
                 f"backward block <= forward budget: {bwd} <= {fwd}"))
    assert bwd <= fwd, (bwd, fwd)

    # Measured grad-step wall time. The einsum step is real CPU XLA; the
    # kernel step runs the Pallas bodies in interpret mode, so it is a
    # correctness/dispatch sanity number, not a perf claim.
    meas = [(64, 4, 64)] if quick else [(64, 4, 64), (256, 8, 250)]
    for u, n_aps, m in meas:
        env = make_env(jax.random.PRNGKey(5), u, n_aps, m)
        beta = jnp.ones((u, m)) / m
        p_up = jnp.full((u,), 0.2)
        p_dn = jnp.full((u,), 1.0)
        reps = 1 if u >= 256 else 2
        us_e = _time(_grad_step(env, "einsum"), beta, p_up, p_dn, n=reps)
        rows.append((f"noma_grad:einsum_step_us:u{u}_m{m}", us_e,
                     "CPU XLA value_and_grad, both links"))
        if u <= 64:
            us_k = _time(_grad_step(env, None, blocks=(32, 32, 128)),
                         beta, p_up, p_dn, n=reps)
            rows.append((f"noma_grad:kernel_step_us:u{u}_m{m}", us_k,
                         "CPU interpret custom_vjp (sanity, not perf)"))
    return rows


def run(quick: bool = False):
    rows = []
    # flash attention: block VMEM working set
    bq = bk = 128
    hd = 128
    vmem = (bq * hd + 2 * bk * hd) * 2 + (bq * hd + 2 * bq) * 4 + bq * bk * 4
    rows.append(("flash_attention:vmem_block_bytes", float(vmem),
                 f"bq={bq},bk={bk},hd={hd}: fits 16MB VMEM"))
    rows.append(("flash_attention:arith_intensity",
                 (2 * bq * bk * hd * 2) / float(vmem),
                 "FLOPs/byte per block >> 0.24 (v5e ridge) -> MXU-bound"))
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), jnp.bfloat16)
    us = _time(lambda a, b, c: ops.flash_attention(a, b, c, interpret=True,
                                                   block_q=64, block_k=64),
               q, k, v, n=2)
    rows.append(("flash_attention:interpret_us", us, "CPU interpret (sanity)"))

    # rg_lru
    la = -jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4, 512, 128)))
    b = jax.random.normal(jax.random.PRNGKey(4), (4, 512, 128))
    us = _time(lambda x, y: ops.rg_lru(x, y, interpret=True), la, b, n=2)
    rows.append(("rg_lru:interpret_us", us, "CPU interpret (sanity)"))
    rows.append(("rg_lru:vmem_block_bytes",
                 float((8 * 256 * 128 * 2 + 8 * 128) * 4),
                 "(bb,bs,bw)=(8,256,128) fp32 in+out+carry"))

    # noma rates at paper-relevant tile
    env = make_env(jax.random.PRNGKey(5), 16, 4, 8)
    beta = jnp.ones((16, 8)) / 8
    p = jnp.full((16,), 0.2)
    us = _time(lambda e, bb, pp: ops.noma_uplink_rates(e, bb, pp,
                                                       interpret=True),
               env, beta, p, n=2)
    rows.append(("noma_rates:interpret_us", us, "CPU interpret (sanity)"))
    rows.append(("noma_rates:paper_scale_uvm_tensor_GB",
                 1250 * 1250 * 250 * 4 / 1e9,
                 "naive (U,V,M) fp32 the kernel avoids materializing"))

    rows.extend(_grad_rows(quick))
    emit("kernel_bench", rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-size measured rows only (CI)")
    run(quick=ap.parse_args().quick)
