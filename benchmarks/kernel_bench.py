"""Kernel microbenchmarks: interpret-mode correctness timing is meaningless
for TPU perf, so we report (a) oracle wall-time on CPU as a sanity number
and (b) the analytic VMEM working set + arithmetic intensity per kernel
block, which is what the TPU schedule is designed around."""
import time

import jax
import jax.numpy as jnp

from repro.core import channel, make_env
from repro.kernels import ops, ref
from benchmarks.paper_common import emit


def _time(f, *args, n=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n * 1e6


def run():
    rows = []
    # flash attention: block VMEM working set
    bq = bk = 128
    hd = 128
    vmem = (bq * hd + 2 * bk * hd) * 2 + (bq * hd + 2 * bq) * 4 + bq * bk * 4
    rows.append(("flash_attention:vmem_block_bytes", float(vmem),
                 f"bq={bq},bk={bk},hd={hd}: fits 16MB VMEM"))
    rows.append(("flash_attention:arith_intensity",
                 (2 * bq * bk * hd * 2) / float(vmem),
                 "FLOPs/byte per block >> 0.24 (v5e ridge) -> MXU-bound"))
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), jnp.bfloat16)
    us = _time(lambda a, b, c: ops.flash_attention(a, b, c, interpret=True,
                                                   block_q=64, block_k=64),
               q, k, v, n=2)
    rows.append(("flash_attention:interpret_us", us, "CPU interpret (sanity)"))

    # rg_lru
    la = -jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4, 512, 128)))
    b = jax.random.normal(jax.random.PRNGKey(4), (4, 512, 128))
    us = _time(lambda x, y: ops.rg_lru(x, y, interpret=True), la, b, n=2)
    rows.append(("rg_lru:interpret_us", us, "CPU interpret (sanity)"))
    rows.append(("rg_lru:vmem_block_bytes",
                 float((8 * 256 * 128 * 2 + 8 * 128) * 4),
                 "(bb,bs,bw)=(8,256,128) fp32 in+out+carry"))

    # noma rates at paper-relevant tile
    env = make_env(jax.random.PRNGKey(5), 16, 4, 8)
    beta = jnp.ones((16, 8)) / 8
    p = jnp.full((16,), 0.2)
    us = _time(lambda e, bb, pp: ops.noma_uplink_rates(e, bb, pp,
                                                       interpret=True),
               env, beta, p, n=2)
    rows.append(("noma_rates:interpret_us", us, "CPU interpret (sanity)"))
    rows.append(("noma_rates:paper_scale_uvm_tensor_GB",
                 1250 * 1250 * 250 * 4 / 1e9,
                 "naive (U,V,M) fp32 the kernel avoids materializing"))
    emit("kernel_bench", rows)


if __name__ == "__main__":
    run()
