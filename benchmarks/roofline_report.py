"""Emit the roofline table rows (one per dry-run cell) in CSV form.
Requires artifacts/dryrun/*.json (python -m repro.launch.dryrun --all)."""
from repro.launch.roofline import load_cells
from benchmarks.paper_common import emit


def run():
    cells = load_cells("pod16x16")
    rows = []
    for c in cells:
        tag = f"{c['arch']}:{c['shape']}"
        if c.get("skipped"):
            rows.append((f"{tag}:skipped", 0.0, c["skipped"]))
            continue
        r = c.get("roofline")
        if not r:
            continue
        rows.append((f"{tag}:compute_s", r["compute_s"], ""))
        rows.append((f"{tag}:memory_s", r["memory_s"], ""))
        rows.append((f"{tag}:collective_s", r["collective_s"],
                     f"dominant={r['dominant']}"))
        rows.append((f"{tag}:model_vs_hlo", r["model_vs_hlo_flops"],
                     "useful-compute fraction"))
        rows.append((f"{tag}:peak_GiB", c["memory"]["peak_bytes_est"] / 2**30,
                     "per device"))
    if not rows:
        rows = [("no_artifacts", 0.0, "run python -m repro.launch.dryrun --all")]
    emit("roofline", rows)


if __name__ == "__main__":
    run()
