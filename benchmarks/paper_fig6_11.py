"""Fig.6-11: ECC-NOMA vs baselines under varying network conditions:
user density (Fig.6/9), number of subchannels (Fig.7/10), workload (Fig.8/11).
Normalization = Device-Only. VGG16 profile (the paper's largest chain)."""
import time

import jax.numpy as jnp

from repro.core import profiles
from benchmarks.paper_common import emit, mean_outcomes


def run():
    t0 = time.time()
    prof = profiles.vgg16()
    rows = []
    # Fig.6/9: user density sweep (users per AP: 4..24 with 3 APs)
    for density in (4, 8, 16, 24):
        acc = mean_outcomes(density * 3, 3, 4, prof, seeds=2)
        dev = acc["device_only"]
        for m in ("ecc_noma", "neurosurgeon", "dnn_surgery", "edge_only"):
            rows.append((f"density{density}:{m}:latency_speedup",
                         dev["T"] / acc[m]["T"],
                         "paper Fig.6: ECC-NOMA advantage shrinks w/ density"))
            rows.append((f"density{density}:{m}:energy_reduction",
                         dev["E"] / acc[m]["E"], "paper Fig.9"))
    # Fig.7/10: subchannel count sweep (fixed 24 users, 3 APs)
    for m_sub in (2, 4, 6, 8):
        acc = mean_outcomes(24, 3, m_sub, prof, seeds=2)
        dev = acc["device_only"]
        rows.append((f"subch{m_sub}:ecc_noma:latency_speedup",
                     dev["T"] / acc["ecc_noma"]["T"],
                     "paper Fig.7: rises then falls (bandwidth split)"))
        rows.append((f"subch{m_sub}:ecc_noma:energy_reduction",
                     dev["E"] / acc["ecc_noma"]["E"], "paper Fig.10"))
    # Fig.8/11: workload sweep (K inferences per user -> scale profile)
    import dataclasses
    for k in (1, 2, 4, 8):
        scaled = dataclasses.replace(
            prof, fl=prof.fl * k, w=prof.w * k, m_down=prof.m_down * k)
        acc = mean_outcomes(12, 3, 4, scaled, seeds=2)
        dev = acc["device_only"]
        for m in ("ecc_noma", "neurosurgeon"):
            rows.append((f"workload{k}x:{m}:latency_speedup",
                         dev["T"] / acc[m]["T"],
                         "paper Fig.8: ECC-NOMA advantage grows w/ load"))
            rows.append((f"workload{k}x:{m}:energy_reduction",
                         dev["E"] / acc[m]["E"], "paper Fig.11"))
    emit("fig6_11", rows)
    print(f"fig6_11,elapsed_s,{time.time()-t0:.1f},wall-clock")


if __name__ == "__main__":
    run()
