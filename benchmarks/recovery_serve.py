"""Recovery benchmark (BENCH_6): goodput and recovery cost under crashes.

Each arm drives the SAME chaos-hardened serving loop (same traffic, same
faults, same seed) through repro.state.CrashSupervisor with crashes
injected at fixed epochs:

  durable        -- SnapshotStore on a fixed cadence: a crash resumes
                    bit-exactly from the newest snapshot, re-executing at
                    most ``cadence`` epochs
  no_checkpoint  -- store=None: every crash is the PR-9 ladder cold start
                    from epoch 0, re-executing the whole prefix

Because resume is bit-exact, both arms end an episode with identical
*simulated* metrics -- what crashes cost is re-executed work and wall
clock. The headline rows are therefore goodput per WALL second (finite
in-deadline completions divided by elapsed time including recovery) and
``recovery_epochs`` (epochs re-executed after crashes). A third pair of
crash-free arms measures the snapshot tax: wall-time overhead % of
cutting snapshots on cadence vs running bare.

  PYTHONPATH=src python -m benchmarks.recovery_serve            # full
  PYTHONPATH=src python -m benchmarks.recovery_serve --quick    # CI smoke
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax

from benchmarks.paper_common import audit_meta, emit
from repro.analysis import audit_recovery, retrace_probe
from repro.core import profiles
from repro.core.types import GdConfig
from repro.online import (
    FaultConfig,
    LadderConfig,
    OnlineLoop,
    ServiceConfig,
    StreamConfig,
)
from repro.planning import PlannerEngine
from repro.scenarios import Scenario, ScenarioConfig
from repro.state import SimulatedCrash, SnapshotConfig, SnapshotStore
from repro.state.supervisor import CrashSupervisor

CFG = GdConfig(step_size=3e-2, eps=1e-4, max_iters=60, optimizer="adam")
STREAM = StreamConfig(arrival_rate_hz=30.0, epoch_dt_s=0.02, deadline_s=0.2)
SERVICE = ServiceConfig(edge_capacity=4, queue_depth=32, load_gain=4.0,
                        replan_every=5, max_work_epochs=200)
LADDER = LadderConfig(quarantine_epochs=15, baseline_after=2)
FAULTS = FaultConfig(link_outage_rate=0.1, fade_depth=1e-6,
                     ap_outage_rate=0.02, telemetry_drop_rate=0.05,
                     service_spike_rate=0.02)
SEED = 7


def _factory() -> OnlineLoop:
    eng = PlannerEngine(profiles.nin(), cfg=CFG)
    scen = Scenario(ScenarioConfig(n_users=6, n_aps=2, n_sub=3,
                                   fading_rho=0.95))
    return OnlineLoop(scen, eng, STREAM, SERVICE, faults=FAULTS,
                      degrade=LADDER)


def _episode(n_epochs: int, crashes: tuple[int, ...], cadence: int,
             checkpointed: bool, tmpdir: str) -> dict:
    store = None
    if checkpointed:
        store = SnapshotStore(
            os.path.join(tmpdir, f"snaps_{len(crashes)}"),
            SnapshotConfig(every=cadence, keep_n=3, asynchronous=True))
    pending = set(crashes)

    def chaos(next_epoch: int) -> None:
        if next_epoch in pending:
            pending.discard(next_epoch)
            raise SimulatedCrash(f"injected kill before epoch {next_epoch}")

    sup = CrashSupervisor(_factory, store=store,
                          max_restarts=len(crashes) + 2)
    t0 = time.perf_counter()
    m = sup.run(jax.random.PRNGKey(SEED), n_epochs, record=True,
                chaos=chaos if crashes else None)
    m["wall_s"] = time.perf_counter() - t0
    if store is not None:
        store.wait()
    return m


def run(quick: bool = False) -> None:
    n_epochs = 40 if quick else 120
    cadence = 8 if quick else 10
    crashes = (25,) if quick else (50, 95)

    # The audit verdict travels with the rows: quick checks the restore
    # path is retrace-free; the full run also proves bit-exact resume and
    # clean journal replay (the executing resume probe).
    report = (retrace_probe(label="recovery_serve") if quick
              else audit_recovery(label="recovery_serve"))
    audit = audit_meta(report)

    rows = []
    results: dict[str, dict] = {}
    with tempfile.TemporaryDirectory() as td:
        for arm, checkpointed in (("durable", True), ("no_checkpoint", False)):
            m = _episode(n_epochs, crashes, cadence, checkpointed, td)
            results[arm] = m
            wall = max(m["wall_s"], 1e-9)
            extra = {
                "arm": arm, "epochs": m["epochs"],
                "crashes": len(crashes), "restarts": m["restarts"],
                "cold_restarts": m["cold_restarts"],
                "recovery_epochs": m["supervisor_recovery_epochs"],
                "restored_from": m["restored_from"],
                "snapshots_saved": m["snapshots_saved"],
                "goodput": m["goodput"], "wall_s": m["wall_s"],
                "goodput_per_s_sim": m["goodput_per_s"],
            }
            rows.append((
                f"{arm}:goodput_per_wall_s", m["goodput"] / wall,
                "finite in-deadline completions per wall-clock second, "
                "crash recovery included (at smoke scale restart "
                "recompilation dominates the wall; recovery_epochs is the "
                "scale-free recovery cost)",
                extra))
            rows.append((
                f"{arm}:recovery_epochs", m["supervisor_recovery_epochs"],
                "epochs re-executed after crashes (durable: bounded by the "
                "snapshot cadence; no-checkpoint: the whole prefix)",
                extra))

        # Snapshot tax: crash-free wall time, snapshotting vs bare.
        base = _episode(n_epochs, (), cadence, checkpointed=False, tmpdir=td)
        snap = _episode(n_epochs, (), cadence, checkpointed=True, tmpdir=td)
        overhead = 100.0 * (snap["wall_s"] - base["wall_s"]) \
            / max(base["wall_s"], 1e-9)
        rows.append((
            "snapshot_overhead_pct", overhead,
            f"wall-time cost of async snapshots every {cadence} epochs, "
            "zero crashes",
            {"bare_wall_s": base["wall_s"], "snap_wall_s": snap["wall_s"],
             "snapshots_saved": snap["snapshots_saved"],
             "cadence": cadence}))

    dur, noc = results["durable"], results["no_checkpoint"]
    saved = (noc["supervisor_recovery_epochs"]
             - dur["supervisor_recovery_epochs"])
    rows.append((
        "recovery_epochs_saved", saved,
        "re-executed epochs avoided by durable snapshots across the crash "
        "schedule",
        {"durable": dur["supervisor_recovery_epochs"],
         "no_checkpoint": noc["supervisor_recovery_epochs"],
         "crashes": list(crashes)}))

    emit("recovery_serve", rows,
         meta={"n_epochs": n_epochs, "cadence": cadence,
               "crashes": list(crashes), "seed": SEED,
               "arrival_rate_hz": STREAM.arrival_rate_hz,
               "epoch_dt_s": STREAM.epoch_dt_s,
               "replan_every": SERVICE.replan_every},
         audit=audit)

    # Sanity gates: recovery must actually recover (all crashes survived,
    # full epoch count served, every served plan finite), and snapshots
    # must beat cold restarts on re-executed work.
    for arm, m in results.items():
        assert m["restarts"] == len(crashes), (arm, m["restarts"])
        assert m["epochs"] == n_epochs, (arm, m["epochs"])
        assert all(m["history"]["plan_finite"]), (arm, "non-finite plan")
    assert dur["supervisor_recovery_epochs"] \
        < noc["supervisor_recovery_epochs"], (dur, noc)
    # Bit-exact resume means both arms end with identical simulated
    # metrics -- crashes cost wall clock, never correctness.
    assert dur["goodput"] == noc["goodput"], (dur["goodput"], noc["goodput"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one crash, fewer epochs (CI smoke)")
    args = ap.parse_args()
    print("name,label,value,derived")
    run(quick=args.quick)


if __name__ == "__main__":
    main()
