"""Fig.2 / Fig.3: latency speedup and energy-consumption reduction of
ECC-NOMA / ECC(-OMA) / Edge-Only vs the Device-Only baseline, for the three
chain DNNs (NiN, YOLOv2, VGG16). Normalization = Device-Only (paper Sec VI.B).
"""
import time

from repro.core import profiles
from benchmarks.paper_common import emit, mean_outcomes


def run():
    t0 = time.time()
    rows = []
    for pname, fn in profiles.PAPER_MODELS.items():
        prof = fn()
        acc = mean_outcomes(12, 3, 4, prof)
        dev_T, dev_E = acc["device_only"]["T"], acc["device_only"]["E"]
        for m in ("ecc_noma", "ecc_oma", "edge_only"):
            rows.append((f"{pname}:{m}:latency_speedup",
                         dev_T / acc[m]["T"],
                         "paper band: ECC 3.1-8x, ECC-NOMA > ECC"))
            rows.append((f"{pname}:{m}:energy_reduction",
                         dev_E / acc[m]["E"],
                         "paper band: ECC 0.85-0.97x"))
    us = (time.time() - t0) * 1e6 / max(1, len(rows))
    emit("fig2_3", [(r[0], r[1], r[2]) for r in rows])
    print(f"fig2_3,us_per_point,{us:.0f},wall-clock")


if __name__ == "__main__":
    run()
