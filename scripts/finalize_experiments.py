"""Regenerate the roofline tables inside EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python scripts/finalize_experiments.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import roofline as rl  # noqa: E402

REPO = os.path.join(os.path.dirname(__file__), "..")


def load(mesh, suffix=""):
    import glob
    cells = []
    d = os.path.join(REPO, "artifacts", f"dryrun{suffix}")
    for p in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        cells.append(json.load(open(p)))
    return cells


def perf_compare():
    """Per-cell baseline vs optimized dominant-term table."""
    base = {(c["arch"], c["shape"]): c for c in load("pod16x16", "_baseline")}
    opt = {(c["arch"], c["shape"]): c for c in load("pod16x16", "_opt")}
    rows = ["| arch | shape | baseline bound | optimized bound | gain | "
            "baseline peak GiB | optimized peak GiB |",
            "|---|---|---|---|---|---|---|"]
    for key in sorted(opt):
        b, o = base.get(key), opt[key]
        if not b or b.get("skipped") or o.get("skipped"):
            continue
        rb, ro = b.get("roofline"), o.get("roofline")
        if not rb or not ro:
            continue
        gain = rb["bound_s"] / max(ro["bound_s"], 1e-12)
        rows.append(
            f"| {key[0]} | {key[1]} | {rl.fmt_s(rb['bound_s'])} "
            f"({rb['dominant'][:4]}) | {rl.fmt_s(ro['bound_s'])} "
            f"({ro['dominant'][:4]}) | **{gain:.1f}×** | "
            f"{b['memory']['peak_bytes_est'] / 2**30:.1f} | "
            f"{o['memory']['peak_bytes_est'] / 2**30:.1f} |")
    return "\n".join(rows)


def multi_pod_summary(suffix="_opt"):
    cells = [c for c in load("pod2x16x16", suffix) if not c.get("skipped")]
    if not cells:
        cells = [c for c in load("pod2x16x16", "") if not c.get("skipped")]
    n = len(cells)
    ok = sum(1 for c in cells if "memory" in c)
    lines = [f"multi-pod (512-chip) compiles: {ok}/{n} live cells",
             "", "| arch | shape | peak GiB/dev | collective kinds |",
             "|---|---|---|---|"]
    for c in cells:
        kinds = ",".join(sorted(c["hlo_full"]["per_kind_bytes"])) or "none"
        lines.append(f"| {c['arch']} | {c['shape']} | "
                     f"{c['memory']['peak_bytes_est'] / 2**30:.2f} | {kinds} |")
    return "\n".join(lines)


def main():
    path = os.path.join(REPO, "EXPERIMENTS.md")
    text = open(path).read()

    opt_cells = load("pod16x16", "_opt")
    base_cells = load("pod16x16", "_baseline")
    blocks = {
        "ROOFLINE-OPT": ("### Optimized roofline (single pod, per device)\n\n"
                         + rl.table(opt_cells) + "\n" + rl.summary(opt_cells)),
        "ROOFLINE-BASELINE": ("### Baseline roofline (single pod, per device)\n\n"
                              + rl.table(base_cells)),
        "PERF-FINAL": ("### Final before/after (all cells)\n\n" + perf_compare()),
        "MULTIPOD": multi_pod_summary(),
    }
    for marker, content in blocks.items():
        begin, end = f"<!-- {marker} -->", f"<!-- /{marker} -->"
        block = f"{begin}\n{content}\n{end}"
        if begin in text:
            pre = text.split(begin)[0]
            post = text.split(end)[1] if end in text else ""
            text = pre + block + post
        else:
            text += "\n\n" + block
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated with", list(blocks))


if __name__ == "__main__":
    main()
