"""Recompute roofline terms in existing dry-run artifacts so the collective
term uniformly comes from the FULL compile's trip-aware HLO parse (stored in
each JSON as hlo_full). No recompiles.

  PYTHONPATH=src python scripts/rebuild_roofline.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def rebuild(path):
    c = json.load(open(path))
    if c.get("skipped") or "probe" not in c:
        return False
    p = c["probe"]
    if "coll_ring_probe_extrap" not in p:
        p["coll_ring_probe_extrap"] = p.get("coll_ring_per_device", 0.0)
    p["coll_ring_per_device"] = c["hlo_full"]["collective_bytes_ring"]
    p["coll_spec_per_device"] = c["hlo_full"]["collective_bytes_spec"]
    compute_t = p["flops_per_device"] / PEAK_FLOPS
    memory_t = p["bytes_per_device"] / HBM_BW
    coll_t = p["coll_ring_per_device"] / ICI_BW
    dom = max(("compute", compute_t), ("memory", memory_t),
              ("collective", coll_t), key=lambda x: x[1])[0]
    flops_global = p["flops_per_device"] * c["n_devices"]
    c["roofline"] = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dom,
        "model_vs_hlo_flops": c["model_flops_global"] / max(flops_global, 1.0),
        "bound_s": max(compute_t, memory_t, coll_t),
    }
    json.dump(c, open(path, "w"), indent=1)
    return True


n = 0
for d in ("dryrun_baseline", "dryrun_opt", "dryrun"):
    for path in glob.glob(os.path.join(os.path.dirname(__file__), "..",
                                       "artifacts", d, "*__pod16x16.json")):
        n += rebuild(path)
print(f"rebuilt {n} artifacts")
