"""The 10 assigned architectures, exact configs from the assignment table.

[source; verified-tier] tags are recorded next to each config.
"""
from repro.configs.base import ArchConfig, register


@register
def llama32_vision_11b() -> ArchConfig:
    # [hf:meta-llama/Llama-3.2-11B-Vision; unverified] — cross-attn image layers
    return ArchConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256, rope_theta=5e5,
        cross_attn_every=5, frontend_tokens=1601,
    )


@register
def qwen2_1_5b() -> ArchConfig:
    # [arXiv:2407.10671; hf] — GQA, QKV bias
    return ArchConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
    )


@register
def qwen15_0_5b() -> ArchConfig:
    # [hf:Qwen/Qwen1.5-0.5B; hf] — QKV bias
    return ArchConfig(
        name="qwen1.5-0.5b", family="dense",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=2816, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
    )


@register
def phi3_medium_14b() -> ArchConfig:
    # [arXiv:2404.14219; unverified] — RoPE SwiGLU GQA
    return ArchConfig(
        name="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
        d_ff=17920, vocab_size=100352,
    )


@register
def internlm2_20b() -> ArchConfig:
    # [arXiv:2403.17297; hf] — GQA
    return ArchConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92544, rope_theta=1e6,
    )


@register
def llama4_scout_17b_a16e() -> ArchConfig:
    # [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — MoE 16e top-1
    return ArchConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab_size=202048, rope_theta=5e5,
        n_experts=16, top_k=1, moe_d_ff=8192, n_shared_experts=1,
    )


@register
def deepseek_moe_16b() -> ArchConfig:
    # [arXiv:2401.06066; hf] — 2 shared + 64 routed top-6, fine-grained
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
        first_dense_layers=1,
    )


@register
def recurrentgemma_9b() -> ArchConfig:
    # [arXiv:2402.19427; unverified] — RG-LRU + local attn, 1 attn : 2 rec
    return ArchConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab_size=256000, head_dim=256,
        block_pattern=("rec", "rec", "attn"), window=2048,
        rglru_dim=4096, conv_width=4, act="gelu",
    )


@register
def xlstm_125m() -> ArchConfig:
    # [arXiv:2405.04517; unverified] — alternating sLSTM + mLSTM blocks
    return ArchConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304, head_dim=192,
        block_pattern=("mlstm", "slstm"),
    )


@register
def whisper_small() -> ArchConfig:
    # [arXiv:2212.04356; unverified] — enc-dec, conv frontend (stub)
    return ArchConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865, act="gelu",
        encoder_layers=12, frontend_tokens=1500, rope_theta=0.0,
    )
