"""Architecture config system.

Every assigned architecture is a frozen ArchConfig registered in ARCHS.
`reduced()` yields the CPU-smoke variant (same family/topology, tiny dims).
`input_shapes()` defines the four assigned input-shape cells; `input_specs`
returns ShapeDtypeStruct stand-ins (no allocation) for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    act: str = "swiglu"            # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0    # deepseek: layer 0 is dense
    # --- hybrid / ssm ---
    block_pattern: tuple = ()      # e.g. ("rec", "rec", "attn") tiled over depth
    window: int = 0                # local attention window (0 = full)
    conv_width: int = 4            # RG-LRU temporal conv width
    rglru_dim: int = 0             # lru width (0 -> d_model)
    # --- enc-dec / vlm ---
    encoder_layers: int = 0        # whisper
    cross_attn_every: int = 0      # vlm: every k-th decoder layer cross-attends
    frontend_tokens: int = 1500    # stub frontend sequence length (audio/vlm)
    causal: bool = True
    # --- TP attention layout (set by Model from tp_size; see runtime docs) ---
    attn_layout: str = "grouped"   # grouped (shard kv heads) | flat (pad+shard q heads)
    heads_padded: int = 0          # flat layout: H padded to a tp multiple

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ArchConfig":
        """Tiny same-topology variant for CPU smoke tests."""
        def shrink_pattern(p):
            return p
        return dataclasses.replace(
            self,
            n_layers=max(2, min(4, self.n_layers // 8)) if not self.block_pattern
            else max(len(self.block_pattern), 3),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads // max(1, self.n_heads // 4))),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            window=min(self.window, 64) if self.window else 0,
            rglru_dim=128 if self.rglru_dim else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            frontend_tokens=16,
            first_dense_layers=min(self.first_dense_layers, 1),
        )


# The four assigned input-shape cells (seq_len, global_batch, kind).
SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC = {"recurrentgemma-9b", "xlstm-125m"}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, "SKIP(full-attention arch; 500k decode needs sub-quadratic mixing)"
    return True, ""


ARCHS: dict[str, Callable[[], ArchConfig]] = {}


def register(fn: Callable[[], ArchConfig]):
    cfg = fn()
    ARCHS[cfg.name] = fn
    return fn


def get(name: str) -> ArchConfig:
    return ARCHS[name]()


def all_names() -> list[str]:
    return sorted(ARCHS)
