from repro.configs.base import (  # noqa: F401
    ARCHS,
    SHAPES,
    SUBQUADRATIC,
    ArchConfig,
    all_names,
    get,
    shape_applicable,
)
import repro.configs.archs  # noqa: F401  (registers the 10 assigned archs)
