from repro.models.model import Model  # noqa: F401
from repro.models.blocks import StageSpec, stages_for  # noqa: F401
