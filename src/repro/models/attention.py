"""Attention: GQA with RoPE, memory-bounded 'flash-style' jnp core.

The core scans over KV chunks with an online-softmax accumulator, so peak
memory is O(Sq * chunk) instead of O(Sq * Sk) -- naive S^2 scores cannot
even be allocated at 32k context. On real TPU hardware the Pallas kernel
(repro.kernels.flash_attention) replaces this core; the jnp path is the
oracle + the dry-run path (Pallas does not lower on the CPU host platform).

Supports: causal / bidirectional / local-window masks, cross attention,
KV caches for decode, grouped KV without materializing repeated heads.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, Array, ParamDef, rope
from repro.pshard import constrain

NEG_INF = -1e30

# Set True during dry-run probe lowering: unrolls the KV-chunk scan so
# XLA cost analysis sees every chunk (while bodies are otherwise counted once).
UNROLL_SCANS = False


def attn_defs(cfg, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    hq = cfg.heads_padded or h  # flat layout pads H to a tp multiple
    defs = {
        "wq": ParamDef((d, hq * hd), ("embed", "qkv")),
        "wk": ParamDef((d, kv * hd), ("embed", "kv")),
        "wv": ParamDef((d, kv * hd), ("embed", "kv")),
        "wo": ParamDef((hq * hd, d), ("qkv", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((hq * hd,), ("qkv",), init="zeros")
        defs["bk"] = ParamDef((kv * hd,), ("kv",), init="zeros")
        defs["bv"] = ParamDef((kv * hd,), ("kv",), init="zeros")
    return defs


def _chunked_mha(
    q: Array,            # (B, Sq, KV, G, hd)  -- grouped query
    k: Array,            # (B, Sk, KV, hd)
    v: Array,            # (B, Sk, KV, hd)
    q_pos: Array,        # (B, Sq) absolute positions of queries
    k_pos: Array,        # (B, Sk) absolute positions of keys
    kv_valid_len: Array | None,  # (B,) or None: #valid cache entries
    causal: bool,
    window: int,
    chunk: int = 1024,
) -> Array:
    """Online-softmax attention, scanning KV in chunks. For tiny Sq (decode)
    a single-pass path is used instead: no scan, so a sequence-sharded KV
    cache keeps the score/AV contractions local per shard with only small
    reductions crossing shards (flash-decoding / split-K; §Perf iteration)."""
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    qf = (q * scale).astype(COMPUTE_DTYPE)
    if sq <= 8:
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, k).astype(jnp.float32)
        valid = k_pos[:, None, None, None, :] >= 0
        if causal:
            valid &= k_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        if window:
            valid &= (q_pos[:, None, None, :, None]
                      - k_pos[:, None, None, None, :]) < window
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(COMPUTE_DTYPE), v)
        return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(COMPUTE_DTYPE)
    chunk = min(chunk, sk)
    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    kc = k.reshape(b, n_chunks, chunk, kvh, hd)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd)
    pc = k_pos.reshape(b, n_chunks, chunk)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp  # (b, chunk, kvh, hd), ..., (b, chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kb).astype(jnp.float32)
        valid = pb[:, None, None, None, :] >= 0
        if kv_valid_len is not None:
            valid &= pb[:, None, None, None, :] < kv_valid_len[:, None, None, None, None]
        if causal:
            valid &= pb[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        if window:
            valid &= q_pos[:, None, None, :, None] - pb[:, None, None, None, :] < window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(COMPUTE_DTYPE), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(pc, 1, 0)),
        unroll=True if UNROLL_SCANS else 1,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (b, kvh, g, sq, hd) -> (b, sq, kvh, g, hd)
    return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(COMPUTE_DTYPE)


def attn_apply(
    p: dict,
    x: Array,                 # (B, S, D)
    cfg,
    q_pos: Array,             # (B, S)
    kv_src: Array | None = None,   # cross-attention source (B, Sk, D)
    cache: dict | None = None,     # {"k","v": (B, Smax, KV, hd), "len": (B,)}
    causal: bool = True,
    window: int = 0,
    rope_theta: float | None = None,
) -> tuple[Array, dict | None]:
    """Returns (out, updated_cache).

    Two TP layouts (chosen by Model via cfg.attn_layout):
      grouped  q stays (B,S,KV,G,hd): KV heads shard over 'model' when
               kv % tp == 0 (the GQA-natural layout).
      flat     q is (B,S,Hp,1,hd) with Hp = H padded to a tp multiple and
               K/V logically repeated per query head: shards attention
               compute/score memory tp-ways even when neither kv nor H
               divides tp (padded heads have zero wq/wo -> exact math).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv
    flat = cfg.attn_layout == "flat"
    hq = (cfg.heads_padded or h) if flat else h
    dt = COMPUTE_DTYPE
    theta = cfg.rope_theta if rope_theta is None else rope_theta

    q = x @ p["wq"].astype(dt)
    src = x if kv_src is None else kv_src
    kproj = src @ p["wk"].astype(dt)
    vproj = src @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        kproj = kproj + p["bk"].astype(dt)
        vproj = vproj + p["bv"].astype(dt)
    kproj = kproj.reshape(b, -1, kv, hd)
    vproj = vproj.reshape(b, -1, kv, hd)

    if kv_src is None:
        q = rope(q.reshape(b, s, hq, hd), q_pos, theta)
        k_pos_new = q_pos
        kproj = rope(kproj, k_pos_new, theta)
    else:
        q = q.reshape(b, s, hq, hd)
        k_pos_new = jnp.broadcast_to(
            jnp.arange(kproj.shape[1], dtype=jnp.int32)[None], kproj.shape[:2]
        )

    if flat:
        # repeat KV per (padded) query head; padded heads clamp to the last
        # real KV head (their zero wo rows erase the result anyway)
        head_map = jnp.clip(jnp.arange(hq) // g, 0, kv - 1)
        q = constrain(q, ("batch", None, "heads", None))[:, :, :, None, :]
        expand = lambda t: constrain(t[:, :, head_map, :],
                                     ("batch", None, "heads", None))
    else:
        q = q.reshape(b, s, kv, g, hd)
        q = constrain(q, ("batch", None, "kv_heads", None, None))
        expand = lambda t: t

    new_cache = None
    if cache is not None:
        # Ring-buffer cache: write at len % size; absolute positions stored in
        # cache["pos"] drive masking (-1 marks empty slots), so local-window
        # caches of size `window` work at any context length.
        size = cache["k"].shape[1]
        if s > 1:
            # prefill: attend over the full fresh K/V (early queries need
            # keys the window-sized cache won't retain) ...
            out = _chunked_mha(q, expand(kproj), expand(vproj), q_pos,
                               k_pos_new, None, causal=causal, window=window)
            # ... the cache keeps the last `size` tokens, rolled so position
            # p lands at slot p % size (the decode ring invariant).
            if s >= size:
                shift = (s - size) % size
                k_all = jnp.roll(kproj[:, -size:].astype(dt), shift, axis=1)
                v_all = jnp.roll(vproj[:, -size:].astype(dt), shift, axis=1)
                pos_all = jnp.roll(q_pos[:, -size:], shift, axis=1)
            else:
                k_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], kproj.astype(dt), 0, 1)
                v_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], vproj.astype(dt), 0, 1)
                pos_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], q_pos, 0, 1)
        else:
            # decode: ring-buffer write, attend over the cache. ALWAYS the
            # grouped layout here (no KV repeat): with a sequence-sharded
            # cache the score/AV contractions are shard-local flash-decoding
            # and repeating KV G-fold would only inflate HBM traffic.
            slot = cache["len"][0] % size  # uniform across batch
            k_all = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kproj.astype(dt), slot, 1)
            v_all = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vproj.astype(dt), slot, 1)
            pos_all = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], q_pos, slot, 1)
            q_g = (q[:, :, :h, 0] if flat else q.reshape(b, s, h, hd))
            q_g = q_g.reshape(b, s, kv, g, hd)
            out = _chunked_mha(q_g, k_all, v_all, q_pos, pos_all, None,
                               causal=causal, window=window)
            out = out.reshape(b, s, h, hd)
            if flat and hq != h:
                out = jnp.pad(out, ((0, 0), (0, 0), (0, hq - h), (0, 0)))
            out = out.reshape(b, s, hq * hd)
            new_cache = {"k": k_all, "v": v_all, "pos": pos_all,
                         "len": cache["len"] + s}
            return out @ p["wo"].astype(dt), new_cache
        new_cache = {"k": k_all, "v": v_all, "pos": pos_all,
                     "len": cache["len"] + s}
    else:
        out = _chunked_mha(q, expand(kproj), expand(vproj), q_pos, k_pos_new,
                           None, causal=causal, window=window)

    if flat and hq != h:
        # zero the padded heads: their random-init wq/wo must not leak
        mask = (jnp.arange(hq) < h).astype(out.dtype)
        out = out * mask[None, None, :, None, None]
    out = out.reshape(b, s, hq * hd)
    return out @ p["wo"].astype(dt), new_cache


def make_cache(cfg, batch: int, max_len: int, n_layers: int,
               window: int = 0) -> dict:
    """Stacked (over layers) KV cache for one attention stage."""
    size = min(window, max_len) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((n_layers, batch, size, kv, hd), COMPUTE_DTYPE),
        "v": jnp.zeros((n_layers, batch, size, kv, hd), COMPUTE_DTYPE),
        "pos": jnp.full((n_layers, batch, size), -1, jnp.int32),
        "len": jnp.zeros((n_layers, batch), jnp.int32),
    }
