"""Model primitives: norms, RoPE, MLPs, embeddings, parameter descriptors.

Parameters are plain dict pytrees. Every parameter is described by a
ParamDef(shape, axes) where `axes` are *logical* axis names resolved to mesh
axes by repro.runtime.sharding. Initializers are deterministic per-path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
COMPUTE_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple          # logical axis names, len == len(shape)
    init: str = "normal" # normal | zeros | ones
    scale: float = 0.02


def init_params(defs: dict, key: jax.Array, n_stack: int = 0) -> dict:
    """Initialize a (possibly nested) dict of ParamDefs. If n_stack > 0 a
    leading 'layers' dimension of that size is added to every leaf."""
    flat, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(flat))
    out = []
    for kd, d in zip(keys, flat):
        shape = (n_stack, *d.shape) if n_stack else d.shape
        if d.init == "zeros":
            arr = jnp.zeros(shape, jnp.float32)
        elif d.init == "ones":
            arr = jnp.ones(shape, jnp.float32)
        else:
            arr = d.scale * jax.random.normal(kd, shape, jnp.float32)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def param_specs(defs: dict, stacked: bool = False) -> dict:
    flat, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    specs = [("layers", *d.axes) if stacked else d.axes for d in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
def rms_norm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, w: Array, b: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: (..., S) int32. On-the-fly frequencies
    (no precomputed table: at 500k context a table would cost ~0.5 GB)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def mlp_apply(p: dict, x: Array, act: str) -> Array:
    """SwiGLU (w1/w3/w2) or GELU (w1/w2) MLP."""
    dt = COMPUTE_DTYPE
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(dt)) * (x @ p["w3"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w1"].astype(dt))
    return h @ p["w2"].astype(dt)


def mlp_defs(d_model: int, d_ff: int, act: str) -> dict:
    defs = {
        "w1": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w2": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }
    if act == "swiglu":
        defs["w3"] = ParamDef((d_model, d_ff), ("embed", "mlp"))
    return defs


def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def embed_lookup(table: Array, tokens: Array) -> Array:
    return jnp.take(table, tokens, axis=0).astype(COMPUTE_DTYPE)


def logits_out(x: Array, table: Array, vocab: int) -> Array:
    """Project to (padded) vocab; mask the padding rows to -inf."""
    logits = (x @ table.astype(COMPUTE_DTYPE).T).astype(jnp.float32)
    vp = table.shape[0]
    if vp != vocab:
        mask = jnp.arange(vp) < vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits
