"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  x -> [W_gate -> GeLU] branch (gate)
        x -> [W_x -> causal depthwise conv(w=4) -> RG-LRU] branch
        out = W_out (gate * lru_out)

RG-LRU per channel:
    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses an associative scan (O(log S) depth, sub-quadratic in S,
which is what qualifies recurrentgemma for the long_500k cell); decode is a
single fused state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, Array, ParamDef

C_EXP = 8.0


def rglru_defs(cfg) -> dict:
    d = cfg.d_model
    w = cfg.rglru_dim or d
    return {
        "w_in": ParamDef((d, w), ("embed", "lru")),
        "w_gate": ParamDef((d, w), ("embed", "lru")),
        "conv_w": ParamDef((cfg.conv_width, w), (None, "lru"), scale=0.1),
        "conv_b": ParamDef((w,), ("lru",), init="zeros"),
        "w_r": ParamDef((w, w), ("lru", "lru_out")),
        "w_i": ParamDef((w, w), ("lru", "lru_out")),
        "lam": ParamDef((w,), ("lru",), init="ones"),
        "w_out": ParamDef((w, d), ("lru", "embed")),
    }


def _causal_conv(x: Array, w: Array, b: Array, state: Array | None):
    """Depthwise causal conv, width K. x: (B, S, W). state: (B, K-1, W)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, W)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k)
    )
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return out + b.astype(x.dtype), new_state


def _rglru_scan(xb: Array, log_a: Array, h0: Array | None):
    """h_t = a_t h_{t-1} + b_t via associative scan over S.
    xb: (B, S, W) effective input b_t; log_a: (B, S, W)."""
    a = jnp.exp(log_a)
    b = xb
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh


def rglru_apply(p: dict, x: Array, cfg, state: dict | None = None
                ) -> tuple[Array, dict | None]:
    """x: (B, S, D). state: {"h": (B, W), "conv": (B, K-1, W)} or None."""
    dt = COMPUTE_DTYPE
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    u = x @ p["w_in"].astype(dt)
    u, conv_state = _causal_conv(
        u, p["conv_w"], p["conv_b"], None if state is None else state["conv"]
    )
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32))
    log_a = -C_EXP * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = scale * (i * uf)
    if state is None:
        h = _rglru_scan(b, log_a, None)
        new_state = None
    else:
        h0 = state["h"].astype(jnp.float32)
        if x.shape[1] == 1:  # decode fast path
            h = jnp.exp(log_a[:, 0]) * h0 + b[:, 0]
            h = h[:, None, :]
        else:
            h = _rglru_scan(b, log_a, h0)
        new_state = {"h": h[:, -1, :].astype(jnp.float32), "conv": conv_state}
    out = (gate * h.astype(dt)) @ p["w_out"].astype(dt)
    return out, new_state


def make_rglru_state(cfg, batch: int, n_layers: int) -> dict:
    w = cfg.rglru_dim or cfg.d_model
    return {
        "h": jnp.zeros((n_layers, batch, w), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.conv_width - 1, w), COMPUTE_DTYPE),
    }
