"""Residual blocks: one param-def + apply pair per block kind.

Kinds:
  attn    pre-norm GQA self-attention + MLP (optionally MoE, optionally
          local-window)
  cross   cross-attention block (VLM image layers / used inside dec)
  enc     bidirectional attention + MLP, LayerNorm (whisper encoder)
  dec     causal self-attn + cross-attn + MLP, LayerNorm (whisper decoder)
  rec     RG-LRU temporal-mixing block + MLP (recurrentgemma)
  mlstm / slstm   xLSTM blocks

block_apply(cfg, spec, p, x, aux, cache) -> (x, new_cache, aux_loss)
`aux` carries {"pos": (B,S), "frontend": (B,Sf,D) or None}.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models import attention, moe, recurrent, xlstm
from repro.models.layers import (
    COMPUTE_DTYPE,
    ParamDef,
    layer_norm,
    mlp_apply,
    mlp_defs,
    rms_norm,
)


@dataclasses.dataclass(frozen=True)
class StageSpec:
    kind: str
    n_layers: int
    moe: bool = False
    window: int = 0
    causal: bool = True
    cache: str | None = "kv"     # kv | rglru | mlstm | slstm | None


def _norm_defs(cfg, name, layernorm=False):
    if layernorm:
        return {
            f"{name}_w": ParamDef((cfg.d_model,), ("embed",), init="ones"),
            f"{name}_b": ParamDef((cfg.d_model,), ("embed",), init="zeros"),
        }
    return {f"{name}_w": ParamDef((cfg.d_model,), ("embed",), init="zeros")}


def _norm(cfg, p, name, x, layernorm=False):
    if layernorm:
        return layer_norm(x, p[f"{name}_w"], p[f"{name}_b"], cfg.norm_eps)
    return rms_norm(x, p[f"{name}_w"], cfg.norm_eps)


def block_defs(cfg, spec: StageSpec) -> dict:
    ln = cfg.family == "audio"
    d: dict = {}
    if spec.kind in ("attn", "enc", "dec"):
        d.update(_norm_defs(cfg, "ln1", ln))
        d["attn"] = attention.attn_defs(cfg)
        if spec.kind == "dec":
            d.update(_norm_defs(cfg, "lnx", ln))
            d["xattn"] = attention.attn_defs(cfg, cross=True)
        d.update(_norm_defs(cfg, "ln2", ln))
        if spec.moe:
            d["moe"] = moe.moe_defs(cfg)
        else:
            d["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, cfg.act)
    elif spec.kind == "cross":
        d.update(_norm_defs(cfg, "ln1", ln))
        d["xattn"] = attention.attn_defs(cfg, cross=True)
        d["xgate"] = ParamDef((1,), (None,), init="zeros")
        d.update(_norm_defs(cfg, "ln2", ln))
        d["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, cfg.act)
    elif spec.kind == "rec":
        d.update(_norm_defs(cfg, "ln1", ln))
        d["rglru"] = recurrent.rglru_defs(cfg)
        d.update(_norm_defs(cfg, "ln2", ln))
        d["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, cfg.act)
    elif spec.kind == "mlstm":
        d.update(_norm_defs(cfg, "ln1", ln))
        d["mlstm"] = xlstm.mlstm_defs(cfg)
    elif spec.kind == "slstm":
        d.update(_norm_defs(cfg, "ln1", ln))
        d["slstm"] = xlstm.slstm_defs(cfg)
    else:
        raise ValueError(spec.kind)
    return d


def block_apply(cfg, spec: StageSpec, p: dict, x, aux: dict, cache=None):
    """Returns (x, new_cache, aux_loss)."""
    ln = cfg.family == "audio"
    pos = aux["pos"]
    zero = jnp.zeros((), jnp.float32)
    new_cache = None

    if spec.kind in ("attn", "enc", "dec"):
        h, kv_cache = attention.attn_apply(
            p["attn"], _norm(cfg, p, "ln1", x, ln), cfg, pos,
            cache=None if cache is None else cache.get("kv"),
            causal=spec.causal, window=spec.window,
        )
        x = x + h
        if spec.kind == "dec":
            hx, _ = attention.attn_apply(
                p["xattn"], _norm(cfg, p, "lnx", x, ln), cfg, pos,
                kv_src=aux["frontend"], causal=False,
            )
            x = x + hx
        aux_l = zero
        if spec.moe:
            y, aux_l = moe.moe_apply(
                p["moe"], _norm(cfg, p, "ln2", x, ln), cfg,
                impl=aux.get("moe_impl", "sorted"),
                capacity_factor=aux.get("moe_capacity", 1.25),
            )
        else:
            y = mlp_apply(p["mlp"], _norm(cfg, p, "ln2", x, ln), cfg.act)
        x = x + y
        if kv_cache is not None:
            new_cache = {"kv": kv_cache}
        return x, new_cache, aux_l

    if spec.kind == "cross":
        hx, _ = attention.attn_apply(
            p["xattn"], _norm(cfg, p, "ln1", x, ln), cfg, pos,
            kv_src=aux["frontend"], causal=False,
        )
        x = x + jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype) * hx
        y = mlp_apply(p["mlp"], _norm(cfg, p, "ln2", x, ln), cfg.act)
        return x + y, (None if cache is None else {}), zero

    if spec.kind == "rec":
        h, st = recurrent.rglru_apply(
            p["rglru"], _norm(cfg, p, "ln1", x, ln), cfg,
            state=None if cache is None else cache.get("rglru"),
        )
        x = x + h
        y = mlp_apply(p["mlp"], _norm(cfg, p, "ln2", x, ln), cfg.act)
        x = x + y
        return x, (None if st is None else {"rglru": st}), zero

    if spec.kind == "mlstm":
        h, st = xlstm.mlstm_apply(
            p["mlstm"], _norm(cfg, p, "ln1", x, ln), cfg,
            state=None if cache is None else cache.get("mlstm"),
        )
        return x + h, (None if st is None else {"mlstm": st}), zero

    if spec.kind == "slstm":
        h, st = xlstm.slstm_apply(
            p["slstm"], _norm(cfg, p, "ln1", x, ln), cfg,
            state=None if cache is None else cache.get("slstm"),
        )
        return x + h, (None if st is None else {"slstm": st}), zero

    raise ValueError(spec.kind)


def stages_for(cfg) -> list[StageSpec]:
    """Build the stage list (consecutive same-kind blocks grouped) that
    realizes each assigned architecture's topology."""
    fam = cfg.family
    if fam in ("dense",):
        return [StageSpec("attn", cfg.n_layers)]
    if fam == "moe":
        stages = []
        if cfg.first_dense_layers:
            stages.append(StageSpec("attn", cfg.first_dense_layers, moe=False))
        stages.append(StageSpec("attn", cfg.n_layers - cfg.first_dense_layers,
                                moe=True))
        return stages
    if fam == "hybrid":
        # tile block_pattern (e.g. rec,rec,attn) over depth, grouping runs
        pattern = cfg.block_pattern
        kinds = [pattern[i % len(pattern)] for i in range(cfg.n_layers)]
        stages = []
        for k in kinds:
            spec = StageSpec(
                "rec" if k == "rec" else "attn",
                1,
                window=cfg.window if k == "attn" else 0,
                cache="rglru" if k == "rec" else "kv",
            )
            if stages and stages[-1].kind == spec.kind:
                stages[-1] = dataclasses.replace(
                    stages[-1], n_layers=stages[-1].n_layers + 1)
            else:
                stages.append(spec)
        return stages
    if fam == "ssm":
        pattern = cfg.block_pattern
        kinds = [pattern[i % len(pattern)] for i in range(cfg.n_layers)]
        stages = []
        for k in kinds:
            spec = StageSpec(k, 1, cache=k)
            if stages and stages[-1].kind == spec.kind:
                stages[-1] = dataclasses.replace(
                    stages[-1], n_layers=stages[-1].n_layers + 1)
            else:
                stages.append(spec)
        return stages
    if fam == "vlm":
        # every cross_attn_every-th layer is followed by a cross block
        period = cfg.cross_attn_every
        n_cross = cfg.n_layers // period
        n_self = cfg.n_layers - n_cross
        stages = []
        self_per_group = period - 1
        done_self = 0
        for _ in range(n_cross):
            take = min(self_per_group, n_self - done_self)
            if take:
                stages.append(StageSpec("attn", take))
                done_self += take
            stages.append(StageSpec("cross", 1, cache=None))
        if done_self < n_self:
            stages.append(StageSpec("attn", n_self - done_self))
        return stages
    if fam == "audio":
        return [
            StageSpec("enc", cfg.encoder_layers, causal=False, cache=None),
            StageSpec("dec", cfg.n_layers),
        ]
    raise ValueError(fam)
