"""Model assembly: stages -> init / train-forward / prefill / decode.

Per-stage parameters are stacked over the stage's layers and the stage body
is a lax.scan (never unrolled: keeps HLO size independent of depth, which
matters when compiling 512-device GSPMD programs on a 1-core host).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SHAPES
from repro.models import attention, blocks, recurrent, xlstm
from repro.models.layers import (
    COMPUTE_DTYPE,
    ParamDef,
    embed_lookup,
    init_params,
    logits_out,
    pad_vocab,
    param_specs,
    rms_norm,
    layer_norm,
)


def _sinusoid(pos: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    ang = pos[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(COMPUTE_DTYPE)


class Model:
    def __init__(self, cfg: ArchConfig, remat: bool = True,
                 moe_impl: str = "sorted", moe_capacity: float = 1.25,
                 unroll: bool = False, tp_size: int | None = None):
        if tp_size and cfg.family != "ssm" and cfg.n_kv_heads % tp_size != 0:
            # flat TP attention layout: pad H to a tp multiple and shard the
            # flattened query heads (see attention.attn_apply; §Perf A)
            hp = -(-cfg.n_heads // tp_size) * tp_size
            cfg = dataclasses.replace(cfg, attn_layout="flat", heads_padded=hp)
        self.cfg = cfg
        self.stages = blocks.stages_for(cfg)
        self.vocab_padded = pad_vocab(cfg.vocab_size)
        self.remat = remat
        self.moe_impl = moe_impl
        self.moe_capacity = moe_capacity
        self.unroll = unroll  # dry-run probes: unroll stage scans

    # ---------------- params ----------------
    def _top_defs(self) -> dict:
        cfg = self.cfg
        d = {
            "embed": ParamDef((self.vocab_padded, cfg.d_model), ("vocab", "embed")),
            "unembed": ParamDef((self.vocab_padded, cfg.d_model), ("vocab", "embed")),
            "final_norm_w": ParamDef((cfg.d_model,), ("embed",),
                                     init="zeros" if cfg.family != "audio" else "ones"),
        }
        if cfg.family == "audio":
            d["final_norm_b"] = ParamDef((cfg.d_model,), ("embed",), init="zeros")
        return d

    def init(self, key: jax.Array) -> dict:
        keys = jax.random.split(key, len(self.stages) + 1)
        params = init_params(self._top_defs(), keys[0])
        params["stages"] = [
            init_params(blocks.block_defs(self.cfg, s), k, n_stack=s.n_layers)
            for s, k in zip(self.stages, keys[1:])
        ]
        return params

    def specs(self) -> dict:
        specs = param_specs(self._top_defs())
        specs["stages"] = [
            param_specs(blocks.block_defs(self.cfg, s), stacked=True)
            for s in self.stages
        ]
        return specs

    # ---------------- stage runner ----------------
    def _run_stage(self, spec, p_stacked, x, aux, cache_stacked):
        cfg = self.cfg

        def body(carry, xs):
            xc, aux_sum = carry
            p_l, cache_l = xs
            xc, new_cache, al = blocks.block_apply(cfg, spec, p_l, xc, aux, cache_l)
            return (xc, aux_sum + al), new_cache

        if self.remat and cache_stacked is None:
            body = jax.checkpoint(body, prevent_cse=False)

        unroll = True if self.unroll else 1
        if cache_stacked is None:
            (x, aux_sum), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), (p_stacked, None),
                unroll=unroll,
            )
            return x, None, aux_sum
        (x, aux_sum), new_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (p_stacked, cache_stacked),
            unroll=unroll,
        )
        return x, new_cache, aux_sum

    # ---------------- forward paths ----------------
    def _final_norm(self, params, x):
        if self.cfg.family == "audio":
            return layer_norm(x, params["final_norm_w"], params["final_norm_b"],
                              self.cfg.norm_eps)
        return rms_norm(x, params["final_norm_w"], self.cfg.norm_eps)

    def _encode(self, params, frontend, caches=None):
        """Audio encoder pass (stage 0). Returns enc_out (B, F, D)."""
        cfg = self.cfg
        b, f, _ = frontend.shape
        pos = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))
        x = frontend.astype(COMPUTE_DTYPE) + _sinusoid(pos, cfg.d_model)
        aux = {"pos": pos, "frontend": None, "moe_impl": self.moe_impl,
               "moe_capacity": self.moe_capacity}
        x, _, _ = self._run_stage(self.stages[0], params["stages"][0], x, aux, None)
        return x

    def forward(self, params, tokens, frontend=None, caches=None,
                positions=None, return_hidden=False):
        """Generic forward. tokens: (B, S) int32. Returns
        (logits fp32 (B,S,Vp) -- or hidden (B,S,D) -- , new_caches, aux)."""
        cfg = self.cfg
        b, s = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        x = embed_lookup(params["embed"], tokens)
        if cfg.family == "audio":
            x = x + _sinusoid(positions, cfg.d_model)
            if caches is not None and caches.get("enc_out") is not None and frontend is None:
                enc_out = caches["enc_out"]
            else:
                enc_out = self._encode(params, frontend)
                if caches is not None:
                    caches = dict(caches, enc_out=enc_out)
            frontend_for_blocks = enc_out
            stage_list = self.stages[1:]
            stage_params = params["stages"][1:]
        else:
            if caches is not None and frontend is None:
                frontend_for_blocks = caches.get("frontend")
            else:
                frontend_for_blocks = (
                    frontend.astype(COMPUTE_DTYPE) if frontend is not None else None
                )
                if caches is not None and frontend_for_blocks is not None:
                    caches = dict(caches, frontend=frontend_for_blocks)
            stage_list = self.stages
            stage_params = params["stages"]

        aux = {"pos": positions, "frontend": frontend_for_blocks,
               "moe_impl": self.moe_impl, "moe_capacity": self.moe_capacity}
        aux_total = jnp.zeros((), jnp.float32)
        new_stage_caches = []
        stage_caches = caches["stages"] if caches is not None else [None] * len(stage_list)
        if cfg.family == "audio" and caches is not None:
            stage_caches = stage_caches[1:]  # encoder stage holds no cache slot
        for spec, p_st, c_st in zip(stage_list, stage_params, stage_caches):
            x, new_c, al = self._run_stage(spec, p_st, x, aux, c_st)
            aux_total = aux_total + al
            new_stage_caches.append(new_c)

        x = self._final_norm(params, x)
        logits = (x if return_hidden
                  else logits_out(x, params["unembed"], cfg.vocab_size))
        new_caches = None
        if caches is not None:
            all_stages = ([None] + new_stage_caches
                          if cfg.family == "audio" else new_stage_caches)
            new_caches = dict(caches, stages=all_stages,
                              pos=caches["pos"] + s)
        return logits, new_caches, aux_total

    # ---------------- public APIs ----------------
    def train_logits(self, params, batch):
        return self.forward(params, batch["tokens"], batch.get("frontend"))

    def train_hidden(self, params, batch):
        """Final-norm'd hidden states (B, S, D) + aux loss -- used by the
        chunked cross-entropy (never materializes (B, S, V) logits)."""
        h, _, aux = self.forward(params, batch["tokens"],
                                 batch.get("frontend"), return_hidden=True)
        return h, aux

    def prefill(self, params, batch, max_len: int):
        caches = self.make_caches(batch["tokens"].shape[0], max_len)
        logits, caches, _ = self.forward(
            params, batch["tokens"], batch.get("frontend"), caches=caches
        )
        return logits[:, -1], caches

    def decode_step(self, params, caches, token):
        """token: (B, 1). One step with KV/state caches."""
        b = token.shape[0]
        pos = jnp.broadcast_to(caches["pos"][:, None], (b, 1))
        logits, caches, _ = self.forward(params, token, caches=caches,
                                         positions=pos)
        return logits[:, -1], caches

    # ---------------- caches / input specs ----------------
    def make_caches(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        stage_caches: list = []
        for spec in self.stages:
            if spec.cache == "kv":
                stage_caches.append(
                    {"kv": attention.make_cache(cfg, batch, max_len,
                                                spec.n_layers, spec.window)}
                )
            elif spec.cache == "rglru":
                stage_caches.append(
                    {"rglru": recurrent.make_rglru_state(cfg, batch, spec.n_layers)}
                )
            elif spec.cache == "mlstm":
                st = xlstm.make_xlstm_state(cfg, batch, spec.n_layers, 0)["mlstm"]
                stage_caches.append({"mlstm": st})
            elif spec.cache == "slstm":
                st = xlstm.make_xlstm_state(cfg, batch, 0, spec.n_layers)["slstm"]
                stage_caches.append({"slstm": st})
            else:
                stage_caches.append(None)
        out = {"stages": stage_caches, "pos": jnp.zeros((batch,), jnp.int32)}
        if cfg.family == "audio":
            out["enc_out"] = jnp.zeros(
                (batch, cfg.frontend_tokens, cfg.d_model), COMPUTE_DTYPE)
        if cfg.family == "vlm":
            out["frontend"] = jnp.zeros(
                (batch, cfg.frontend_tokens, cfg.d_model), COMPUTE_DTYPE)
        return out

    def input_specs(self, shape_name: str) -> dict:
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        cfg = self.cfg
        sh = SHAPES[shape_name]
        seq, batch, kind = sh["seq"], sh["batch"], sh["kind"]
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if kind == "train":
            dec_seq = seq // 4 if cfg.family == "audio" else seq
            spec = {"tokens": sds((batch, dec_seq), i32),
                    "targets": sds((batch, dec_seq), i32)}
            if cfg.family == "audio":
                spec["frontend"] = sds((batch, seq, cfg.d_model), COMPUTE_DTYPE)
            if cfg.family == "vlm":
                spec["frontend"] = sds((batch, cfg.frontend_tokens, cfg.d_model),
                                       COMPUTE_DTYPE)
            return spec
        if kind == "prefill":
            spec = {"tokens": sds((batch, seq), i32)}
            if cfg.family == "audio":
                spec["frontend"] = sds((batch, cfg.frontend_tokens, cfg.d_model),
                                       COMPUTE_DTYPE)
            if cfg.family == "vlm":
                spec["frontend"] = sds((batch, cfg.frontend_tokens, cfg.d_model),
                                       COMPUTE_DTYPE)
            return spec
        # decode: one new token against a seq-length cache
        caches = jax.eval_shape(lambda: self.make_caches(batch, seq))
        return {"token": sds((batch, 1), i32), "caches": caches}
