"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential) in pre-norm residual blocks.

mLSTM: per head, state C_t = f_t C_{t-1} + i_t v_t k_t^T, n_t = f_t n_{t-1}
+ i_t k_t, out h_t = (C_t q_t) / max(|n_t . q_t|, 1). Implemented CHUNKWISE:
intra-chunk quadratic + inter-chunk state scan => O(S * chunk) work, which
is what qualifies xlstm for the long_500k cell. Gates use exp(i) / sig(f)
with a running log-stabilizer folded into the chunk decays (we use
log-sigmoid forget + clipped log-input gates, computed in fp32).

sLSTM: per head scalar-memory LSTM with exponential input gating and a
block-diagonal recurrent matrix; inherently sequential -> lax.scan over S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, Array, ParamDef

CHUNK = 256

# Dry-run probe flag (see attention.UNROLL_SCANS). The sLSTM *time* scan is
# never unrolled (S steps); its FLOPs are corrected analytically in dryrun.
UNROLL_SCANS = False


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------
def mlstm_defs(cfg) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "wq": ParamDef((d, h * hd), ("embed", "qkv")),
        "wk": ParamDef((d, h * hd), ("embed", "qkv")),
        "wv": ParamDef((d, h * hd), ("embed", "qkv")),
        "wi": ParamDef((d, h), ("embed", "heads")),
        "wf": ParamDef((d, h), ("embed", "heads")),
        "wo_gate": ParamDef((d, h * hd), ("embed", "qkv")),
        "wo": ParamDef((h * hd, d), ("qkv", "embed")),
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i, state):
    """q,k,v: (B, H, S, hd); log_f, log_i: (B, H, S) fp32.
    state: (C0 (B,H,hd,hd), n0 (B,H,hd)) or None. Returns (out, state)."""
    b, h, s, hd = q.shape
    c = min(CHUNK, s)
    nc = s // c
    assert s % c == 0, f"seq {s} must divide chunk {c}"
    qc = q.reshape(b, h, nc, c, hd)
    kc = k.reshape(b, h, nc, c, hd)
    vc = v.reshape(b, h, nc, c, hd)
    lf = log_f.reshape(b, h, nc, c).astype(jnp.float32)
    li = log_i.reshape(b, h, nc, c).astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
    else:
        c0, n0 = state

    def step(carry, inp):
        C, n = carry
        qb, kb, vb, lfb, lib = inp  # (b,h,c,hd) ... (b,h,c)
        qf, kf, vf = (t.astype(jnp.float32) for t in (qb, kb, vb))
        cum = jnp.cumsum(lfb, axis=-1)                  # (b,h,c) inclusive
        tot = cum[..., -1:]
        # intra-chunk: D[i,j] = exp(cum_i - cum_j + li_j) for i >= j
        dmat = cum[..., :, None] - cum[..., None, :] + lib[..., None, :]
        mask = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.where(mask, dmat, -jnp.inf)
        scores = jnp.einsum("bhid,bhjd->bhij", qf, kf) * (hd ** -0.5)
        w = scores * jnp.exp(dmat)
        intra = jnp.einsum("bhij,bhjd->bhid", w, vf)
        # inter-chunk: decayed initial state
        dec_q = jnp.exp(cum)[..., None]                 # (b,h,c,1)
        inter = jnp.einsum("bhid,bhde->bhie", qf * dec_q, C) * (hd ** -0.5)
        # normalizer q . n_t, split the same way (intra = row-sum of w)
        n_inter = jnp.einsum("bhid,bhd->bhi", qf * dec_q, n) * (hd ** -0.5)
        n_intra_q = jnp.sum(w, axis=-1)
        num = intra + inter
        den = jnp.maximum(jnp.abs(n_inter + n_intra_q), 1.0)[..., None]
        out = num / den
        # state update: C' = exp(tot) C + sum_j exp(tot - cum_j + li_j) k_j v_j^T
        decay_j = jnp.exp(tot - cum + lib)[..., None]   # (b,h,c,1)
        Cn = jnp.exp(tot)[..., None] * C + jnp.einsum(
            "bhjd,bhje->bhde", kf * decay_j, vf
        )
        nn = jnp.exp(tot[..., 0])[..., None] * n + jnp.sum(kf * decay_j, axis=2)
        return (Cn, nn), out

    (cN, nN), outs = jax.lax.scan(
        step, (c0, n0),
        (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0),
         jnp.moveaxis(lf, 2, 0), jnp.moveaxis(li, 2, 0)),
        unroll=True if UNROLL_SCANS else 1,
    )
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, s, hd)
    return out, (cN, nN)


def mlstm_apply(p: dict, x: Array, cfg, state: dict | None = None
                ) -> tuple[Array, dict | None]:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    dt = COMPUTE_DTYPE
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    xf = x.astype(jnp.float32)
    log_i = jnp.clip(xf @ p["wi"].astype(jnp.float32), -10.0, 5.0).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(xf @ p["wf"].astype(jnp.float32) + 3.0).transpose(0, 2, 1)

    if state is not None and s == 1:
        # decode: single recurrent update
        C, n = state["C"], state["n"]
        f = jnp.exp(log_f[..., 0])[..., None, None]
        i = jnp.exp(log_i[..., 0])[..., None, None]
        kk = k[:, :, 0].astype(jnp.float32)
        vv = v[:, :, 0].astype(jnp.float32)
        Cn = f * C + i * jnp.einsum("bhd,bhe->bhde", kk, vv)
        nn = f[..., 0] * n + i[..., 0] * kk
        qq = q[:, :, 0].astype(jnp.float32) * (hd ** -0.5)
        num = jnp.einsum("bhd,bhde->bhe", qq, Cn)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qq, nn)), 1.0)
        out = (num / den[..., None])[:, :, None, :]
        new_state = {"C": Cn, "n": nn}
    else:
        st = None if state is None else (state["C"], state["n"])
        out, (cN, nN) = _mlstm_chunk_scan(q, k, v, log_f, log_i, st)
        new_state = None if state is None else {"C": cN, "n": nN}

    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd).astype(dt)
    gate = jax.nn.silu(x @ p["wo_gate"].astype(dt))
    return (out * gate) @ p["wo"].astype(dt), new_state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------
def slstm_defs(cfg) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "w_zifo": ParamDef((d, 4 * h * hd), ("embed", "qkv")),
        "r_zifo": ParamDef((h, hd, 4 * hd), ("heads", None, None), scale=0.05),
        "b_zifo": ParamDef((4 * h * hd,), ("qkv",), init="zeros"),
        "w_out": ParamDef((h * hd, d), ("qkv", "embed")),
    }


def slstm_apply(p: dict, x: Array, cfg, state: dict | None = None
                ) -> tuple[Array, dict | None]:
    """Sequential scan over time. state: {"c","n","h","m": (B, H, hd)}."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    zifo = (x.astype(jnp.float32) @ p["w_zifo"].astype(jnp.float32)
            + p["b_zifo"].astype(jnp.float32))
    zifo = zifo.reshape(b, s, h, 4 * hd)

    if state is None:
        c0 = jnp.zeros((b, h, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        h0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h, hd), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = state["c"], state["n"], state["h"], state["m"]

    r = p["r_zifo"].astype(jnp.float32)

    def step(carry, u):
        c, n, hh, m = carry  # (B, H, hd)
        rec = jnp.einsum("bhd,hde->bhe", hh, r)         # (B, H, 4hd)
        g = u + rec
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        # exponential gating with stabilizer m
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    (cN, nN, hN, mN), outs = jax.lax.scan(step, (c0, n0, h0, m0),
                                          jnp.moveaxis(zifo, 1, 0))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h * hd).astype(COMPUTE_DTYPE)
    new_state = None
    if state is not None:
        new_state = {"c": cN, "n": nN, "h": hN, "m": mN}
    return out @ p["w_out"].astype(COMPUTE_DTYPE), new_state


def make_xlstm_state(cfg, batch: int, n_m: int, n_s: int) -> dict:
    h, hd = cfg.n_heads, cfg.hd
    return {
        "mlstm": {
            "C": jnp.zeros((n_m, batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((n_m, batch, h, hd), jnp.float32),
        },
        "slstm": {
            "c": jnp.zeros((n_s, batch, h, hd), jnp.float32),
            "n": jnp.zeros((n_s, batch, h, hd), jnp.float32),
            "h": jnp.zeros((n_s, batch, h, hd), jnp.float32),
            "m": jnp.full((n_s, batch, h, hd), -1e30, jnp.float32),
        },
    }
