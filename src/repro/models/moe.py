"""Mixture-of-Experts layer: top-k routing with shared experts.

Two implementations, selectable via `impl`:

  * "sorted" (default): sort-based token dispatch -- token slots are sorted
    by expert id, scattered into a capacity-bounded (E, C, D) buffer, run
    through batched expert matmuls, and combined by scatter-add. Only real
    FLOPs are the expert matmuls (gathers/scatters are data movement), so
    HLO FLOPs track active-expert MODEL_FLOPS.
  * "dense": every expert runs on every token, combined with routing probs.
    Trivially shardable and numerically identical, but E/k x the FLOPs --
    kept as the oracle for tests and as a fallback.

Router z-loss and load-balance aux loss are returned for the train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, Array, ParamDef


def moe_defs(cfg) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    defs = {
        "router": ParamDef((d, e), ("embed", "experts_row")),
        "w1": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "w3": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "w2": ParamDef((e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        defs["sw1"] = ParamDef((d, fs), ("embed", "mlp"))
        defs["sw3"] = ParamDef((d, fs), ("embed", "mlp"))
        defs["sw2"] = ParamDef((fs, d), ("mlp", "embed"))
    return defs


def _router(p: dict, xt: Array, cfg) -> tuple[Array, Array, Array]:
    """Returns (gates (N,k), idx (N,k), aux_loss ())."""
    logits = (xt @ p["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + router z-loss
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0
    )
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, idx, lb + 1e-3 * z


def _experts_sorted(p: dict, xt: Array, gates: Array, idx: Array, cfg,
                    capacity_factor: float = 1.25) -> Array:
    n, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    nk = n * k
    cap = int((nk / e) * capacity_factor + 0.5)
    cap = max(8, ((cap + 7) // 8) * 8)

    flat_e = idx.reshape(nk)                        # expert of each slot
    order = jnp.argsort(flat_e)                     # stable sort by expert
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(nk) - starts[sorted_e]        # position within expert
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, e * cap)  # OOB -> dropped
    tok = order // k                                # source token per slot

    buf = jnp.zeros((e * cap, d), COMPUTE_DTYPE)
    buf = buf.at[dest].set(xt[tok], mode="drop")
    h = buf.reshape(e, cap, d)
    dt = COMPUTE_DTYPE
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["w1"].astype(dt)))
    hidden = hidden * jnp.einsum("ecd,edf->ecf", h, p["w3"].astype(dt))
    out = jnp.einsum("ecf,efd->ecd", hidden, p["w2"].astype(dt))
    out_flat = out.reshape(e * cap, d)

    gate_slot = gates.reshape(nk)[order].astype(dt)  # aligned with sorted slots
    contrib = out_flat[jnp.where(keep, dest, 0)] * jnp.where(keep, gate_slot, 0.0)[:, None]
    y = jnp.zeros((n, d), dt).at[tok].add(contrib, mode="drop")
    return y


def _experts_dense(p: dict, xt: Array, gates: Array, idx: Array, cfg) -> Array:
    e = cfg.n_experts
    dt = COMPUTE_DTYPE
    # combine weights (N, E): sum of gate over the slots routed to e
    comb = jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32) * gates[..., None], axis=1)
    hidden = jax.nn.silu(jnp.einsum("nd,edf->enf", xt, p["w1"].astype(dt)))
    hidden = hidden * jnp.einsum("nd,edf->enf", xt, p["w3"].astype(dt))
    out = jnp.einsum("enf,efd->end", hidden, p["w2"].astype(dt))
    return jnp.einsum("end,ne->nd", out, comb.astype(dt))


def moe_apply(p: dict, x: Array, cfg, impl: str = "sorted",
              capacity_factor: float = 1.25) -> tuple[Array, Array]:
    """x: (B, S, D). Returns (y, aux_loss)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gates, idx, aux = _router(p, xt, cfg)
    if impl == "sorted":
        y = _experts_sorted(p, xt, gates, idx, cfg, capacity_factor)
    else:
        y = _experts_dense(p, xt, gates, idx, cfg)
    if cfg.n_shared_experts:
        dt = COMPUTE_DTYPE
        h = jax.nn.silu(xt @ p["sw1"].astype(dt)) * (xt @ p["sw3"].astype(dt))
        y = y + h @ p["sw2"].astype(dt)
    return y.reshape(b, s, d), aux
