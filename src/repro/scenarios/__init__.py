"""Time-correlated NOMA-MEC scenarios: Gauss-Markov fading, random-waypoint
mobility, Poisson churn, and named deployment presets."""
from repro.scenarios import churn, fading, mobility, presets  # noqa: F401
from repro.scenarios.scenario import (  # noqa: F401
    Scenario,
    ScenarioConfig,
    ScenarioState,
)
