"""Named deployment presets: canonical scenario families for benchmarks and
examples. Doppler values follow f_d = v / lambda_c at a ~2 GHz carrier
(lambda_c ~ 0.15 m): pedestrian ~1.4 m/s -> ~9 Hz, vehicular 30 m/s -> 200 Hz.
"""
from __future__ import annotations

from repro.scenarios.scenario import ScenarioConfig

_PRESETS: dict[str, ScenarioConfig] = {
    # Many pedestrian users, dense small cells, steady churn from shops and
    # transit. 10 ms re-planning epochs: at pedestrian Doppler the channel
    # stays ~92% correlated between plans, so warm starts track it cheaply.
    "dense_urban": ScenarioConfig(
        name="dense_urban", n_users=24, n_aps=6, n_sub=8,
        epoch_dt_s=0.01, doppler_hz=9.0, speed_mps=1.4,
        arrival_rate_hz=2.0, cluster_frac=0.5, n_clusters=3,
        cluster_radius_m=40.0,
    ),
    # Vehicular speeds: 200 Hz Doppler fully decorrelates fading between
    # 50 ms epochs (rho = 0) -- the stress case where warm starts cannot help
    # and cold re-planning is the right strategy.
    "highway": ScenarioConfig(
        name="highway", n_users=12, n_aps=3, n_sub=4,
        epoch_dt_s=0.05, doppler_hz=200.0, speed_mps=30.0,
        arrival_rate_hz=1.0,
    ),
    # Most users packed around a couple of hotspots (stadium gates, cafes):
    # heavy intra-cell NOMA contention at the hotspot APs.
    "hotspot": ScenarioConfig(
        name="hotspot", n_users=16, n_aps=4, n_sub=6,
        epoch_dt_s=0.01, doppler_hz=6.0, speed_mps=0.8,
        cluster_frac=0.9, n_clusters=2, cluster_radius_m=25.0,
    ),
    # Massive static IoT: big U, nearly-frozen channels, rare battery-driven
    # churn -- the best case for the online warm start.
    "iot_massive": ScenarioConfig(
        name="iot_massive", n_users=48, n_aps=4, n_sub=12,
        epoch_dt_s=1.0, doppler_hz=0.02, speed_mps=0.0,
        arrival_rate_hz=0.2,
    ),
}


def names() -> list[str]:
    return sorted(_PRESETS)


def get(name: str) -> ScenarioConfig:
    try:
        return _PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; available: {names()}") from None
