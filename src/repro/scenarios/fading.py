"""Time-correlated small-scale fading: first-order Gauss-Markov (AR(1))
evolution of the complex channel coefficients.

The seed's make_env draws i.i.d. Rayleigh fading (|h|^2 ~ Exp(1)) per epoch.
Here the complex coefficient h ~ CN(0, 1) evolves as

    h[t+1] = rho * h[t] + sqrt(1 - rho^2) * w,   w ~ CN(0, 1)

which keeps the Rayleigh marginal exactly (|h|^2 stays Exp(1)) while giving
correlation E[h[t+1] h*[t]] = rho between re-planning epochs -- the property
the online planner's warm start exploits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import Array


def init_coeffs(key: jax.Array, shape: tuple[int, ...]) -> Array:
    """CN(0, 1) coefficients: |h|^2 ~ Exp(1), matching make_env's marginal."""
    kr, ki = jax.random.split(key)
    scale = jnp.sqrt(0.5)
    return (jax.random.normal(kr, shape) * scale
            + 1j * jax.random.normal(ki, shape) * scale).astype(jnp.complex64)


def gauss_markov_step(key: jax.Array, h: Array, rho: float | Array) -> Array:
    """One AR(1) step; rho in [0, 1] (1 = frozen channel, 0 = i.i.d.)."""
    w = init_coeffs(key, h.shape)
    rho = jnp.asarray(rho, dtype=jnp.float32)
    return rho * h + jnp.sqrt(jnp.maximum(1.0 - rho * rho, 0.0)) * w


def power_gain(h: Array) -> Array:
    """|h|^2 as fp32 (the linear power gain used by the channel model)."""
    return (h.real * h.real + h.imag * h.imag).astype(jnp.float32)


def jakes_rho(doppler_hz: float, dt_s: float) -> float:
    """Epoch-to-epoch correlation for Jakes' model, rho = J0(2 pi f_d dt).

    Small-argument Bessel series (enough terms for the x <= ~3 regime that
    matters here), clipped to [0, 1] -- beyond the first J0 zero the channel
    is effectively decorrelated for warm-start purposes.
    """
    x = 2.0 * jnp.pi * doppler_hz * dt_s
    if x >= 2.405:  # first J0 zero: treat faster motion as fully decorrelated
        return 0.0
    x2 = (x / 2.0) ** 2
    j0 = 1.0 - x2 + x2**2 / 4.0 - x2**3 / 36.0 + x2**4 / 576.0
    return float(jnp.clip(j0, 0.0, 1.0))
