"""User mobility: random-waypoint motion inside the square service area.

Each user moves toward a private waypoint at the scenario speed; on arrival
(within one epoch's travel distance) a fresh waypoint is drawn. Positions
drive the large-scale path loss, so mobility couples into the planner through
slowly-drifting channel gains and occasional nearest-AP handovers.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Array


class MobilityState(NamedTuple):
    pos: Array       # (U, 2) current positions, meters
    waypoint: Array  # (U, 2) targets


def init_positions(
    key: jax.Array,
    n_users: int,
    side_m: float,
    cluster_frac: float = 0.0,
    n_clusters: int = 1,
    cluster_radius_m: float = 30.0,
) -> Array:
    """Uniform positions, with an optional fraction packed around hotspot
    cluster centers (truncated-Gaussian blobs)."""
    k_u, k_c, k_pick, k_off = jax.random.split(key, 4)
    uniform = jax.random.uniform(k_u, (n_users, 2), minval=0.0, maxval=side_m)
    if cluster_frac <= 0.0:
        return uniform
    centers = jax.random.uniform(k_c, (n_clusters, 2), minval=0.0, maxval=side_m)
    which = jax.random.randint(k_pick, (n_users,), 0, n_clusters)
    offsets = jax.random.normal(k_off, (n_users, 2)) * cluster_radius_m
    clustered = jnp.clip(centers[which] + offsets, 0.0, side_m)
    in_cluster = (jnp.arange(n_users) < cluster_frac * n_users)[:, None]
    return jnp.where(in_cluster, clustered, uniform)


def init_state(key: jax.Array, pos: Array, side_m: float) -> MobilityState:
    wp = jax.random.uniform(key, pos.shape, minval=0.0, maxval=side_m)
    return MobilityState(pos=pos, waypoint=wp)


def waypoint_step(
    key: jax.Array, state: MobilityState, speed_mps: float, dt_s: float,
    side_m: float,
) -> MobilityState:
    """Advance every user by speed*dt toward its waypoint; re-draw reached
    waypoints. speed == 0 degenerates to a static scenario."""
    delta = state.waypoint - state.pos
    dist = jnp.linalg.norm(delta, axis=-1, keepdims=True)
    travel = speed_mps * dt_s
    step = jnp.where(dist > 1e-9, delta / jnp.maximum(dist, 1e-9), 0.0) * travel
    arrived = dist[:, 0] <= travel
    new_pos = jnp.where(arrived[:, None], state.waypoint, state.pos + step)
    fresh = jax.random.uniform(key, state.waypoint.shape, minval=0.0,
                               maxval=side_m)
    new_wp = jnp.where(arrived[:, None], fresh, state.waypoint)
    return MobilityState(pos=new_pos, waypoint=new_wp)
