"""User arrival/departure churn with a fixed-size user pool.

The planner's compiled programs are cached per environment *shape*, so churn
must not change U between epochs. We therefore model Poisson churn as slot
replacement: departures free a slot that the next arrival immediately reuses.
Each epoch draws K ~ Poisson(rate * dt) replacement events (approximated per
user as an independent Bernoulli with the matched mean, exact in the sparse
regime rate*dt << U); a replaced user gets a fresh position, waypoint, and
decorrelated fading -- exactly what a new user joining the cell looks like to
the planner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import Array
from repro.scenarios import fading
from repro.scenarios.mobility import MobilityState


def replacement_mask(key: jax.Array, n_users: int, rate_hz: float,
                     dt_s: float) -> Array:
    """(U,) bool: which user slots are replaced this epoch."""
    p = jnp.clip(rate_hz * dt_s / max(n_users, 1), 0.0, 1.0)
    return jax.random.bernoulli(key, p, (n_users,))


def apply_churn(
    key: jax.Array,
    mask: Array,                 # (U,) bool
    mob: MobilityState,
    h_up: Array,                 # (U, N, M) complex
    h_dn: Array,                 # (U, N, M) complex
    side_m: float,
) -> tuple[MobilityState, Array, Array]:
    """Resample position/waypoint/fading for masked slots; others untouched."""
    k_pos, k_wp, k_up, k_dn = jax.random.split(key, 4)
    pos_new = jax.random.uniform(k_pos, mob.pos.shape, minval=0.0, maxval=side_m)
    wp_new = jax.random.uniform(k_wp, mob.waypoint.shape, minval=0.0,
                                maxval=side_m)
    m2 = mask[:, None]
    m3 = mask[:, None, None]
    mob = MobilityState(
        pos=jnp.where(m2, pos_new, mob.pos),
        waypoint=jnp.where(m2, wp_new, mob.waypoint),
    )
    h_up = jnp.where(m3, fading.init_coeffs(k_up, h_up.shape), h_up)
    h_dn = jnp.where(m3, fading.init_coeffs(k_dn, h_dn.shape), h_dn)
    return mob, h_up, h_dn
