"""Time-evolving NOMA network scenarios: the environment generator feeding
the online PlannerEngine.

A Scenario composes three processes, all with static shapes so every epoch's
NetworkEnv hits the same compiled solver:

  * Gauss-Markov (AR(1)) Rayleigh fading   -- scenarios.fading
  * random-waypoint user mobility          -- scenarios.mobility
  * Poisson slot-replacement churn         -- scenarios.churn

`step` advances one re-planning epoch and emits the NetworkEnv realization;
`episode` rolls a whole correlated sequence. Epoch 0's env is distributed
exactly like core.channel.make_env (uniform positions, Exp(1) fading).

`init_many`/`step_many`/`env_many` are the jitted + vmapped fleet variants:
B independent realizations of the same ScenarioConfig evolving in parallel
(leaves lead with B), feeding PlannerEngine.plan_many/replan_many with one
compiled program. step_many optionally takes a per-member fading rho, so a
single fleet can sweep correlation levels. Because every fleet op is a
compiled program over device-resident state, the whole online epoch loop
(step_many -> env_many -> replan_many -> serve decision) enqueues
asynchronously without leaving the device.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    Array,
    ComputeConstants,
    NetworkEnv,
    RadioConstants,
)
from repro.scenarios import churn, fading, mobility


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Knobs of a time-evolving deployment. fading_rho overrides the
    Jakes-derived correlation when set; speed_mps=0 freezes mobility and
    arrival_rate_hz=0 disables churn."""

    n_users: int = 12
    n_aps: int = 3
    n_sub: int = 4
    epoch_dt_s: float = 0.1
    doppler_hz: float = 5.0
    fading_rho: float | None = None
    speed_mps: float = 1.4
    arrival_rate_hz: float = 0.0
    cluster_frac: float = 0.0
    n_clusters: int = 1
    cluster_radius_m: float = 30.0
    radio: RadioConstants = RadioConstants()
    comp: ComputeConstants = ComputeConstants()
    name: str = "custom"

    @property
    def rho(self) -> float:
        if self.fading_rho is not None:
            return float(self.fading_rho)
        return fading.jakes_rho(self.doppler_hz, self.epoch_dt_s)

    @property
    def side_m(self) -> float:
        return self.radio.cell_radius_m * max(1.0, self.n_aps**0.5)


class ScenarioState(NamedTuple):
    mob: mobility.MobilityState
    ap_pos: Array    # (N, 2) fixed for the episode
    h_up: Array      # (U, N, M) complex64
    h_dn: Array      # (U, N, M) complex64
    epoch: Array     # () int32


class Scenario:
    def __init__(self, cfg: ScenarioConfig):
        self._cfg = cfg

    @property
    def cfg(self) -> ScenarioConfig:
        """Read-only: the jitted fleet ops close over the config (and its
        Jakes rho) at first use, so mutating it afterwards would be silently
        ignored -- build a new Scenario for new parameters."""
        return self._cfg

    # -- state ------------------------------------------------------------
    def init(self, key: jax.Array) -> ScenarioState:
        cfg = self.cfg
        k_ap, k_pos, k_wp, k_up, k_dn = jax.random.split(key, 5)
        ap_pos = jax.random.uniform(k_ap, (cfg.n_aps, 2), minval=0.0,
                                    maxval=cfg.side_m)
        pos = mobility.init_positions(
            k_pos, cfg.n_users, cfg.side_m, cluster_frac=cfg.cluster_frac,
            n_clusters=cfg.n_clusters, cluster_radius_m=cfg.cluster_radius_m,
        )
        mob = mobility.init_state(k_wp, pos, cfg.side_m)
        shape = (cfg.n_users, cfg.n_aps, cfg.n_sub)
        return ScenarioState(
            mob=mob, ap_pos=ap_pos,
            h_up=fading.init_coeffs(k_up, shape),
            h_dn=fading.init_coeffs(k_dn, shape),
            epoch=jnp.int32(0),
        )

    def step(self, key: jax.Array, state: ScenarioState,
             rho: Array | float | None = None) -> ScenarioState:
        """Advance one epoch. `rho` overrides the config's fading correlation
        (may be a traced scalar, enabling per-member sweeps under vmap)."""
        cfg = self.cfg
        k_mob, k_up, k_dn, k_mask, k_churn = jax.random.split(key, 5)
        mob = mobility.waypoint_step(k_mob, state.mob, cfg.speed_mps,
                                     cfg.epoch_dt_s, cfg.side_m)
        rho = cfg.rho if rho is None else rho
        h_up = fading.gauss_markov_step(k_up, state.h_up, rho)
        h_dn = fading.gauss_markov_step(k_dn, state.h_dn, rho)
        if cfg.arrival_rate_hz > 0.0:
            mask = churn.replacement_mask(k_mask, cfg.n_users,
                                          cfg.arrival_rate_hz, cfg.epoch_dt_s)
            mob, h_up, h_dn = churn.apply_churn(k_churn, mask, mob, h_up,
                                                h_dn, cfg.side_m)
        return ScenarioState(mob=mob, ap_pos=state.ap_pos, h_up=h_up,
                             h_dn=h_dn, epoch=state.epoch + 1)

    # -- realization ------------------------------------------------------
    def env(self, state: ScenarioState) -> NetworkEnv:
        """Materialize the NetworkEnv for the current epoch: path loss from
        positions x Gauss-Markov fading power, nearest-AP association."""
        cfg = self.cfg
        d = jnp.linalg.norm(state.mob.pos[:, None, :] - state.ap_pos[None, :, :],
                            axis=-1)
        d = jnp.maximum(d, 1.0)
        path = d ** (-cfg.radio.path_loss_exp)            # (U, N)
        g_up = path[:, :, None] * fading.power_gain(state.h_up)
        g_dn = jnp.swapaxes(path[:, :, None] * fading.power_gain(state.h_dn),
                            0, 1)                          # (N, U, M)
        ap = jnp.argmax(path, axis=1).astype(jnp.int32)
        return NetworkEnv(g_up=g_up, g_dn=g_dn, ap=ap, radio=cfg.radio,
                          comp=cfg.comp)

    # -- jitted fleet API --------------------------------------------------
    # Each fleet op is jit(vmap(...)) built once per Scenario (jit's own
    # cache then keys on the fleet size), so an online epoch loop dispatches
    # compiled programs over device-resident state instead of re-tracing
    # vmaps -- nothing syncs to host between step, env, and replan.
    @functools.cached_property
    def _init_many(self):
        return jax.jit(jax.vmap(self.init))

    @functools.cached_property
    def _step_many(self):
        # The config's Jakes-derived rho is host math (float()) -- hoist it
        # out of the trace and close over it as a constant.
        rho = self.cfg.rho
        return jax.jit(jax.vmap(lambda k, s: self.step(k, s, rho)))

    @functools.cached_property
    def _step_many_rho(self):
        return jax.jit(jax.vmap(self.step, in_axes=(0, 0, 0)))

    @functools.cached_property
    def _env_many(self):
        return jax.jit(jax.vmap(self.env))

    def init_many(self, keys: jax.Array) -> ScenarioState:
        """Initialize B independent realizations; keys: (B, 2) from
        jax.random.split. Returned leaves lead with B."""
        return self._init_many(keys)

    def step_many(self, keys: jax.Array, states: ScenarioState,
                  rho: Array | None = None) -> ScenarioState:
        """Advance every fleet member one epoch. rho: optional (B,) per-member
        fading correlation override (sweep rho across the fleet in one
        compiled program)."""
        if rho is None:
            return self._step_many(keys, states)
        return self._step_many_rho(keys, states, jnp.asarray(rho))

    def env_many(self, states: ScenarioState) -> NetworkEnv:
        """Materialize the stacked NetworkEnv of the fleet (leaves lead with
        B; constant radio/comp scalars are broadcast), ready for
        PlannerEngine.plan_many/replan_many."""
        return self._env_many(states)

    def episode(self, key: jax.Array, n_epochs: int) -> Iterator[NetworkEnv]:
        """Yield n_epochs correlated NetworkEnv realizations."""
        k_init, key = jax.random.split(key)
        state = self.init(k_init)
        for _ in range(n_epochs):
            yield self.env(state)
            k_step, key = jax.random.split(key)
            state = self.step(k_step, state)

    def episode_list(self, key: jax.Array, n_epochs: int) -> list[NetworkEnv]:
        return list(self.episode(key, n_epochs))
