"""Deterministic synthetic data pipeline.

Stateless-resumable: batch content is a pure function of (seed, step), so a
restarted/rescaled job reproduces the exact stream with no iterator state in
checkpoints. Host-sharded: each process materializes only its slice
(process_index/process_count), which is the multi-host pattern; prefetch
runs on a background thread.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


def make_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
               frontend_shape=None, process_index: int = 0,
               process_count: int = 1) -> dict:
    """Markov-ish synthetic LM stream (not uniform noise: loss can improve)."""
    local = batch // process_count
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, process_index]))
    # blocky structure: repeat short motifs so there is signal to learn
    motifs = rng.integers(0, vocab, size=(local, 8), dtype=np.int32)
    reps = seq // 8 + 1
    toks = np.tile(motifs, (1, reps))[:, :seq]
    noise = rng.integers(0, vocab, size=(local, seq), dtype=np.int32)
    mask = rng.random((local, seq)) < 0.1
    toks = np.where(mask, noise, toks).astype(np.int32)
    out = {
        "tokens": jnp.asarray(toks),
        "targets": jnp.asarray(np.roll(toks, -1, axis=1)),
    }
    if frontend_shape is not None:
        f = rng.standard_normal((local, *frontend_shape)).astype(np.float32)
        out["frontend"] = jnp.asarray(0.1 * f, jnp.bfloat16)
    return out


class SyntheticLM:
    """Prefetching iterator over make_batch(seed, step, ...)."""

    def __init__(self, seed: int, batch: int, seq: int, vocab: int,
                 frontend_shape=None, start_step: int = 0, prefetch: int = 2):
        self.seed, self.batch, self.seq, self.vocab = seed, batch, seq, vocab
        self.frontend_shape = frontend_shape
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            b = make_batch(self.seed, s, self.batch, self.seq, self.vocab,
                           self.frontend_shape)
            self._q.put((s, b))
            s += 1

    def __next__(self):
        s, b = self._q.get()
        self.step = s + 1
        return b

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
