from repro.optim.adamw import AdamWState, adamw_init, adamw_update, cosine_lr  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_topk,
    decompress_topk,
    error_feedback_update,
)
