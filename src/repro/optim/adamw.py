"""AdamW + cosine schedule, as plain pytree functions (no optax offline)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=z,
                      v=jax.tree.map(jnp.zeros_like, params))


def cosine_lr(step, base_lr=3e-4, warmup=100, total=10000, min_frac=0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(1, warmup)
    prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = min_frac * base_lr + (1 - min_frac) * base_lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm=1.0):
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state: AdamWState, lr,
                 b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, state.v, grads)

    def upd(p, mm, vv):
        mh = mm / (1 - b1 ** t)
        vh = vv / (1 - b2 ** t)
        return p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v)
