"""Top-k gradient compression with error feedback (beyond-paper distributed
trick; Lin et al. "Deep Gradient Compression", arXiv:1712.01887 adapted).

Used on the data-parallel all-reduce path inside the shard_map train step:
each shard sends only the top k fraction of |g| entries (values + indices),
the reduction is a sum of sparse contributions via all_gather + scatter-add,
and the un-sent residual is carried into the next step (error feedback).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_topk(g: jax.Array, k_frac: float = 0.01):
    """Returns (values, flat_indices) of the top-k |entries|."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * k_frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def decompress_topk(values, idx, shape, dtype):
    out = jnp.zeros(int(jnp.prod(jnp.array(shape))), dtype)
    return out.at[idx].add(values.astype(dtype)).reshape(shape)


def error_feedback_update(g, residual, k_frac: float = 0.01):
    """One-device view: compress(g + residual); returns (g_hat, new_residual).
    In the distributed step, g_hat is what gets summed across shards."""
    acc = g + residual
    vals, idx = compress_topk(acc, k_frac)
    g_hat = decompress_topk(vals, idx, g.shape, g.dtype)
    return g_hat, acc - g_hat


def compressed_psum(g: jax.Array, axis_name: str, residual: jax.Array,
                    k_frac: float = 0.01):
    """Sparse all-reduce inside shard_map: top-k per shard -> all_gather of
    (values, indices) -> local scatter-add. Comm volume = 2 * k_frac of dense
    (values + indices) * world instead of the dense ring all-reduce."""
    acc = g + residual
    vals, idx = compress_topk(acc, k_frac)
    new_residual = acc - decompress_topk(vals, idx, g.shape, g.dtype)
    all_vals = jax.lax.all_gather(vals, axis_name)    # (W, k)
    all_idx = jax.lax.all_gather(idx, axis_name)      # (W, k)
    flat = jnp.zeros(g.size, g.dtype)
    flat = flat.at[all_idx.reshape(-1)].add(all_vals.reshape(-1).astype(g.dtype))
    return flat.reshape(g.shape), new_residual
