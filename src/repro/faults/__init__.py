"""Chaos engineering for the closed loop: seeded fault injection, in-jit
health guards, and the graceful-degradation ladder.

Three layers, mirroring the discipline of the measured-profile feedback
path (everything that varies per epoch is an *operand* of an
already-compiled program, never a trace-time constant):

* ``injectors`` -- deterministic fault processes (deep-fade link outages,
  AP blackouts, telemetry dropout/corruption, service-time spikes) traced
  into the compiled epoch program. Fault rates are f32 device scalars
  (``FaultConfig.rates()``), so sweeping an outage rate is an operand swap
  with zero recompiles; the persistent outage masks are a ``FaultState``
  pytree donated across epochs like every other loop state.
* ``guards`` -- in-jit finiteness/feasibility checks over plans, measured
  profiles, observations, and service times, packed into ONE int32 health
  word per epoch. The loop's host-sync budget stays at PR 8's two scalars
  plus this word; the planner's plan check rides the existing s* sync as
  ``(health << 16) | s``.
* ``degrade`` -- the host-side degradation ladder
  (reject-and-hold-last-good-plan -> telemetry quarantine -> baseline
  fallback -> cold replan with exponential backoff) plus the epoch
  watchdog generalizing ``runtime.ft`` to the serving path.

Machine-checked by ``repro.analysis.fault_audit`` (blocking in CI) and
exercised by ``benchmarks/chaos_serve.py``.
"""
from repro.faults.degrade import (  # noqa: F401
    DegradeLadder,
    EpochWatchdog,
    LadderConfig,
    fallback_plan,
)
from repro.faults.guards import (  # noqa: F401
    HEALTH_BITS,
    PLAN_MASK,
    PLAN_WORD_SHIFT,
    TELEMETRY_MASK,
    decode_health,
    observation_health,
    pack_health,
    plan_health,
    plan_word,
    service_health,
    split_plan_word,
    telemetry_health,
    tree_select,
)
from repro.faults.injectors import (  # noqa: F401
    FaultConfig,
    FaultDraw,
    FaultRates,
    FaultState,
    apply_env_faults,
    corrupt_observation,
    fault_step,
    init_fault_state,
    spike_service,
)
