"""In-jit health guards: finiteness/feasibility checks packed into one word.

Every check runs inside an already-compiled program and produces an int32
bit; the bits OR into a single *health word* so the host learns everything
it needs for the degradation ladder from ONE extra scalar per epoch -- the
loop's sync budget stays at PR 8's two scalars plus this word. The
planner-side plan check does not even cost that: it rides the existing
s*-sync as ``(health << PLAN_WORD_SHIFT) | s`` (``plan_word`` /
``split_plan_word``), so a guarded replan still syncs exactly one scalar.

Bit layout (LSB first; 0 = healthy):

  0 plan_utility   plan utility or per-layer utility non-finite
  1 plan_power     power vector non-finite or outside [0, p_max]
  2 plan_alloc     edge compute allocation non-finite or outside [0, r_max]
  3 plan_subch     subchannel index outside [0, M)
  4 profile        measured-profile tables (fl/w/m_down) non-finite
  5 kappa          congestion estimate non-finite or past ``kappa_max``
  6 telemetry      this epoch's observation non-finite
  7 service        this epoch's modeled service times non-finite

Bits 0-3 are planner-side (checked at replan, ``PLAN_MASK``); bits 4-6 are
the telemetry-quarantine trigger (``TELEMETRY_MASK``); bit 7 is
informational (service corruption surfaces in shedding/QoS).
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core.types import Array, SplitPlan

if TYPE_CHECKING:  # repro.online imports the loop, which imports this
    # package back -- annotation-only here keeps the import acyclic
    from repro.online.telemetry import Observation, TelemetryState

HEALTH_BITS: dict[str, int] = {
    "plan_utility": 0,
    "plan_power": 1,
    "plan_alloc": 2,
    "plan_subch": 3,
    "profile": 4,
    "kappa": 5,
    "telemetry": 6,
    "service": 7,
}

PLAN_MASK = 0b1111
TELEMETRY_MASK = (1 << HEALTH_BITS["profile"]) | (1 << HEALTH_BITS["kappa"]) \
    | (1 << HEALTH_BITS["telemetry"])

# The planner's packed word: health in the high bits, s* in the low 16.
PLAN_WORD_SHIFT = 16


def _bit(unhealthy: Array, name: str) -> Array:
    return jnp.where(unhealthy, jnp.int32(1 << HEALTH_BITS[name]),
                     jnp.int32(0))


def _all_finite(*xs: Array) -> Array:
    ok = jnp.bool_(True)
    for x in xs:
        ok = ok & jnp.all(jnp.isfinite(x))
    return ok


def plan_health(plan: SplitPlan, *, n_sub: int, p_up_max: float,
                p_dn_max: float, r_max: float, slack: float = 1.05) -> Array:
    """() int32 over bits 0-3. ``slack`` absorbs rounding noise at the box
    boundaries -- the guard exists to catch corruption (NaN/Inf, wildly
    infeasible values), not to re-litigate the solver's projection."""
    bad_util = ~_all_finite(plan.utility, plan.per_layer_utility)
    ok_pow = (_all_finite(plan.p_up, plan.p_dn)
              & jnp.all(plan.p_up >= 0.0)
              & jnp.all(plan.p_up <= p_up_max * slack)
              & jnp.all(plan.p_dn >= 0.0)
              & jnp.all(plan.p_dn <= p_dn_max * slack))
    ok_alloc = (_all_finite(plan.r) & jnp.all(plan.r >= 0.0)
                & jnp.all(plan.r <= r_max * slack))
    ok_sub = (jnp.all((plan.sub_up >= 0) & (plan.sub_up < n_sub))
              & jnp.all((plan.sub_dn >= 0) & (plan.sub_dn < n_sub)))
    return (_bit(bad_util, "plan_utility") | _bit(~ok_pow, "plan_power")
            | _bit(~ok_alloc, "plan_alloc") | _bit(~ok_sub, "plan_subch"))


def telemetry_health(state: TelemetryState, kappa_max: float) -> Array:
    """() int32 over bits 4-5: is the measured profile still a sane planner
    operand? A kappa past ``kappa_max`` is finite but no longer a credible
    congestion estimate (a spiked sample landed) -- quarantine territory."""
    bad_prof = ~_all_finite(state.fl, state.w, state.m_down, state.rate_dn,
                            state.r_units)
    bad_kappa = ~(jnp.isfinite(state.kappa) & (state.kappa <= kappa_max))
    return _bit(bad_prof, "profile") | _bit(bad_kappa, "kappa")


def observation_health(obs: Observation) -> Array:
    """() int32, bit 6: this epoch's telemetry sample arrived intact."""
    bad = ~_all_finite(obs.t_layer, obs.t_up, obs.rate_up, obs.rate_dn,
                       obs.r_units)
    return _bit(bad, "telemetry")


def service_health(service: Array) -> Array:
    """() int32, bit 7: modeled service times are finite."""
    return _bit(~_all_finite(service), "service")


def pack_health(*words: Array) -> Array:
    """OR component words into the epoch's single health scalar."""
    out = jnp.int32(0)
    for w in words:
        out = out | w
    return out


def plan_word(plan: SplitPlan, *, n_sub: int, p_up_max: float,
              p_dn_max: float, r_max: float) -> Array:
    """() int32 ``(plan_health << PLAN_WORD_SHIFT) | s``: the guarded
    replan's one host sync carries both the re-cut decision and the plan's
    health. s is clamped into the low half-word; a non-finite or negative
    s maps to 0 with the utility bit necessarily set alongside it."""
    h = plan_health(plan, n_sub=n_sub, p_up_max=p_up_max, p_dn_max=p_dn_max,
                    r_max=r_max)
    s = jnp.clip(plan.s.astype(jnp.int32), 0, (1 << PLAN_WORD_SHIFT) - 1)
    return (h << PLAN_WORD_SHIFT) | s


def split_plan_word(word: int) -> tuple[int, int]:
    """Host-side unpack of ``plan_word`` -> (health, s)."""
    w = int(word)
    return w >> PLAN_WORD_SHIFT, w & ((1 << PLAN_WORD_SHIFT) - 1)


def decode_health(word: int) -> dict[str, bool]:
    """Host-side: name -> bit set? (metrics/debugging; never in-jit)."""
    w = int(word)
    return {name: bool(w & (1 << bit)) for name, bit in HEALTH_BITS.items()}


def tree_select(keep_new: Array, new, old):
    """Per-leaf where over matching pytrees: the in-jit quarantine gate
    (corrupt observation -> hold the previous telemetry state)."""
    return jax.tree.map(lambda a, b: jnp.where(keep_new, a, b), new, old)
