"""Seeded, deterministic fault processes for the closed serving loop.

Every fault is drawn inside the compiled epoch program from the SAME
epoch-folded key stream the scenario uses, so an episode is exactly
reproducible from its seed, and every per-epoch quantity is a device
array -- injection moves nothing to host and traces nothing after warmup.

The knobs follow the ``prof=`` operand discipline: ``FaultConfig`` is the
host-side description, ``FaultConfig.rates()`` lowers it to ``FaultRates``,
a NamedTuple of f32 device *scalars* that enter the epoch program as plain
operands. Sweeping an outage rate (benchmarks/chaos_serve.py) swaps the
operand; the program's cache key never sees the numbers.

Link outages and AP blackouts are persistent Gilbert-Elliott-style Markov
processes, not per-epoch coin flips: a user in a deep fade stays faded for
``link_mean_epochs`` on average, which is what makes holding the last good
plan (rather than replanning into the fade every epoch) a meaningful
strategy. The outage masks live in ``FaultState``, donated across epochs
like every other loop state pytree. ``link_outage_rate`` /
``ap_outage_rate`` are the *long-run fraction of time* spent in outage
(the acceptance criterion's "20% link-outage rate"), from which the
per-epoch onset probability is derived.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Array, NetworkEnv

if TYPE_CHECKING:  # repro.online imports the loop, which imports this
    # package back -- annotation-only here keeps the import acyclic
    from repro.online.telemetry import Observation


class FaultRates(NamedTuple):
    """Per-epoch fault probabilities/scales as f32 device scalars -- the
    epoch program's fault operand (same avals for every config)."""

    link_fail: Array        # () P(healthy link enters a deep fade)
    link_recover: Array     # () P(faded link recovers)
    fade_depth: Array       # () gain multiplier inside a fade (<< 1)
    ap_fail: Array          # () P(healthy AP blacks out)
    ap_recover: Array       # () P(blacked-out AP recovers)
    tel_drop: Array         # () P(this epoch's telemetry sample is lost)
    tel_spike: Array        # () P(this epoch's telemetry sample is spiked)
    tel_spike_scale: Array  # () multiplier applied to a spiked sample
    svc_spike: Array        # () per-user P(service-time spike)
    svc_spike_scale: Array  # () multiplier applied to a spiked service


class FaultState(NamedTuple):
    """Persistent outage masks, donated across epochs."""

    link_down: Array   # (U,) bool: user is in a deep fade
    ap_down: Array     # (N,) bool: AP is blacked out


class FaultDraw(NamedTuple):
    """One epoch's realized faults (device arrays, consumed in-jit)."""

    link_down: Array   # (U,) bool
    ap_down: Array     # (N,) bool
    tel_drop: Array    # () bool
    tel_spike: Array   # () bool
    svc_mult: Array    # (U,) f32 service-time multiplier (1.0 = clean)


def _onset(stationary: float, mean_epochs: float) -> float:
    """Markov onset probability giving the requested stationary outage
    fraction at the given mean outage duration."""
    pi = min(max(float(stationary), 0.0), 0.999)
    recover = 1.0 / max(float(mean_epochs), 1.0)
    return min(pi * recover / max(1.0 - pi, 1e-6), 1.0)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Host-side fault mix. All rates default to zero: a zero config is an
    exact identity on the loop (bernoulli(p=0) never fires, multipliers
    stay 1.0), so hardened and unhardened loops share one epoch program."""

    link_outage_rate: float = 0.0       # long-run fraction of users in fade
    link_mean_epochs: float = 8.0       # mean fade duration
    fade_depth: float = 1e-6            # gain multiplier inside a fade
    ap_outage_rate: float = 0.0         # long-run fraction of APs down
    ap_mean_epochs: float = 20.0
    telemetry_drop_rate: float = 0.0    # P(sample lost -> NaN) per epoch
    telemetry_spike_rate: float = 0.0   # P(sample spiked) per epoch
    telemetry_spike_scale: float = 50.0
    service_spike_rate: float = 0.0     # per-user P(transient slow service)
    service_spike_scale: float = 10.0

    def rates(self) -> FaultRates:
        """Lower to the epoch program's f32-scalar operand tuple."""
        return FaultRates(
            link_fail=jnp.float32(_onset(self.link_outage_rate,
                                         self.link_mean_epochs)),
            link_recover=jnp.float32(1.0 / max(self.link_mean_epochs, 1.0)),
            fade_depth=jnp.float32(self.fade_depth),
            ap_fail=jnp.float32(_onset(self.ap_outage_rate,
                                       self.ap_mean_epochs)),
            ap_recover=jnp.float32(1.0 / max(self.ap_mean_epochs, 1.0)),
            tel_drop=jnp.float32(self.telemetry_drop_rate),
            tel_spike=jnp.float32(self.telemetry_spike_rate),
            tel_spike_scale=jnp.float32(self.telemetry_spike_scale),
            svc_spike=jnp.float32(self.service_spike_rate),
            svc_spike_scale=jnp.float32(self.service_spike_scale),
        )


def init_fault_state(n_users: int, n_aps: int) -> FaultState:
    return FaultState(link_down=jnp.zeros((int(n_users),), bool),
                      ap_down=jnp.zeros((int(n_aps),), bool))


def fault_step(rates: FaultRates, key: Array,
               state: FaultState) -> tuple[FaultState, FaultDraw]:
    """Advance the Markov outage masks one epoch and draw the epoch's
    transient faults. Pure; composable inside the jitted epoch program."""
    u = state.link_down.shape[0]
    n = state.ap_down.shape[0]
    k_lf, k_lr, k_af, k_ar, k_td, k_ts, k_sv = jax.random.split(key, 7)
    link_down = jnp.where(
        state.link_down,
        ~jax.random.bernoulli(k_lr, rates.link_recover, (u,)),
        jax.random.bernoulli(k_lf, rates.link_fail, (u,)))
    ap_down = jnp.where(
        state.ap_down,
        ~jax.random.bernoulli(k_ar, rates.ap_recover, (n,)),
        jax.random.bernoulli(k_af, rates.ap_fail, (n,)))
    svc_mult = jnp.where(
        jax.random.bernoulli(k_sv, rates.svc_spike, (u,)),
        rates.svc_spike_scale, jnp.float32(1.0))
    new = FaultState(link_down=link_down, ap_down=ap_down)
    draw = FaultDraw(link_down=link_down, ap_down=ap_down,
                     tel_drop=jax.random.bernoulli(k_td, rates.tel_drop),
                     tel_spike=jax.random.bernoulli(k_ts, rates.tel_spike),
                     svc_mult=svc_mult)
    return new, draw


def apply_env_faults(env: NetworkEnv, draw: FaultDraw,
                     rates: FaultRates) -> NetworkEnv:
    """Mask the channel gains: faded users' gains scale by ``fade_depth``
    in both directions, blacked-out APs' gains go to exactly zero for the
    whole cell. Downstream rate floors (channel.user_rates and the loop's
    service model clamp rates at 1e-9) keep the math finite -- a blackout
    produces astronomically bad but *finite* plans; the NaN channel is
    telemetry corruption. A zero draw returns gains scaled by 1.0."""
    fade_u = jnp.where(draw.link_down, rates.fade_depth,
                       jnp.float32(1.0))                      # (U,)
    ap_up = jnp.where(draw.ap_down, jnp.float32(0.0),
                      jnp.float32(1.0))                       # (N,)
    g_up = env.g_up * fade_u[:, None, None] * ap_up[None, :, None]
    g_dn = env.g_dn * ap_up[:, None, None] * fade_u[None, :, None]
    return dataclasses.replace(env, g_up=g_up.astype(env.g_up.dtype),
                               g_dn=g_dn.astype(env.g_dn.dtype))


def corrupt_observation(obs: Observation, draw: FaultDraw,
                        rates: FaultRates) -> Observation:
    """Telemetry faults: a dropped sample becomes NaN (missing data that an
    unguarded EMA propagates forever -- the silent-corruption channel the
    motivation names), a spiked sample is scaled by ``tel_spike_scale``
    (finite corruption that drives the kappa estimate off the rails)."""
    nanf = jnp.float32(jnp.nan)

    def hit(x: Array) -> Array:
        spiked = jnp.where(draw.tel_spike, x * rates.tel_spike_scale, x)
        return jnp.where(draw.tel_drop, jnp.full_like(spiked, nanf), spiked)

    return obs._replace(t_layer=hit(obs.t_layer), t_up=hit(obs.t_up))


def spike_service(service: Array, draw: FaultDraw) -> Array:
    """Transient service-time spikes (a wedged edge worker, a GC pause):
    per-user multiplicative, memoryless."""
    return service * draw.svc_mult
