"""The graceful-degradation ladder: host-side policy over in-jit guards.

The ladder is the serving loop's decision layer when a guard bit fires.
Its rungs, in escalation order:

  1. reject-and-hold   a replan whose plan fails the health check is never
                       served; the server keeps the last good PlanState
                       (OnlineSplitServer.observe with guard_plans=True).
  2. quarantine        telemetry-health bits freeze the measured-profile
                       feedback: the loop plans against the static
                       ModelProfile until ``quarantine_epochs`` clean
                       observations pass (the in-jit gate additionally
                       holds the TelemetryState itself, so corruption
                       never enters the EMA).
  3. baseline fallback after ``baseline_after`` consecutive bad replans
                       the served plan drops to a guaranteed-feasible
                       baseline (device-only / edge-only greedy, from the
                       core.baselines family) while retries continue.
  4. cold replan       degraded-stage retries rebuild the warm state from
                       scratch (the stale warm payload is suspect) on an
                       exponential backoff, so a wedged planner is not
                       hammered every epoch.

All decisions consume only the packed health word and the plan word the
loop already syncs -- the ladder adds no device traffic. The fallback plan
is built by a jitted program with the SAME output avals as the engine's
plans (cast against a template plan), so switching to it never retraces
the epoch program.

``EpochWatchdog`` generalizes ``runtime.ft.Watchdog`` to the serving path:
detection-only (an epoch that overruns its budget counts and escalates the
ladder instead of raising -- there is no checkpoint to restore mid-epoch).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    Array,
    EccWeights,
    GdVars,
    ModelProfile,
    NetworkEnv,
    SplitPlan,
)
from repro.faults.guards import TELEMETRY_MASK
from repro.runtime import ft


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    """Degradation policy knobs. ``shed_service_factor`` > 0 additionally
    sheds arrivals whose modeled service exceeds ``factor * deadline_s`` at
    admission -- under a persistent deep fade those requests would jam
    batch slots for ``max_work_epochs`` each, starving healthy users."""

    quarantine_epochs: int = 20
    baseline_after: int = 3        # consecutive bad replans -> rung 3
    recover_after: int = 1         # consecutive good replans -> normal
    backoff_base: int = 2          # epochs before the first degraded retry
    backoff_max: int = 32
    fallback: str = "device_only"  # rung-3 plan: device_only | edge_only
    kappa_max: float = 100.0       # guards.telemetry_health ceiling
    shed_service_factor: float = 4.0
    watchdog_timeout_s: float = 0.0   # 0 disables the epoch watchdog

    def __post_init__(self) -> None:
        if self.fallback not in ("device_only", "edge_only"):
            raise ValueError(f"unknown fallback mode {self.fallback!r}")
        if self.baseline_after < 1 or self.recover_after < 1:
            raise ValueError("baseline_after/recover_after must be >= 1")


class LadderDecision(NamedTuple):
    """What the loop should do with this epoch's replan opportunity."""

    use_measured: bool   # feed the measured profile (False = quarantined)
    hold: bool           # skip the replan entirely (degraded backoff)
    force: bool          # dispatch off-schedule (degraded retry due)
    force_cold: bool     # rebuild the warm state before dispatching


class DegradeLadder:
    """Host state machine over the per-epoch health/plan words.

    Stages: ``normal`` -> ``hold`` (last good plan served, retries backed
    off) -> ``baseline`` (fallback plan served). Telemetry quarantine is
    orthogonal: it gates the measured-profile operand, not the stage.
    """

    def __init__(self, cfg: LadderConfig = LadderConfig()):
        self.cfg = cfg
        self.stage = "normal"
        self.epoch = 0
        self.quarantine_left = 0
        self.backoff = cfg.backoff_base
        self.cooldown = 0
        self.bad_streak = 0
        self.clean_streak = 0
        self._down_since: int | None = None
        # recovery counters (surfaced via metrics())
        self.quarantines = 0
        self.holds = 0
        self.baseline_fallbacks = 0
        self.cold_replans = 0
        self.recoveries = 0
        self.recovery_epochs: list[int] = []
        self.watchdog_fires = 0

    @property
    def serve_fallback(self) -> bool:
        """Serve the rung-3 baseline plan this epoch? Only while the most
        recent replan attempts are still failing -- one good replan puts
        the planner's plan back on the air even before full recovery."""
        return self.stage == "baseline" and self.bad_streak > 0

    def pre_replan(self, health: int) -> LadderDecision:
        """Fold this epoch's health word in; decide the replan posture."""
        self.epoch += 1
        if health & TELEMETRY_MASK:
            if self.quarantine_left == 0:
                self.quarantines += 1
            self.quarantine_left = self.cfg.quarantine_epochs
        elif self.quarantine_left > 0:
            self.quarantine_left -= 1
        use_measured = self.quarantine_left == 0
        if self.stage == "normal":
            return LadderDecision(use_measured, hold=False, force=False,
                                  force_cold=False)
        self.cooldown -= 1
        if self.cooldown <= 0:
            self.cold_replans += 1
            return LadderDecision(use_measured, hold=False, force=True,
                                  force_cold=True)
        return LadderDecision(use_measured, hold=True, force=False,
                              force_cold=False)

    def post_replan(self, plan_ok: bool | None, replanned: bool) -> None:
        """Fold the replan outcome in: escalate on a rejected plan, recover
        on clean ones. Held epochs (no dispatch) carry no evidence."""
        if not replanned or plan_ok is None:
            return
        if plan_ok:
            self.clean_streak += 1
            self.bad_streak = 0
            if (self.stage != "normal"
                    and self.clean_streak >= self.cfg.recover_after):
                self.stage = "normal"
                self.recoveries += 1
                if self._down_since is not None:
                    self.recovery_epochs.append(self.epoch - self._down_since)
                    self._down_since = None
                self.backoff = self.cfg.backoff_base
                self.cooldown = 0
            return
        self.clean_streak = 0
        self.bad_streak += 1
        if self._down_since is None:
            self._down_since = self.epoch
        if self.stage == "normal":
            self.stage = "hold"
            self.holds += 1
        elif (self.stage == "hold"
              and self.bad_streak >= self.cfg.baseline_after):
            self.stage = "baseline"
            self.baseline_fallbacks += 1
        self.cooldown = self.backoff
        self.backoff = min(self.backoff * 2, self.cfg.backoff_max)

    def on_timeout(self) -> None:
        """An epoch overran the watchdog budget: count it and back the
        planner off as if a replan had failed (no plan evidence, but a
        wedged epoch is not the moment to dispatch more work)."""
        self.watchdog_fires += 1
        if self.stage == "normal":
            self.stage = "hold"
            self.holds += 1
            if self._down_since is None:
                self._down_since = self.epoch
        self.cooldown = self.backoff
        self.backoff = min(self.backoff * 2, self.cfg.backoff_max)

    def export_state(self) -> dict:
        """The ladder's full host state as JSON-serializable scalars, for
        the serving snapshot (repro.state). ``_down_since`` rides along so
        an outage that spans a crash keeps its original start epoch --
        recovery latency is measured once, from the true onset, and never
        double-counted across a restore."""
        return {
            "stage": self.stage,
            "epoch": self.epoch,
            "quarantine_left": self.quarantine_left,
            "backoff": self.backoff,
            "cooldown": self.cooldown,
            "bad_streak": self.bad_streak,
            "clean_streak": self.clean_streak,
            "down_since": self._down_since,
            "quarantines": self.quarantines,
            "holds": self.holds,
            "baseline_fallbacks": self.baseline_fallbacks,
            "cold_replans": self.cold_replans,
            "recoveries": self.recoveries,
            "recovery_epochs": list(self.recovery_epochs),
            "watchdog_fires": self.watchdog_fires,
        }

    def import_state(self, state: dict) -> None:
        """Inverse of export_state: overwrite the ladder with a snapshot."""
        self.stage = str(state["stage"])
        self.epoch = int(state["epoch"])
        self.quarantine_left = int(state["quarantine_left"])
        self.backoff = int(state["backoff"])
        self.cooldown = int(state["cooldown"])
        self.bad_streak = int(state["bad_streak"])
        self.clean_streak = int(state["clean_streak"])
        ds = state["down_since"]
        self._down_since = None if ds is None else int(ds)
        self.quarantines = int(state["quarantines"])
        self.holds = int(state["holds"])
        self.baseline_fallbacks = int(state["baseline_fallbacks"])
        self.cold_replans = int(state["cold_replans"])
        self.recoveries = int(state["recoveries"])
        self.recovery_epochs = [int(x) for x in state["recovery_epochs"]]
        self.watchdog_fires = int(state["watchdog_fires"])

    def metrics(self) -> dict:
        mean_rec = (sum(self.recovery_epochs) / len(self.recovery_epochs)
                    if self.recovery_epochs else 0.0)
        return {
            "ladder_stage": self.stage,
            "quarantines": self.quarantines,
            "quarantine_left": self.quarantine_left,
            "holds": self.holds,
            "baseline_fallbacks": self.baseline_fallbacks,
            "ladder_cold_replans": self.cold_replans,
            "recoveries": self.recoveries,
            "mean_recovery_epochs": mean_rec,
            "watchdog_fires": self.watchdog_fires,
        }


class EpochWatchdog:
    """Detection-only watchdog for the serving loop, generalizing
    ``ft.Watchdog`` from the training path: the epoch's host-side critical
    section runs under a timer, and an overrun *reports* instead of
    raising -- the epoch's result is kept (state stays consistent) and the
    ladder escalates via ``on_timeout``. A zero timeout disables it."""

    def __init__(self, timeout_s: float):
        self.timeout_s = float(timeout_s)
        self.fires = 0

    def guard(self, fn: Callable):
        """Run ``fn`` under the timer; returns (result, fired)."""
        if self.timeout_s <= 0:
            return fn(), False
        with ft.Watchdog(self.timeout_s) as wd:
            out = fn()
        fired = wd.fired
        self.fires += int(fired)
        return out, fired


def fallback_plan(env: NetworkEnv, prof: ModelProfile, w: EccWeights,
                  template: SplitPlan | None = None,
                  mode: str = "device_only") -> SplitPlan:
    """A guaranteed-feasible SplitPlan from the core.baselines family.

    ``device_only`` keeps every layer local (s = F, minimum radio/edge
    footprint): finite under ANY channel state, including a full AP
    blackout -- the terminal rung. ``edge_only`` is the greedy full-offload
    twin (max power, best own-gain subchannel, full edge allocation) for
    deployments whose devices cannot run the model.

    Pure and jit-compatible. When ``template`` (any engine-produced plan)
    is given, every leaf is cast to the template's dtype and weak types are
    stripped, so the fallback has byte-identical avals to planner output
    and serving it never retraces the epoch program.
    """
    from repro.core.utility import delay_energy  # deferred: keep the
    # faults package importable without the solver stack

    if mode not in ("device_only", "edge_only"):
        raise ValueError(f"unknown fallback mode {mode!r}")
    u, f = env.n_users, prof.n_layers
    rc, cc = env.radio, env.comp
    best_up = jnp.argmax(env.own_gain_up(), axis=-1).astype(jnp.int32)
    best_dn = jnp.argmax(env.own_gain_dn(), axis=-1).astype(jnp.int32)
    if mode == "device_only":
        s = jnp.int32(f)
        p_up = jnp.full((u,), rc.p_up_min_w, jnp.float32)
        p_dn = jnp.full((u,), rc.p_dn_min_w, jnp.float32)
        r = jnp.full((u,), cc.r_min, jnp.float32)
    else:
        s = jnp.int32(0)
        p_up = jnp.full((u,), rc.p_up_max_w, jnp.float32)
        p_dn = jnp.full((u,), rc.p_dn_max_w, jnp.float32)
        r = jnp.full((u,), cc.r_max, jnp.float32)
    v = GdVars(beta_up=jax.nn.one_hot(best_up, env.n_sub),
               beta_dn=jax.nn.one_hot(best_dn, env.n_sub),
               p_up=p_up, p_dn=p_dn, r=r)
    t_cost, e_cost = delay_energy(env, prof, s, v)
    util = jnp.sum(w.w_T * t_cost + w.w_E * e_cost).astype(jnp.float32)
    plan = SplitPlan(
        s=s, sub_up=best_up, sub_dn=best_dn, p_up=p_up, p_dn=p_dn, r=r,
        utility=util,
        per_layer_utility=jnp.full((f + 1,), util, jnp.float32),
        iters=jnp.zeros((f + 1,), jnp.int32),
        rounding_violations=jnp.int32(0))
    if template is not None:
        plan = jax.tree.map(lambda x, t: x.astype(t.dtype), plan, template)
    return jax.tree.map(
        lambda x: jax.lax.convert_element_type(x, x.dtype)
        if getattr(x, "weak_type", False) else x, plan)
