"""Pallas TPU kernels for the NOMA pairwise-interference reduction.

This is the paper's computational hot spot: every (Li-)GD iteration evaluates
U x M SINR terms whose denominators are masked pairwise reductions over all
other users (SIC intra-cell ordering + inter-cell leakage), eqs. (5)/(8).
Naively this is a (U, V, M) tensor -- at paper scale (U=1250, M=250) that is
390M elements per evaluation, too large to materialize in fp32 on-chip.

TPU adaptation (DESIGN.md Sec. 4): tile (U, M) output blocks into VMEM and
stream interferer blocks V as the innermost sequential grid dimension,
accumulating in fp32 VMEM scratch. The (BU, BV, BM) mask products are VPU
elementwise work on (8,128)-aligned tiles.

Gather-free layout: the kernels consume the RAW channel state -- uplink
g_up (V, N, M), downlink g_dn (N, U, M), N = number of APs -- plus the
per-user AP one-hot (U, N). The AP-indexed selection g_vu[v,u,m] =
g[v, ap[u], m] that earlier revisions pre-gathered into a (V, U, M) HBM
tensor (1.56 GB fp32 at paper scale, plus a block-padded copy) is folded
into the kernels as a one-hot contraction over N: because same_cell[u,v] =
<onehot[u], onehot[v]> couples the pair only through the shared AP, the
inter-cell reduction factors through a per-AP (N, M) accumulator,

  uplink:   inter[u,m] = sum_n oh[u,n] * A[n,m],
            A[n,m]     = sum_v (1 - oh[v,n]) * w_power[v,m] * g_up[v,n,m]
  downlink: inter[u,m] = sum_n (1 - oh[u,n]) * g_dn[n,u,m] * B[n,m],
            B[n,m]     = sum_v oh[v,n] * w_power[v,m]

and the same_cell mask input is gone too (derived in-kernel as
oh_u @ oh_v^T, cheap MXU work since N is small). The SIC intra term keeps
its pairwise form (a genuine per-pair comparison):

  intra[u,m] = sum_v same[u,v] * cmp(own_v[v,m], own_u[u,m]) * w_intra[v,m]

Single-pass gain traffic: a reduction whose per-AP accumulator is
independent of the pairwise grid's parallel axis would re-stream the whole
gain tensor once per output block if computed inside the pairwise kernel.
Those two cases -- the uplink-forward A and the downlink-backward D =
sum_u (1-oh[u,n]) g_dn[n,u,m] dx[u,m] -- run as a separate per-AP
reduction kernel (noma_per_ap_kernel, grid (M, W) with W streamed) that
reads the gain exactly once; the pairwise kernel then consumes the tiny
(N, M) result. The remaining two cases (downlink-forward, uplink-backward)
index the gain by the pairwise grid's own parallel axis, so each block is
fetched exactly once there (Pallas skips refetches while the block index
is constant along the sequential axis) and they stay fused.

Inputs arrive UNPADDED: the grid over-covers with pl.cdiv and boundary
blocks are masked in-kernel (iota vs the true U/V extents). Out-of-bounds
lanes of a boundary block read unspecified values (NaN in interpret mode),
so masks are applied with jnp.where -- never by multiplication -- and
every reduction keeps OOB garbage confined to rows/lanes the final
(masked) output store drops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

_DOT32 = functools.partial(jnp.dot, preferred_element_type=jnp.float32)


def _valid_rows(block_id: int, block: int, rows: int, n_valid: int):
    """(rows, 1) bool: which rows of this block index real (unpadded) data."""
    idx = block_id * block + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    return idx < n_valid


def _intra_contrib(own_u, own_v, same, weight, valid, descending, vu_major):
    """Masked SIC accumulation shared by all four pairwise kernel bodies.

    vu_major=False: (BU, BV, BM) layout, returns sum over v -> (BU, BM)
      sum_v same[u,v] * cmp(own_v, own_u) * weight[v,m]   (weight: (BV, BM))
    vu_major=True: (BV, BU, BM) layout, returns sum over u -> (BV, BM)
      sum_u same[v,u] * cmp(own_v, own_u) * weight[u,m]   (weight: (BU, BM))
    valid masks the streamed axis (the one being summed is the local-major
    one in the forward pass and the streamed one in the backward pass --
    callers pass the mask of the axis whose OOB rows must not contribute)."""
    if vu_major:
        cmp = own_v[:, None, :] < own_u[None, :, :] if descending else \
              own_v[:, None, :] > own_u[None, :, :]
    else:
        cmp = own_v[None, :, :] < own_u[:, None, :] if descending else \
              own_v[None, :, :] > own_u[:, None, :]
    keep = cmp & (same[:, :, None] > 0.5) & valid[None, :, :]
    return jnp.sum(jnp.where(keep, weight[None, :, :], 0.0), axis=1)


def _per_ap_kernel(oh_ref, wgt_ref, g_ref, out_ref, acc_ref, *,
                   uplink: bool, n_w: int, block_w: int):
    """out[n,m] = sum_w (1 - oh[w,n]) * wgt[w,m] * g[w-major or n-major].

    The gather-free other-cell reduction: streams the raw gain exactly once
    (grid (M, W), W innermost sequential), accumulating the (N, BM) per-AP
    slab in VMEM scratch."""
    wi = pl.program_id(1)
    nw = pl.num_programs(1)

    @pl.when(wi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    oh = oh_ref[...]                 # (BW, N)
    wgt = wgt_ref[...]               # (BW, BM)
    valid_w = _valid_rows(wi, block_w, oh.shape[0], n_w)
    if uplink:
        g = g_ref[...]               # (BW, N, BM)
        term = jnp.where(valid_w[:, :, None],
                         (1.0 - oh)[:, :, None] * wgt[:, None, :] * g, 0.0)
        acc_ref[...] += jnp.sum(term, axis=0)
    else:
        g = g_ref[...]               # (N, BW, BM)
        term = jnp.where(valid_w[None, :, :],
                         (1.0 - oh.T)[:, :, None] * g * wgt[None, :, :], 0.0)
        acc_ref[...] += jnp.sum(term, axis=1)

    @pl.when(wi == nw - 1)
    def _finish():
        out_ref[...] = acc_ref[...]


def _fwd_up_kernel(own_u_ref, own_v_ref, w_intra_ref, a_ref, oh_u_ref,
                   oh_v_ref, intra_ref, inter_ref, acc_i_ref, *,
                   descending: bool, n_v: int, block_v: int):
    """Uplink forward: pairwise SIC intra + inter = oh_u @ A, with the
    per-AP accumulator A precomputed by _per_ap_kernel (so the raw gain
    never enters this kernel)."""
    vi = pl.program_id(2)
    nv = pl.num_programs(2)

    @pl.when(vi == 0)
    def _init():
        acc_i_ref[...] = jnp.zeros_like(acc_i_ref)

    own_u = own_u_ref[...]           # (BU, BM)
    own_v = own_v_ref[...]           # (BV, BM)
    oh_u = oh_u_ref[...]             # (BU, N)
    oh_v = oh_v_ref[...]             # (BV, N)
    valid_v = _valid_rows(vi, block_v, own_v.shape[0], n_v)
    same = _DOT32(oh_u, oh_v.T)      # (BU, BV)
    acc_i_ref[...] += _intra_contrib(own_u, own_v, same, w_intra_ref[...],
                                     valid_v, descending, vu_major=False)

    @pl.when(vi == nv - 1)
    def _finish():
        intra_ref[...] = acc_i_ref[...]
        inter_ref[...] = _DOT32(oh_u, a_ref[...])


def _fwd_dn_kernel(own_u_ref, own_v_ref, w_intra_ref, w_power_ref, g_ref,
                   oh_u_ref, oh_v_ref, intra_ref, inter_ref, acc_i_ref,
                   acc_nm_ref, *, descending: bool, n_v: int, block_v: int):
    """Downlink forward: pairwise SIC intra + the per-AP tx accumulator
    B[n,m] = sum_v oh_v[v,n] w_power[v,m] (no gain involved), contracted at
    finish against the receiver-major raw gain block -- which is indexed by
    this kernel's own parallel (ui, mi) axes, so it is fetched once."""
    vi = pl.program_id(2)
    nv = pl.num_programs(2)

    @pl.when(vi == 0)
    def _init():
        acc_i_ref[...] = jnp.zeros_like(acc_i_ref)
        acc_nm_ref[...] = jnp.zeros_like(acc_nm_ref)

    own_u = own_u_ref[...]           # (BU, BM)
    own_v = own_v_ref[...]           # (BV, BM)
    w_p = w_power_ref[...]           # (BV, BM)
    oh_u = oh_u_ref[...]             # (BU, N)
    oh_v = oh_v_ref[...]             # (BV, N)
    valid_v = _valid_rows(vi, block_v, own_v.shape[0], n_v)
    same = _DOT32(oh_u, oh_v.T)
    acc_i_ref[...] += _intra_contrib(own_u, own_v, same, w_intra_ref[...],
                                     valid_v, descending, vu_major=False)
    term = jnp.where(valid_v[:, :, None],
                     oh_v[:, :, None] * w_p[:, None, :], 0.0)
    acc_nm_ref[...] += jnp.sum(term, axis=0)                # (N, BM)

    @pl.when(vi == nv - 1)
    def _finish():
        intra_ref[...] = acc_i_ref[...]
        g_ru = g_ref[...]                                   # (N, BU, BM)
        inter_ref[...] = jnp.sum(
            (1.0 - oh_u.T)[:, :, None] * g_ru * acc_nm_ref[...][:, None, :],
            axis=0)


def _bwd_up_kernel(own_u_ref, own_v_ref, g_ref, oh_u_ref, oh_v_ref, di_ref,
                   dx_ref, d_wi_ref, d_wp_ref, acc_i_ref, acc_nm_ref, *,
                   descending: bool, n_u: int, block_u: int):
    """Uplink backward: d_wi pairwise + C[n,m] = sum_u oh_u dx (no gain),
    contracted at finish against the interferer-major raw gain block --
    indexed by this kernel's own parallel (vi, mi) axes, fetched once:

      d_wi[v,m] = sum_u same[u,v] * cmp(own_v, own_u) * di[u,m]
      d_wp[v,m] = sum_n (1 - oh[v,n]) * g_up[v,n,m] * C[n,m]"""
    ui = pl.program_id(2)
    nu = pl.num_programs(2)

    @pl.when(ui == 0)
    def _init():
        acc_i_ref[...] = jnp.zeros_like(acc_i_ref)
        acc_nm_ref[...] = jnp.zeros_like(acc_nm_ref)

    own_u = own_u_ref[...]           # (BU, BM)
    own_v = own_v_ref[...]           # (BV, BM)
    oh_u = oh_u_ref[...]             # (BU, N)
    oh_v = oh_v_ref[...]             # (BV, N)
    dx = dx_ref[...]                 # (BU, BM)
    valid_u = _valid_rows(ui, block_u, own_u.shape[0], n_u)
    same_vu = _DOT32(oh_v, oh_u.T)   # (BV, BU)
    acc_i_ref[...] += _intra_contrib(own_u, own_v, same_vu, di_ref[...],
                                     valid_u, descending, vu_major=True)
    term = jnp.where(valid_u[:, :, None],
                     oh_u[:, :, None] * dx[:, None, :], 0.0)
    acc_nm_ref[...] += jnp.sum(term, axis=0)                # (N, BM)

    @pl.when(ui == nu - 1)
    def _finish():
        d_wi_ref[...] = acc_i_ref[...]
        g_v = g_ref[...]                                    # (BV, N, BM)
        d_wp_ref[...] = jnp.sum(
            (1.0 - oh_v)[:, :, None] * g_v * acc_nm_ref[...][None, :, :],
            axis=1)


def _bwd_dn_kernel(own_u_ref, own_v_ref, d_acc_ref, oh_u_ref, oh_v_ref,
                   di_ref, d_wi_ref, d_wp_ref, acc_i_ref, *,
                   descending: bool, n_u: int, block_u: int):
    """Downlink backward: d_wi pairwise + d_wp = oh_v @ D, with the per-AP
    cotangent accumulator D[n,m] = sum_u (1-oh[u,n]) g_dn[n,u,m] dx[u,m]
    precomputed by _per_ap_kernel (the raw gain never enters this kernel)."""
    ui = pl.program_id(2)
    nu = pl.num_programs(2)

    @pl.when(ui == 0)
    def _init():
        acc_i_ref[...] = jnp.zeros_like(acc_i_ref)

    own_u = own_u_ref[...]           # (BU, BM)
    own_v = own_v_ref[...]           # (BV, BM)
    oh_u = oh_u_ref[...]             # (BU, N)
    oh_v = oh_v_ref[...]             # (BV, N)
    valid_u = _valid_rows(ui, block_u, own_u.shape[0], n_u)
    same_vu = _DOT32(oh_v, oh_u.T)
    acc_i_ref[...] += _intra_contrib(own_u, own_v, same_vu, di_ref[...],
                                     valid_u, descending, vu_major=True)

    @pl.when(ui == nu - 1)
    def _finish():
        d_wi_ref[...] = acc_i_ref[...]
        d_wp_ref[...] = _DOT32(oh_v_ref[...], d_acc_ref[...])


def noma_per_ap_kernel(
    oh: jax.Array,       # (W, N) fp32 AP one-hot of the streamed users
    wgt: jax.Array,      # (W, M) per-user weight (w_power fwd, dx bwd)
    g_raw: jax.Array,    # uplink: (W, N, M) raw g_up; downlink: (N, W, M) raw g_dn
    uplink: bool = True,
    block_w: int = 8,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Other-cell per-AP reduction, (N, M):

      out[n,m] = sum_w (1 - oh[w,n]) * wgt[w,m] * g[w,n,m]   (uplink layout)
      out[n,m] = sum_w (1 - oh[w,n]) * wgt[w,m] * g[n,w,m]   (downlink layout)

    Streams the raw gain exactly once -- this is the kernel that replaces
    the (V, U, M) AP-indexed gather of earlier revisions for the two
    reductions whose accumulator is independent of the pairwise grid's
    parallel axis (uplink-forward A, downlink-backward D)."""
    w, n_aps = oh.shape
    m = wgt.shape[1]
    bw, bm = min(block_w, w), min(block_m, m)
    nwb, nm = pl.cdiv(w, bw), pl.cdiv(m, bm)

    kernel = functools.partial(_per_ap_kernel, uplink=uplink, n_w=w,
                               block_w=bw)
    if uplink:
        g_spec = pl.BlockSpec((bw, n_aps, bm), lambda mi, wi: (wi, 0, mi))
    else:
        g_spec = pl.BlockSpec((n_aps, bw, bm), lambda mi, wi: (0, wi, mi))
    out = pl.pallas_call(
        kernel,
        grid=(nm, nwb),
        in_specs=[
            pl.BlockSpec((bw, n_aps), lambda mi, wi: (wi, 0)),      # oh
            pl.BlockSpec((bw, bm), lambda mi, wi: (wi, mi)),        # wgt
            g_spec,                                                 # g_raw
        ],
        out_specs=pl.BlockSpec((n_aps, bm), lambda mi, wi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((n_aps, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_aps, bm), jnp.float32)],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(oh, wgt, g_raw)
    return out


def noma_pairwise_kernel(
    own_u: jax.Array,    # (U, M) fp32
    own_v: jax.Array,    # (V, M)  V may differ from U (it never does in ops)
    w_intra: jax.Array,  # (V, M)
    w_power: jax.Array,  # (V, M)
    g_raw: jax.Array,    # uplink: (V, N, M) raw g_up; downlink: (N, U, M) raw g_dn
    oh_u: jax.Array,     # (U, N) fp32 AP one-hot of the receivers
    oh_v: jax.Array,     # (V, N) fp32 AP one-hot of the interferers
    descending: bool = True,
    uplink: bool = True,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Gather-free pairwise reduction: returns (intra (U, M), inter (U, M)).

    Inputs are consumed unpadded -- boundary blocks are masked in-kernel,
    so no _pad_to copies (and no pad ops in the jaxpr) on any operand.
    Uplink composes the per-AP reduction kernel (gain read once) with the
    pairwise kernel; downlink fuses both (the gain block is indexed by the
    pairwise grid's parallel axes there, so it is fetched once anyway)."""
    u, m = own_u.shape
    v = own_v.shape[0]
    n_aps = oh_u.shape[1]
    bu, bv, bm = min(block_u, u), min(block_v, v), min(block_m, m)
    nu, nvb, nm = pl.cdiv(u, bu), pl.cdiv(v, bv), pl.cdiv(m, bm)
    grid = (nu, nm, nvb)
    params = tpu_compiler_params(("parallel", "parallel", "arbitrary"))
    out_specs = [
        pl.BlockSpec((bu, bm), lambda ui, mi, vi: (ui, mi)),
        pl.BlockSpec((bu, bm), lambda ui, mi, vi: (ui, mi)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((u, m), jnp.float32),
        jax.ShapeDtypeStruct((u, m), jnp.float32),
    ]

    if uplink:
        a_nm = noma_per_ap_kernel(oh_v, w_power, g_raw, uplink=True,
                                  block_w=bv, block_m=bm, interpret=interpret)
        kernel = functools.partial(_fwd_up_kernel, descending=descending,
                                   n_v=v, block_v=bv)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bu, bm), lambda ui, mi, vi: (ui, mi)),   # own_u
                pl.BlockSpec((bv, bm), lambda ui, mi, vi: (vi, mi)),   # own_v
                pl.BlockSpec((bv, bm), lambda ui, mi, vi: (vi, mi)),   # w_intra
                pl.BlockSpec((n_aps, bm), lambda ui, mi, vi: (0, mi)),  # A
                pl.BlockSpec((bu, n_aps), lambda ui, mi, vi: (ui, 0)),  # oh_u
                pl.BlockSpec((bv, n_aps), lambda ui, mi, vi: (vi, 0)),  # oh_v
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bu, bm), jnp.float32)],
            compiler_params=params,
            interpret=interpret,
        )(own_u, own_v, w_intra, a_nm, oh_u, oh_v)
    else:
        kernel = functools.partial(_fwd_dn_kernel, descending=descending,
                                   n_v=v, block_v=bv)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bu, bm), lambda ui, mi, vi: (ui, mi)),   # own_u
                pl.BlockSpec((bv, bm), lambda ui, mi, vi: (vi, mi)),   # own_v
                pl.BlockSpec((bv, bm), lambda ui, mi, vi: (vi, mi)),   # w_intra
                pl.BlockSpec((bv, bm), lambda ui, mi, vi: (vi, mi)),   # w_power
                pl.BlockSpec((n_aps, bu, bm),
                             lambda ui, mi, vi: (0, ui, mi)),          # g_raw
                pl.BlockSpec((bu, n_aps), lambda ui, mi, vi: (ui, 0)),  # oh_u
                pl.BlockSpec((bv, n_aps), lambda ui, mi, vi: (vi, 0)),  # oh_v
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[
                pltpu.VMEM((bu, bm), jnp.float32),
                pltpu.VMEM((n_aps, bm), jnp.float32),
            ],
            compiler_params=params,
            interpret=interpret,
        )(own_u, own_v, w_intra, w_power, g_raw, oh_u, oh_v)
    return out[0], out[1]


def noma_pairwise_bwd_kernel(
    own_u: jax.Array,    # (U, M) fp32
    own_v: jax.Array,    # (V, M)
    g_raw: jax.Array,    # uplink: (V, N, M); downlink: (N, U, M)
    oh_u: jax.Array,     # (U, N)
    oh_v: jax.Array,     # (V, N)
    d_intra: jax.Array,  # (U, M) cotangent of the forward intra output
    d_inter: jax.Array,  # (U, M) cotangent of the forward inter output
    descending: bool = True,
    uplink: bool = True,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """VJP of noma_pairwise_kernel w.r.t. (w_intra, w_power): (V, M) each.

    Same gather-free layout and single-pass gain traffic as the forward
    pass, with the grid transposed: (V, M) cotangent tiles accumulate while
    receiver blocks stream sequentially, so the backward direction never
    materializes (U, V, M) either (downlink composes the per-AP kernel on
    d_inter; uplink fuses, its gain block being indexed by the pairwise
    grid's parallel axes). Cotangents w.r.t. own_u/own_v are zero a.e.
    (the SIC ordering enters through a step function, exactly as in the
    einsum reference where the comparison is detached by .astype) and are
    the caller's to emit; d_g is never needed because the channel gains are
    environment constants in the GD path."""
    u, m = own_u.shape
    v = own_v.shape[0]
    n_aps = oh_u.shape[1]
    bu, bv, bm = min(block_u, u), min(block_v, v), min(block_m, m)
    nu, nvb, nm = pl.cdiv(u, bu), pl.cdiv(v, bv), pl.cdiv(m, bm)
    grid = (nvb, nm, nu)
    params = tpu_compiler_params(("parallel", "parallel", "arbitrary"))
    out_specs = [
        pl.BlockSpec((bv, bm), lambda vi, mi, ui: (vi, mi)),
        pl.BlockSpec((bv, bm), lambda vi, mi, ui: (vi, mi)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((v, m), jnp.float32),
        jax.ShapeDtypeStruct((v, m), jnp.float32),
    ]

    if uplink:
        kernel = functools.partial(_bwd_up_kernel, descending=descending,
                                   n_u=u, block_u=bu)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bu, bm), lambda vi, mi, ui: (ui, mi)),   # own_u
                pl.BlockSpec((bv, bm), lambda vi, mi, ui: (vi, mi)),   # own_v
                pl.BlockSpec((bv, n_aps, bm),
                             lambda vi, mi, ui: (vi, 0, mi)),          # g_raw
                pl.BlockSpec((bu, n_aps), lambda vi, mi, ui: (ui, 0)),  # oh_u
                pl.BlockSpec((bv, n_aps), lambda vi, mi, ui: (vi, 0)),  # oh_v
                pl.BlockSpec((bu, bm), lambda vi, mi, ui: (ui, mi)),   # d_intra
                pl.BlockSpec((bu, bm), lambda vi, mi, ui: (ui, mi)),   # d_inter
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[
                pltpu.VMEM((bv, bm), jnp.float32),
                pltpu.VMEM((n_aps, bm), jnp.float32),
            ],
            compiler_params=params,
            interpret=interpret,
        )(own_u, own_v, g_raw, oh_u, oh_v, d_intra, d_inter)
    else:
        d_nm = noma_per_ap_kernel(oh_u, d_inter, g_raw, uplink=False,
                                  block_w=bu, block_m=bm, interpret=interpret)
        kernel = functools.partial(_bwd_dn_kernel, descending=descending,
                                   n_u=u, block_u=bu)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bu, bm), lambda vi, mi, ui: (ui, mi)),   # own_u
                pl.BlockSpec((bv, bm), lambda vi, mi, ui: (vi, mi)),   # own_v
                pl.BlockSpec((n_aps, bm), lambda vi, mi, ui: (0, mi)),  # D
                pl.BlockSpec((bu, n_aps), lambda vi, mi, ui: (ui, 0)),  # oh_u
                pl.BlockSpec((bv, n_aps), lambda vi, mi, ui: (vi, 0)),  # oh_v
                pl.BlockSpec((bu, bm), lambda vi, mi, ui: (ui, mi)),   # d_intra
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bv, bm), jnp.float32)],
            compiler_params=params,
            interpret=interpret,
        )(own_u, own_v, d_nm, oh_u, oh_v, d_intra)
    return out[0], out[1]


def vmem_block_bytes(block_u: int = 8, block_v: int = 8, block_m: int = 128,
                     n_aps: int = 4, direction: str = "fwd",
                     uplink: bool = True) -> int:
    """Analytic fp32 VMEM working set of one kernel block (inputs + scratch
    + outputs), reported as the MAX over the Pallas kernels a direction
    launches (the uplink forward and downlink backward compose the per-AP
    reduction kernel with the pairwise kernel; the other two directions
    are a single fused kernel). The raw-gain block -- (BW, N, BM) or
    (N, BW, BM) -- makes the budget LINEAR in the AP count N: ~4 KiB per
    AP at the deployed (8, 8, 128) tiles, bounding N at a few thousand
    before a block alone approaches the ~16 MB VMEM ceiling (the paper's
    multi-cell regimes use N <= ~100). The fused directions (downlink fwd,
    uplink bwd) carry the gain inside the pairwise kernel; the composed
    directions split it into two smaller kernels, so their max is below
    the fused budget up to moderate N (at very large N the per-AP kernel's
    2x (N, BM) out+scratch edges marginally past the fused figure)."""
    bu, bv, bm, n = block_u, block_v, block_m, n_aps

    def per_ap(bw):
        # oh (BW, N) + wgt (BW, BM) + gain (BW*N*BM either layout) +
        # out + scratch (N, BM)
        return bw * n + bw * bm + bw * n * bm + 2 * n * bm

    if direction == "fwd":
        if uplink:
            # pairwise: own_u, acc_i, 2x out (BU, BM); own_v, w_intra
            # (BV, BM); A (N, BM); one-hots
            pairwise = (4 * bu * bm + 2 * bv * bm + n * bm
                        + n * (bu + bv))
            words = max(per_ap(bv), pairwise)
        else:
            # fused: own_u, acc_i, 2x out; own_v, w_intra, w_power; gain
            # (N, BU, BM); acc_nm; one-hots
            words = (4 * bu * bm + 3 * bv * bm + n * bu * bm + n * bm
                     + n * (bu + bv))
    elif direction == "bwd":
        if uplink:
            # fused: own_u, d_intra, d_inter; own_v, acc_i, 2x out; gain
            # (BV, N, BM); acc_nm; one-hots
            words = (3 * bu * bm + 4 * bv * bm + bv * n * bm + n * bm
                     + n * (bu + bv))
        else:
            # pairwise: own_u, d_intra (BU, BM); own_v, acc_i, 2x out
            # (BV, BM); D (N, BM); one-hots
            pairwise = (2 * bu * bm + 4 * bv * bm + n * bm
                        + n * (bu + bv))
            words = max(per_ap(bu), pairwise)
    else:
        raise ValueError(f"direction must be 'fwd' or 'bwd', got {direction!r}")
    return 4 * words
