"""Pallas TPU kernel for the NOMA pairwise-interference reduction.

This is the paper's computational hot spot: every (Li-)GD iteration evaluates
U x M SINR terms whose denominators are masked pairwise reductions over all
other users (SIC intra-cell ordering + inter-cell leakage), eqs. (5)/(8).
Naively this is a (U, V, M) tensor -- at paper scale (U=1250, M=250) that is
390M elements per evaluation, too large to materialize in fp32 on-chip.

TPU adaptation (DESIGN.md Sec. 4): tile (U, M) output blocks into VMEM and
stream interferer blocks V as the innermost sequential grid dimension,
accumulating both reductions in fp32 VMEM scratch. The (BU, BV, BM) mask
products are VPU elementwise work on (8,128)-aligned tiles; no MXU is used.

  intra[u,m] = sum_v same_cell[u,v] * cmp(own_v[v,m], own_u[u,m]) * w_intra[v,m]
  inter[u,m] = sum_v !same_cell[u,v] * w_power[v,m] * g_vu[v,u,m]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _kernel(own_u_ref, own_v_ref, w_intra_ref, w_power_ref, g_vu_ref,
            same_ref, intra_ref, inter_ref, acc_i_ref, acc_x_ref, *,
            descending: bool, n_users: int, block_v: int):
    vi = pl.program_id(2)
    nv = pl.num_programs(2)

    @pl.when(vi == 0)
    def _init():
        acc_i_ref[...] = jnp.zeros_like(acc_i_ref)
        acc_x_ref[...] = jnp.zeros_like(acc_x_ref)

    own_u = own_u_ref[...]           # (BU, BM)
    own_v = own_v_ref[...]           # (BV, BM)
    w_i = w_intra_ref[...]           # (BV, BM)
    w_p = w_power_ref[...]           # (BV, BM)
    g = g_vu_ref[...]                # (BV, BU, BM)
    same = same_ref[...]             # (BU, BV)

    # mask out padded interferer rows
    v_idx = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, (own_v.shape[0], 1), 0)
    valid = (v_idx < n_users).astype(own_u.dtype)    # (BV, 1)

    if descending:
        cmp = own_v[None, :, :] < own_u[:, None, :]   # (BU, BV, BM)
    else:
        cmp = own_v[None, :, :] > own_u[:, None, :]
    sc = same[:, :, None]
    contrib = jnp.where(cmp & (sc > 0.5), (w_i * valid)[None, :, :], 0.0)
    acc_i_ref[...] += jnp.sum(contrib, axis=1)

    xterm = (1.0 - same)[:, :, None] * jnp.swapaxes(g, 0, 1) * (w_p * valid)[None, :, :]
    acc_x_ref[...] += jnp.sum(xterm, axis=1)

    @pl.when(vi == nv - 1)
    def _finish():
        intra_ref[...] = acc_i_ref[...]
        inter_ref[...] = acc_x_ref[...]


def _bwd_kernel(own_u_ref, own_v_ref, g_vu_ref, same_vu_ref, di_ref, dx_ref,
                d_wi_ref, d_wp_ref, acc_i_ref, acc_x_ref, *,
                descending: bool):
    """Backward pass: accumulate cotangents w.r.t. the interferer weights.

    Transposed tiling of the forward kernel: (V, M) output blocks live in
    VMEM and *receiver* blocks U stream as the innermost sequential grid
    dimension. The masks are recomputed per block (they are cheap VPU work
    and saving them would cost a (U, V, M) residual -- the tensor this
    kernel exists to avoid):

      d_wi[v,m] = sum_u same[u,v] * cmp(own_v[v,m], own_u[u,m]) * di[u,m]
      d_wp[v,m] = sum_u !same[u,v] * g_vu[v,u,m] * dx[u,m]

    Padded receiver rows need no explicit mask: their incoming cotangents
    di/dx are zero (the caller zero-pads them), so they cannot contribute.
    Padded interferer rows produce garbage that the caller slices off."""
    ui = pl.program_id(2)
    nu = pl.num_programs(2)

    @pl.when(ui == 0)
    def _init():
        acc_i_ref[...] = jnp.zeros_like(acc_i_ref)
        acc_x_ref[...] = jnp.zeros_like(acc_x_ref)

    own_u = own_u_ref[...]           # (BU, BM)
    own_v = own_v_ref[...]           # (BV, BM)
    g = g_vu_ref[...]                # (BV, BU, BM)
    same = same_vu_ref[...]          # (BV, BU)
    di = di_ref[...]                 # (BU, BM)
    dx = dx_ref[...]                 # (BU, BM)

    if descending:
        cmp = own_v[:, None, :] < own_u[None, :, :]   # (BV, BU, BM)
    else:
        cmp = own_v[:, None, :] > own_u[None, :, :]
    sc = same[:, :, None]
    contrib = jnp.where(cmp & (sc > 0.5), di[None, :, :], 0.0)
    acc_i_ref[...] += jnp.sum(contrib, axis=1)

    xterm = (1.0 - same)[:, :, None] * g * dx[None, :, :]
    acc_x_ref[...] += jnp.sum(xterm, axis=1)

    @pl.when(ui == nu - 1)
    def _finish():
        d_wi_ref[...] = acc_i_ref[...]
        d_wp_ref[...] = acc_x_ref[...]


def noma_pairwise_kernel(
    own_u: jax.Array,    # (U, M) fp32
    own_v: jax.Array,    # (V, M)  V may exceed U (independent padding)
    w_intra: jax.Array,  # (V, M)
    w_power: jax.Array,  # (V, M)
    g_vu: jax.Array,     # (V, U, M)  interferer-major
    same: jax.Array,     # (U, V) fp32 0/1
    descending: bool = True,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
    n_valid: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """n_valid: number of real (unpadded) interferer rows; rows >= n_valid are
    masked out of both reductions (defaults to V, i.e. no padding)."""
    u, m = own_u.shape
    v = own_v.shape[0]
    n_valid = v if n_valid is None else n_valid
    bu, bv, bm = min(block_u, u), min(block_v, v), min(block_m, m)
    nu, nvb, nm = pl.cdiv(u, bu), pl.cdiv(v, bv), pl.cdiv(m, bm)

    kernel = functools.partial(_kernel, descending=descending, n_users=n_valid,
                               block_v=bv)
    grid = (nu, nm, nvb)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu, bm), lambda ui, mi, vi: (ui, mi)),       # own_u
            pl.BlockSpec((bv, bm), lambda ui, mi, vi: (vi, mi)),       # own_v
            pl.BlockSpec((bv, bm), lambda ui, mi, vi: (vi, mi)),       # w_intra
            pl.BlockSpec((bv, bm), lambda ui, mi, vi: (vi, mi)),       # w_power
            pl.BlockSpec((bv, bu, bm), lambda ui, mi, vi: (vi, ui, mi)),  # g_vu
            pl.BlockSpec((bu, bv), lambda ui, mi, vi: (ui, vi)),       # same
        ],
        out_specs=[
            pl.BlockSpec((bu, bm), lambda ui, mi, vi: (ui, mi)),
            pl.BlockSpec((bu, bm), lambda ui, mi, vi: (ui, mi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((u, m), jnp.float32),
            jax.ShapeDtypeStruct((u, m), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bu, bm), jnp.float32),
            pltpu.VMEM((bu, bm), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(own_u, own_v, w_intra, w_power, g_vu, same)
    return out[0], out[1]


def noma_pairwise_bwd_kernel(
    own_u: jax.Array,    # (U, M) fp32
    own_v: jax.Array,    # (V, M)
    g_vu: jax.Array,     # (V, U, M)  interferer-major
    same_vu: jax.Array,  # (V, U) fp32 0/1 -- the forward mask TRANSPOSED
    d_intra: jax.Array,  # (U, M) cotangent of the forward intra output
    d_inter: jax.Array,  # (U, M) cotangent of the forward inter output
    descending: bool = True,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """VJP of noma_pairwise_kernel w.r.t. (w_intra, w_power): (V, M) each.

    Same (BU, BV, BM) VMEM block budget as the forward pass, with the grid
    transposed: (V, M) cotangent tiles accumulate while receiver blocks
    stream sequentially, so the backward direction never materializes
    (U, V, M) either. Cotangents w.r.t. own_u/own_v are zero a.e. (the SIC
    ordering enters through a step function, exactly as in the einsum
    reference where the comparison is detached by .astype) and are the
    caller's to emit; d_g_vu is never needed because the channel gains are
    environment constants in the GD path."""
    u, m = own_u.shape
    v = own_v.shape[0]
    bu, bv, bm = min(block_u, u), min(block_v, v), min(block_m, m)
    nu, nvb, nm = pl.cdiv(u, bu), pl.cdiv(v, bv), pl.cdiv(m, bm)

    kernel = functools.partial(_bwd_kernel, descending=descending)
    grid = (nvb, nm, nu)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu, bm), lambda vi, mi, ui: (ui, mi)),       # own_u
            pl.BlockSpec((bv, bm), lambda vi, mi, ui: (vi, mi)),       # own_v
            pl.BlockSpec((bv, bu, bm), lambda vi, mi, ui: (vi, ui, mi)),  # g_vu
            pl.BlockSpec((bv, bu), lambda vi, mi, ui: (vi, ui)),       # same_vu
            pl.BlockSpec((bu, bm), lambda vi, mi, ui: (ui, mi)),       # d_intra
            pl.BlockSpec((bu, bm), lambda vi, mi, ui: (ui, mi)),       # d_inter
        ],
        out_specs=[
            pl.BlockSpec((bv, bm), lambda vi, mi, ui: (vi, mi)),
            pl.BlockSpec((bv, bm), lambda vi, mi, ui: (vi, mi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((v, m), jnp.float32),
            jax.ShapeDtypeStruct((v, m), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bv, bm), jnp.float32),
            pltpu.VMEM((bv, bm), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(own_u, own_v, g_vu, same_vu, d_intra, d_inter)
    return out[0], out[1]


def vmem_block_bytes(block_u: int = 8, block_v: int = 8, block_m: int = 128,
                     direction: str = "fwd") -> int:
    """Analytic fp32 VMEM working set of one kernel block (inputs + scratch +
    outputs). The dominant term is the streamed (BV, BU, BM) gain block in
    both directions; bwd - fwd = 8*(block_v - block_u)*block_m bytes, so the
    backward pass fits the forward budget whenever block_v <= block_u
    (equal at the deployed square tiles)."""
    bu, bv, bm = block_u, block_v, block_m
    if direction == "fwd":
        # own_u, 2x scratch, 2x out: (BU, BM); own_v, w_intra, w_power: (BV, BM)
        words = 5 * bu * bm + 3 * bv * bm + bv * bu * bm + bu * bv
    elif direction == "bwd":
        # own_u, d_intra, d_inter: (BU, BM); own_v, 2x scratch, 2x out: (BV, BM)
        words = 3 * bu * bm + 5 * bv * bm + bv * bu * bm + bv * bu
    else:
        raise ValueError(f"direction must be 'fwd' or 'bwd', got {direction!r}")
    return 4 * words
