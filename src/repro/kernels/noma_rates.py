"""Pallas TPU kernels for the NOMA pairwise-interference reduction.

This is the paper's computational hot spot: every (Li-)GD iteration evaluates
U x M SINR terms whose denominators are masked pairwise reductions over all
other users (SIC intra-cell ordering + inter-cell leakage), eqs. (5)/(8).
Naively this is a (U, V, M) tensor -- at paper scale (U=1250, M=250) that is
390M elements per evaluation, too large to materialize in fp32 on-chip.

Cell-block decomposition (the massive-connectivity layout): the two terms of
the denominator have fundamentally different structure, so they run through
different kernels.

* The INTER-cell term couples a pair (u, v) only through the shared AP, so it
  factors exactly through a per-AP (N, M) table and never needs pairwise
  compute:

    uplink:   inter[u,m] = A[ap[u], m],
              A[n,m]     = sum_v [ap[v] != n] * w_power[v,m] * g_up[v,n,m]
    downlink: inter[u,m] = sum_n [ap[u] != n] * g_dn[n,u,m] * B[n,m],
              B[n,m]     = sum_v [ap[v] == n] * w_power[v,m]

  The gain-carrying reductions run as N-TILED Pallas kernels -- a blocked
  (BN, BM) accumulator, the raw gain streamed single-pass in (BW, BN, BM)
  blocks -- so per-block VMEM is a function of BN only, independent of the
  total AP count N (noma_per_ap_kernel builds A and the backward cotangent
  table D; noma_ap_contract_kernel consumes B and the backward C). The
  gain-free tables (B, C) are plain O(U*M) segment-sums, and the final
  row-selections A[ap] / D[ap] are O(U*M) takes of a tiny (N, M) tensor.

* The INTRA-cell SIC term is a genuine per-pair comparison,

    intra[u,m] = sum_v same[u,v] * cmp(own_v[v,m], own_u[u,m]) * w_intra[v,m]

  but same[u,v] makes it BLOCK-SPARSE: only same-cell pairs contribute. The
  intra kernel (noma_cell_intra_kernel) launches over an explicit tile list
  (tile_r[t], tile_s[t]) held in SMEM via scalar prefetch, with every block
  load index-mapped through the prefetched ids. With users sorted by AP
  (kernels/cells.py CellLayout) the same-cell pairs live on the block
  diagonal, so the list covers sum-of-cell-sizes^2 work instead of U^2 --
  forward and backward (the backward list is the same tile set reordered so
  the transposed output blocks are revisited consecutively). Without a
  layout the list is simply the dense grid, which reproduces the previous
  all-pairs schedule.

AP structure enters as RAW int32 ap ids, not a (U, N) one-hot: the same-cell
mask is an in-kernel id compare (O(1) in N), and the one-hot blocks the
per-AP kernels need are derived from the ids against an N-block iota
(ap_mode="iota", the profiled default -- no O(U*N) one-hot in HBM, which at
U ~ 1e6, N ~ 1e3 would itself be GBs). ap_mode="onehot" retains the
previous MXU-contraction layout (a streamed (BW, BN) one-hot block slice)
for like-for-like profiling in kernel_bench.

Inputs arrive UNPADDED: grids over-cover with pl.cdiv and boundary blocks
are masked in-kernel (iota vs the true U/V/M/N extents). Out-of-bounds
lanes of a boundary block read unspecified values (NaN in interpret mode),
so masks are applied with jnp.where -- never by multiplication -- and every
reduction keeps OOB garbage confined to rows/lanes the final (clipped)
output store drops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

AP_MODES = ("iota", "onehot")

# VMEM ceiling the autotuner must respect (TPU v4/v5 have ~16 MiB/core;
# Pallas double-buffers inputs, so kernels budget to half).
VMEM_CEILING_BYTES = 16 * 1024 * 1024

# The (BU, BV, BM, BN) blocks the ops wrappers -- and therefore the engine's
# compiled programs -- use when the caller does not override them. Single
# definition so the analyzer (repro.analysis) and benchmarks derive grid and
# VMEM expectations from the same numbers the kernels actually launch with.
DEFAULT_BLOCKS = (8, 8, 128, 8)

# (BU, BV, BM, BN) candidates the kernel_bench autotuner may select from.
# Every entry must satisfy vmem_block_bytes(...) < VMEM_CEILING_BYTES for
# both directions and both links at any n_aps (enforced by
# tests/test_kernels.py::test_autotune_candidates_fit_vmem_ceiling); the
# winning row is recorded in the BENCH artifact's tuning table.
AUTOTUNE_BLOCKS = (
    (8, 8, 128, 8),
    (8, 8, 128, 16),
    (16, 16, 128, 8),
    (16, 8, 256, 8),
    (8, 16, 128, 16),
    (32, 32, 128, 8),
    (8, 8, 512, 8),
    (16, 16, 256, 16),
)


def _valid_rows(block_id, block: int, rows: int, n_valid: int):
    """(rows, 1) bool: which rows of this block index real (unpadded) data.
    block_id may be a traced scalar (scalar-prefetched tile id)."""
    idx = block_id * block + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    return idx < n_valid


def _onehot_block(ap_col, ni, block_n: int, oh_ref):
    """(BW, BN) bool AP one-hot block for N-block ni.

    ap_mode="iota": derived from the raw ap ids against the block's global
    n indices -- OOB n columns (boundary N block) can never match a valid
    ap id, so the boundary mask is free. ap_mode="onehot": sliced from the
    streamed (W, N) one-hot operand (oh_ref is the (BW, BN) block)."""
    if oh_ref is not None:
        return oh_ref[...] > 0.5
    bw = ap_col.shape[0]
    n_global = ni * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (bw, block_n), 1)
    return ap_col == n_global


def _cell_intra_kernel(tr_ref, ts_ref, own_r_ref, own_s_ref, w_ref,
                       ap_r_ref, ap_s_ref, out_ref, acc_ref, *,
                       descending: bool, n_s: int, block_s: int):
    """Tile-driven SIC intra reduction:

      out[r,m] = sum_s same[r,s] * cmp(own_s[s,m], own_r[r,m]) * w[s,m]

    over the scalar-prefetched tile list (tr[t], ts[t]). The list is sorted
    by tr, so all tiles of one output block are consecutive: the (BR, BM)
    accumulator is zeroed at the first tile of a run and stored at the last
    (the output block index is constant in between, so Pallas keeps the
    buffer resident). same[r,s] is an ap-id compare -- no one-hot, no gain,
    nothing in this kernel depends on the AP count."""
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    rb = tr_ref[t]
    first = (t == 0) | (tr_ref[jnp.maximum(t - 1, 0)] != rb)
    last = (t == nt - 1) | (tr_ref[jnp.minimum(t + 1, nt - 1)] != rb)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    own_r = own_r_ref[...]           # (BR, BM)
    own_s = own_s_ref[...]           # (BS, BM)
    ap_r = ap_r_ref[...]             # (BR, 1) int32
    ap_s = ap_s_ref[...]             # (BS, 1) int32
    same = ap_r == ap_s.T            # (BR, BS)
    valid_s = _valid_rows(ts_ref[t], block_s, ap_s.shape[0], n_s)  # (BS, 1)
    if descending:
        cmp = own_s[None, :, :] < own_r[:, None, :]
    else:
        cmp = own_s[None, :, :] > own_r[:, None, :]
    keep = cmp & same[:, :, None] & valid_s[None, :, 0, None]
    acc_ref[...] += jnp.sum(jnp.where(keep, w_ref[...][None, :, :], 0.0),
                            axis=1)

    @pl.when(last)
    def _store():
        out_ref[...] = acc_ref[...]


def _per_ap_kernel(*refs, uplink: bool, n_w: int, block_w: int, block_n: int,
                   onehot: bool):
    """Other-cell per-AP reduction into a BLOCKED (BN, BM) accumulator:

      out[n,m] = sum_w [ap[w] != n] * wgt[w,m] * g[w or n major]

    Grid (NN, NM, NW): the (BN, BM) output block accumulates while the users
    stream; the raw gain is read in (BW, BN, BM) / (BN, BW, BM) blocks, each
    exactly once across the grid (single-pass)."""
    if onehot:
        ap_ref, wgt_ref, g_ref, oh_ref, out_ref, acc_ref = refs
    else:
        ap_ref, wgt_ref, g_ref, out_ref, acc_ref = refs
        oh_ref = None
    ni = pl.program_id(0)
    wi = pl.program_id(2)
    nw = pl.num_programs(2)

    @pl.when(wi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ap_col = ap_ref[...]             # (BW, 1)
    wgt = wgt_ref[...]               # (BW, BM)
    oh = _onehot_block(ap_col, ni, block_n, oh_ref)   # (BW, BN)
    valid_w = _valid_rows(wi, block_w, ap_col.shape[0], n_w)
    other = (~oh) & valid_w          # (BW, BN); OOB n rows of acc are
    if uplink:                       # clipped at the (boundary-block) store
        g = g_ref[...]               # (BW, BN, BM)
        term = jnp.where(other[:, :, None], wgt[:, None, :] * g, 0.0)
        acc_ref[...] += jnp.sum(term, axis=0)
    else:
        g = g_ref[...]               # (BN, BW, BM)
        term = jnp.where(other.T[:, :, None], g * wgt[None, :, :], 0.0)
        acc_ref[...] += jnp.sum(term, axis=1)

    @pl.when(wi == nw - 1)
    def _store():
        out_ref[...] = acc_ref[...]


def _ap_contract_kernel(*refs, uplink: bool, n_aps: int, block_n: int,
                        onehot: bool):
    """Other-cell contraction of a per-AP (N, M) table against the raw gain:

      out[w,m] = sum_n [ap[w] != n] * g[w or n major] * nm[n,m]

    Grid (NW, NM, NN): the (BW, BM) output block accumulates while the AP
    axis streams in BN blocks; each raw-gain block is read exactly once.
    The reduction runs over n, so OOB n lanes (boundary N block) are
    excluded explicitly -- garbage there would contaminate valid outputs."""
    if onehot:
        ap_ref, nm_ref, g_ref, oh_ref, out_ref, acc_ref = refs
    else:
        ap_ref, nm_ref, g_ref, out_ref, acc_ref = refs
        oh_ref = None
    ni = pl.program_id(2)
    nn = pl.num_programs(2)

    @pl.when(ni == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ap_col = ap_ref[...]             # (BW, 1)
    nm_t = nm_ref[...]               # (BN, BM)
    oh = _onehot_block(ap_col, ni, block_n, oh_ref)
    bw, bn = oh.shape
    n_global = ni * block_n + jax.lax.broadcasted_iota(jnp.int32, (bw, bn), 1)
    other = (~oh) & (n_global < n_aps)   # (BW, BN)
    if uplink:
        g = g_ref[...]               # (BW, BN, BM)
        term = jnp.where(other[:, :, None], g * nm_t[None, :, :], 0.0)
        acc_ref[...] += jnp.sum(term, axis=1)
    else:
        g = g_ref[...]               # (BN, BW, BM)
        term = jnp.where(other.T[:, :, None], g * nm_t[:, None, :], 0.0)
        acc_ref[...] += jnp.sum(term, axis=0)

    @pl.when(ni == nn - 1)
    def _store():
        out_ref[...] = acc_ref[...]


def dense_tile_count(n_r: int, n_s: int, block_r: int = 8,
                     block_s: int = 8) -> int:
    """Tile count of the dense (no-CellLayout) intra/SIC schedule: every
    (r-block, s-block) pair, with the same block clamping the kernels apply.
    This is what the analysis.SparseGrid rule expects for programs that do
    not thread a layout (the engine today -- see ROADMAP); with a layout the
    expectation is CellLayout.n_tiles."""
    br, bs = min(block_r, n_r), min(block_s, n_s)
    return int(pl.cdiv(n_r, br)) * int(pl.cdiv(n_s, bs))


def max_vmem_block_bytes(block_u: int = 8, block_v: int = 8,
                         block_m: int = 128, block_n: int = 8,
                         n_aps: int = 4) -> int:
    """vmem_block_bytes maximized over direction x link: the single number a
    block-size candidate must keep under VMEM_CEILING_BYTES (every autotune
    candidate launches all four kernel directions across a grad step)."""
    return max(
        vmem_block_bytes(block_u, block_v, block_m, block_n, n_aps,
                         direction=d, uplink=ul)
        for d in ("fwd", "bwd") for ul in (True, False))


@functools.lru_cache(maxsize=64)
def _dense_tiles(n_blocks_r: int, n_blocks_s: int):
    """All (r, s) block pairs, sorted by r: the no-layout tile list (exactly
    the previous all-pairs schedule). Shape-derived, so safe under jit."""
    rr, ss = np.meshgrid(np.arange(n_blocks_r, dtype=np.int32),
                         np.arange(n_blocks_s, dtype=np.int32), indexing="ij")
    return rr.ravel(), ss.ravel()


def noma_cell_intra_kernel(
    own_r: jax.Array,    # (R, M) fp32 own-cell gain of the receivers
    own_s: jax.Array,    # (S, M) own-cell gain of the streamed users
    w_s: jax.Array,      # (S, M) per-user weight (w_intra fwd, cotangent bwd)
    ap_r: jax.Array,     # (R,) int32 serving-AP ids
    ap_s: jax.Array,     # (S,) int32
    tile_r: jax.Array | None = None,   # (T,) int32 receiver block per tile
    tile_s: jax.Array | None = None,   # (T,) int32 streamed block per tile
    descending: bool = True,
    block_r: int = 8,
    block_s: int = 8,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """SIC intra reduction over an explicit tile list, (R, M):

      out[r,m] = sum_s [ap_r[r] == ap_s[s]] * cmp(own_s, own_r) * w_s[s,m]

    tile_r MUST be non-decreasing (output blocks are revisited while the
    index is constant and written out when it changes) and the tile set must
    cover every (r-block, s-block) pair containing a same-cell pair exactly
    once -- kernels/cells.py builds such lists from a host-side sort; the
    default is the dense grid. Scalar-prefetch machinery: the tile ids live
    in SMEM and every VMEM block load is index-mapped through them."""
    r, m = own_r.shape
    s = own_s.shape[0]
    br, bs, bm = min(block_r, r), min(block_s, s), min(block_m, m)
    if tile_r is None or tile_s is None:
        tr_np, ts_np = _dense_tiles(pl.cdiv(r, br), pl.cdiv(s, bs))
        tile_r, tile_s = jnp.asarray(tr_np), jnp.asarray(ts_np)
    nt = tile_r.shape[0]
    nm = pl.cdiv(m, bm)

    kernel = functools.partial(_cell_intra_kernel, descending=descending,
                               n_s=s, block_s=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nm, nt),
        in_specs=[
            pl.BlockSpec((br, bm), lambda mi, t, tr, ts: (tr[t], mi)),
            pl.BlockSpec((bs, bm), lambda mi, t, tr, ts: (ts[t], mi)),
            pl.BlockSpec((bs, bm), lambda mi, t, tr, ts: (ts[t], mi)),
            pl.BlockSpec((br, 1), lambda mi, t, tr, ts: (tr[t], 0)),
            pl.BlockSpec((bs, 1), lambda mi, t, tr, ts: (ts[t], 0)),
        ],
        out_specs=pl.BlockSpec((br, bm), lambda mi, t, tr, ts: (tr[t], mi)),
        scratch_shapes=[pltpu.VMEM((br, bm), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, m), jnp.float32),
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(tile_r, tile_s, own_r, own_s, w_s,
      ap_r.reshape(-1, 1), ap_s.reshape(-1, 1))


def _ap_structure_operands(ap, n_aps: int, ap_mode: str, block_w: int,
                           block_n: int, grid_pos: tuple):
    """(extra operands, extra in_specs) for the AP-structure input of the
    per-AP/contract kernels. ap_mode="onehot" streams a (W, N) one-hot
    (built here, outside the kernel -- the PR-5 layout); "iota" needs
    nothing beyond the ap ids."""
    if ap_mode not in AP_MODES:
        raise ValueError(f"ap_mode must be one of {AP_MODES}, got {ap_mode!r}")
    if ap_mode == "iota":
        return [], []
    oh = jax.nn.one_hot(ap, n_aps, dtype=jnp.float32)
    wi_pos, ni_pos = grid_pos
    spec = pl.BlockSpec((block_w, block_n),
                        lambda *idx: (idx[wi_pos], idx[ni_pos]))
    return [oh], [spec]


def noma_per_ap_kernel(
    ap: jax.Array,       # (W,) int32 serving-AP ids of the streamed users
    wgt: jax.Array,      # (W, M) per-user weight (w_power fwd, dx bwd)
    g_raw: jax.Array,    # uplink: (W, N, M) raw g_up; downlink: (N, W, M) raw g_dn
    uplink: bool = True,
    block_w: int = 8,
    block_m: int = 128,
    block_n: int = 8,
    ap_mode: str = "iota",
    interpret: bool = False,
) -> jax.Array:
    """Other-cell per-AP reduction, (N, M):

      out[n,m] = sum_w [ap[w] != n] * wgt[w,m] * g[w,n,m]   (uplink layout)
      out[n,m] = sum_w [ap[w] != n] * wgt[w,m] * g[n,w,m]   (downlink layout)

    Streams the raw gain exactly once. The accumulator is a BLOCKED
    (BN, BM) tile on an N-tiled grid, so the per-block VMEM budget is a
    function of BN only -- independent of the total AP count (N in the
    thousands tiles like N=16)."""
    w = ap.shape[0]
    m = wgt.shape[1]
    n_aps = g_raw.shape[1] if uplink else g_raw.shape[0]
    bw, bm, bn = min(block_w, w), min(block_m, m), min(block_n, n_aps)
    nwb, nm, nn = pl.cdiv(w, bw), pl.cdiv(m, bm), pl.cdiv(n_aps, bn)

    kernel = functools.partial(_per_ap_kernel, uplink=uplink, n_w=w,
                               block_w=bw, block_n=bn,
                               onehot=ap_mode == "onehot")
    if uplink:
        g_spec = pl.BlockSpec((bw, bn, bm), lambda ni, mi, wi: (wi, ni, mi))
    else:
        g_spec = pl.BlockSpec((bn, bw, bm), lambda ni, mi, wi: (ni, wi, mi))
    extra, extra_specs = _ap_structure_operands(ap, n_aps, ap_mode, bw, bn,
                                                grid_pos=(2, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nn, nm, nwb),
        in_specs=[
            pl.BlockSpec((bw, 1), lambda ni, mi, wi: (wi, 0)),      # ap
            pl.BlockSpec((bw, bm), lambda ni, mi, wi: (wi, mi)),    # wgt
            g_spec,                                                 # g_raw
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda ni, mi, wi: (ni, mi)),
        out_shape=jax.ShapeDtypeStruct((n_aps, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap.reshape(-1, 1), wgt, g_raw, *extra)
    return out


def noma_ap_contract_kernel(
    ap: jax.Array,       # (W,) int32 serving-AP ids of the output users
    nm_table: jax.Array,  # (N, M) per-AP table (B fwd-dn, C bwd-up)
    g_raw: jax.Array,    # uplink: (W, N, M) raw g_up; downlink: (N, W, M) raw g_dn
    uplink: bool = True,
    block_w: int = 8,
    block_m: int = 128,
    block_n: int = 8,
    ap_mode: str = "iota",
    interpret: bool = False,
) -> jax.Array:
    """Other-cell contraction of a per-AP table against the raw gain, (W, M):

      out[w,m] = sum_n [ap[w] != n] * g[w,n,m] * nm[n,m]   (uplink layout)
      out[w,m] = sum_n [ap[w] != n] * g[n,w,m] * nm[n,m]   (downlink layout)

    The dual of noma_per_ap_kernel: the AP axis streams in BN blocks while
    the (BW, BM) output accumulates, raw gain single-pass, VMEM O(BN)."""
    w = ap.shape[0]
    n_aps, m = nm_table.shape
    bw, bm, bn = min(block_w, w), min(block_m, m), min(block_n, n_aps)
    nwb, nm, nn = pl.cdiv(w, bw), pl.cdiv(m, bm), pl.cdiv(n_aps, bn)

    kernel = functools.partial(_ap_contract_kernel, uplink=uplink,
                               n_aps=n_aps, block_n=bn,
                               onehot=ap_mode == "onehot")
    if uplink:
        g_spec = pl.BlockSpec((bw, bn, bm), lambda wi, mi, ni: (wi, ni, mi))
    else:
        g_spec = pl.BlockSpec((bn, bw, bm), lambda wi, mi, ni: (ni, wi, mi))
    extra, extra_specs = _ap_structure_operands(ap, n_aps, ap_mode, bw, bn,
                                                grid_pos=(0, 2))
    out = pl.pallas_call(
        kernel,
        grid=(nwb, nm, nn),
        in_specs=[
            pl.BlockSpec((bw, 1), lambda wi, mi, ni: (wi, 0)),      # ap
            pl.BlockSpec((bn, bm), lambda wi, mi, ni: (ni, mi)),    # nm_table
            g_spec,                                                 # g_raw
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bw, bm), lambda wi, mi, ni: (wi, mi)),
        out_shape=jax.ShapeDtypeStruct((w, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw, bm), jnp.float32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap.reshape(-1, 1), nm_table, g_raw, *extra)
    return out


def _segment_table(values: jax.Array, ap: jax.Array, n_aps: int) -> jax.Array:
    """(N, M) per-AP segment sum: sum_w [ap[w] == n] * values[w, m]. The
    gain-free per-AP tables (fwd-dn B, bwd-up C) -- O(U*M) scatter-add, no
    (U, N) one-hot, no pairwise anything."""
    return jnp.zeros((n_aps, values.shape[1]), jnp.float32).at[ap].add(
        values.astype(jnp.float32))


def noma_pairwise_kernel(
    own_u: jax.Array,    # (U, M) fp32
    own_v: jax.Array,    # (V, M)  V may differ from U (it never does in ops)
    w_intra: jax.Array,  # (V, M)
    w_power: jax.Array,  # (V, M)
    g_raw: jax.Array,    # uplink: (V, N, M) raw g_up; downlink: (N, U, M) raw g_dn
    ap_u: jax.Array,     # (U,) int32 serving-AP ids of the receivers
    ap_v: jax.Array,     # (V,) int32 serving-AP ids of the interferers
    descending: bool = True,
    uplink: bool = True,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
    block_n: int = 8,
    tiles: tuple[jax.Array, jax.Array] | None = None,
    ap_mode: str = "iota",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Cell-block pairwise reduction: returns (intra (U, M), inter (U, M)).

    intra runs through the tile-driven SIC kernel (tiles = the (tile_u,
    tile_v) block-diagonal list from a CellLayout, or the dense grid when
    None); inter is recovered entirely from per-AP (N, M) tables -- the
    gain-carrying reduction N-tiled and single-pass, the rest O(U*M).
    All inputs are consumed unpadded; boundary blocks are masked in-kernel."""
    tile_u, tile_v = tiles if tiles is not None else (None, None)
    intra = noma_cell_intra_kernel(
        own_u, own_v, w_intra, ap_u, ap_v, tile_u, tile_v,
        descending=descending, block_r=block_u, block_s=block_v,
        block_m=block_m, interpret=interpret)
    if uplink:
        a_nm = noma_per_ap_kernel(ap_v, w_power, g_raw, uplink=True,
                                  block_w=block_v, block_m=block_m,
                                  block_n=block_n, ap_mode=ap_mode,
                                  interpret=interpret)
        inter = jnp.take(a_nm, ap_u, axis=0)
    else:
        b_nm = _segment_table(w_power, ap_v, g_raw.shape[0])
        inter = noma_ap_contract_kernel(ap_u, b_nm, g_raw, uplink=False,
                                        block_w=block_u, block_m=block_m,
                                        block_n=block_n, ap_mode=ap_mode,
                                        interpret=interpret)
    return intra, inter


def noma_pairwise_bwd_kernel(
    own_u: jax.Array,    # (U, M) fp32
    own_v: jax.Array,    # (V, M)
    g_raw: jax.Array,    # uplink: (V, N, M); downlink: (N, U, M)
    ap_u: jax.Array,     # (U,) int32
    ap_v: jax.Array,     # (V,) int32
    d_intra: jax.Array,  # (U, M) cotangent of the forward intra output
    d_inter: jax.Array,  # (U, M) cotangent of the forward inter output
    descending: bool = True,
    uplink: bool = True,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
    block_n: int = 8,
    tiles: tuple[jax.Array, jax.Array] | None = None,
    ap_mode: str = "iota",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """VJP of noma_pairwise_kernel w.r.t. (w_intra, w_power): (V, M) each.

    The intra cotangent is the SAME tile kernel with receiver/streamed roles
    swapped and the SIC comparison flipped (sum_u same * cmp * d_intra[u]);
    tiles here is the layout's BACKWARD list -- the identical tile set
    reordered so tile_v is non-decreasing (dense grid transposed when None).
    The inter cotangent mirrors the forward factorization with the per-AP
    roles swapped: uplink contracts C[n,m] = sum_u [ap[u]==n] d_inter[u,m]
    against the raw gain (N-tiled, single-pass); downlink takes rows of the
    per-AP cotangent table D[n,m] = sum_u [ap[u]!=n] g_dn[n,u,m] d_inter.
    Cotangents w.r.t. own_u/own_v are zero a.e. (the SIC ordering enters
    through a step function, exactly as in the einsum reference where the
    comparison is detached) and are the caller's to emit; d_g is never
    needed because the channel gains are environment constants in the GD
    path."""
    tile_v_b, tile_u_b = tiles if tiles is not None else (None, None)
    d_wi = noma_cell_intra_kernel(
        own_v, own_u, d_intra, ap_v, ap_u, tile_v_b, tile_u_b,
        descending=not descending, block_r=block_v, block_s=block_u,
        block_m=block_m, interpret=interpret)
    if uplink:
        c_nm = _segment_table(d_inter, ap_u, g_raw.shape[1])
        d_wp = noma_ap_contract_kernel(ap_v, c_nm, g_raw, uplink=True,
                                       block_w=block_v, block_m=block_m,
                                       block_n=block_n, ap_mode=ap_mode,
                                       interpret=interpret)
    else:
        d_nm = noma_per_ap_kernel(ap_u, d_inter, g_raw, uplink=False,
                                  block_w=block_u, block_m=block_m,
                                  block_n=block_n, ap_mode=ap_mode,
                                  interpret=interpret)
        d_wp = jnp.take(d_nm, ap_v, axis=0)
    return d_wi, d_wp


def vmem_block_bytes(block_u: int = 8, block_v: int = 8, block_m: int = 128,
                     block_n: int = 8, n_aps: int = 4, direction: str = "fwd",
                     uplink: bool = True) -> int:
    """Analytic fp32 VMEM working set of one kernel block (inputs + scratch
    + outputs), reported as the MAX over the Pallas kernels a direction
    launches: the tile-driven intra kernel plus one N-tiled gain kernel
    (per-AP for uplink-fwd/downlink-bwd, contract for downlink-fwd/
    uplink-bwd). Every term is a function of the BLOCK sizes only: the raw
    gain enters as a (BW, BN, BM) block and the accumulators are (BN, BM) /
    (BW, BM), so the budget is INDEPENDENT of the total AP count N (n_aps
    only clamps BN, exactly as the kernels do) -- N=4096 tiles under the
    same budget as N=16. The previous layout's ~4 KiB/AP linear term is
    gone; the tile lists themselves live in SMEM, not VMEM."""
    bm, bn = block_m, min(block_n, n_aps)

    def intra(br, bs):
        # own_r + out + acc (BR, BM); own_s + w (BS, BM); ap ids (BR/BS, 1)
        return 3 * br * bm + 2 * bs * bm + br + bs

    def per_ap(bw):
        # ap (BW, 1) + wgt (BW, BM) + gain (BW, BN, BM) + out/acc (BN, BM)
        return bw + bw * bm + bw * bn * bm + 2 * bn * bm

    def contract(bw):
        # ap (BW, 1) + table (BN, BM) + gain (BW, BN, BM) + out/acc (BW, BM)
        return bw + bn * bm + bw * bn * bm + 2 * bw * bm

    if direction == "fwd":
        words = max(intra(block_u, block_v),
                    per_ap(block_v) if uplink else contract(block_u))
    elif direction == "bwd":
        words = max(intra(block_v, block_u),
                    contract(block_v) if uplink else per_ap(block_u))
    else:
        raise ValueError(f"direction must be 'fwd' or 'bwd', got {direction!r}")
    return 4 * words
