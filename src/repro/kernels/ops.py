"""jit'd public wrappers around the Pallas kernels.

Each wrapper handles padding to block multiples, GQA reshapes, and exposes
`interpret=` so the CPU container can execute the kernel bodies for
validation (the compiled Mosaic path needs real TPU hardware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cells import CellLayout
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.noma_rates import (
    DEFAULT_BLOCKS,
    noma_pairwise_bwd_kernel,
    noma_pairwise_kernel,
)
from repro.kernels.rg_lru import rg_lru_kernel
from repro.core.types import LOG2, NetworkEnv


def _pad_to(x, mult, axis):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,   # (B, Sq, H, hd)
    k: jax.Array,   # (B, Sk, KV, hd)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, sk))
    qp = _pad_to(qf, bq, 1)
    kp = _pad_to(kf, bk, 1)
    vp = _pad_to(vf, bk, 1)
    out = flash_attention_kernel(
        qp, kp, vp, group=g, causal=causal, window=window,
        block_q=bq, block_k=bk, kv_len=sk, interpret=interpret,
    )[:, :sq]
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


def _layout_blocks(layout, env, block_u, block_v):
    """Resolve the intra block sizes. The tile lists are block-granular and
    tied to one env, so when a CellLayout is supplied ITS blocks are
    authoritative (they were fixed at build_cell_layout time) and override
    the arguments -- channel-layer callers thread layout= without having to
    re-thread matching block sizes. A layout built for a different user
    count is a silent-wrong-answer bug and is refused."""
    if layout is None:
        return block_u, block_v
    if layout.n_users != env.n_users:
        raise ValueError(
            f"CellLayout built for U={layout.n_users}, env has "
            f"U={env.n_users}; rebuild with build_cell_layout(env, ...).")
    return layout.block_u, layout.block_v


def _noma_pairwise(own, w_intra, w_power, g_raw, ap, uplink, descending,
                   interpret, block_u, block_v, block_m, block_n, tiles,
                   ap_mode):
    """Run the cell-block forward kernel on the UNPADDED operands.

    The kernel masks boundary blocks in-kernel (clamped cdiv grid), so no
    _pad_to copies -- and no pad ops in the jaxpr -- on any operand; the
    receiver (U) and interferer (V) axes still tile independently
    (block_u vs block_v), and the AP axis tiles in block_n. tiles is the
    layout's block-diagonal intra list (dense grid when None)."""
    return noma_pairwise_kernel(
        own, own, w_intra, w_power, g_raw, ap, ap,
        descending=descending, uplink=uplink,
        block_u=block_u, block_v=block_v, block_m=block_m, block_n=block_n,
        tiles=tiles, ap_mode=ap_mode, interpret=interpret,
    )


def _noma_pairwise_bwd(own, g_raw, ap, d_intra, d_inter, uplink, descending,
                       interpret, block_u, block_v, block_m, block_n, tiles,
                       ap_mode):
    """Backward twin of _noma_pairwise: the transposed-streaming kernels on
    the same unpadded raw-gain operands; returns (V, M) weight cotangents.
    tiles is the layout's BACKWARD list (the same tile set reordered for the
    swapped receiver/streamed roles); boundary blocks are masked in-kernel
    (the cotangents arrive unpadded, so garbage OOB lanes must not
    contribute)."""
    d_wi, d_wp = noma_pairwise_bwd_kernel(
        own, own, g_raw, ap, ap,
        d_intra.astype(jnp.float32), d_inter.astype(jnp.float32),
        descending=descending, uplink=uplink,
        block_u=block_u, block_v=block_v, block_m=block_m, block_n=block_n,
        tiles=tiles, ap_mode=ap_mode, interpret=interpret,
    )
    return d_wi, d_wp


def _zeros_cot(tree):
    """Zero cotangents matching a primal pytree: float leaves get dense
    zeros (weak types preserved via zeros_like), integer leaves get the
    float0 arrays custom_vjp requires for non-differentiable dtypes."""
    def z(x):
        if jnp.issubdtype(jax.core.get_aval(x).dtype, jnp.inexact):
            return jnp.zeros_like(x)
        return np.zeros(jnp.shape(x), jax.dtypes.float0)
    return jax.tree.map(z, tree)


def _used_env(env: NetworkEnv, layout: CellLayout | None) -> NetworkEnv:
    """The environment the kernels actually consume: the layout's AP-sorted
    copy when a CellLayout is supplied, the caller's env otherwise. The big
    gain permutation was paid eagerly at build_cell_layout time -- nothing
    here gathers a 3D tensor inside the traced step."""
    return env if layout is None else layout.env


def _up_inputs(env: NetworkEnv):
    """The uplink kernel inputs derived from the (used) environment, all
    constants of the GD path: own-AP gains, the RAW (V, N, M) uplink gains
    -- no g_up[:, ap, :] gather, the AP selection is an in-kernel id
    compare -- and the raw int32 ap ids."""
    own = env.own_gain_up().astype(jnp.float32)
    g_raw = env.g_up.astype(jnp.float32)
    return own, g_raw, env.ap


def _dn_inputs(env: NetworkEnv):
    """Downlink analogue: the RAW (N, U, M) downlink gains consumed
    receiver-major (no g_dn[ap, :, :] gather, no transpose copy)."""
    own = env.own_gain_dn().astype(jnp.float32)
    g_raw = env.g_dn.astype(jnp.float32)
    return own, g_raw, env.ap


def _sort_in(x, layout):
    """(U, M) decision variables into the layout's sorted user order -- the
    only per-call cost of the cell-block schedule (a 2D row take)."""
    return x if layout is None else jnp.take(x, layout.perm, axis=0)


def _sort_out(x, layout):
    """Kernel outputs back to the caller's original user order."""
    return x if layout is None else jnp.take(x, layout.inv, axis=0)


def _fwd_tiles(layout):
    return None if layout is None else (layout.tile_u, layout.tile_v)


def _bwd_tiles(layout):
    return None if layout is None else (layout.bwd_tile_v, layout.bwd_tile_u)


_PAIR_NONDIFF = (3, 4, 5, 6, 7, 8)   # interpret + block sizes + ap_mode


@functools.partial(jax.custom_vjp, nondiff_argnums=_PAIR_NONDIFF)
def _pairwise_up(env, tx, layout, interpret, block_u, block_v, block_m,
                 block_n, ap_mode):
    return _pairwise_up_fwd(env, tx, layout, interpret, block_u, block_v,
                            block_m, block_n, ap_mode)[0]


def _pairwise_up_fwd(env, tx, layout, interpret, block_u, block_v, block_m,
                     block_n, ap_mode):
    own, g_raw, ap = _up_inputs(_used_env(env, layout))
    tx = _sort_in(tx.astype(jnp.float32), layout)
    out = _noma_pairwise(own, tx * own, tx, g_raw, ap, True, True,
                         interpret, block_u, block_v, block_m, block_n,
                         _fwd_tiles(layout), ap_mode)
    # Residuals are exactly the kernel inputs -- no pairwise intermediates
    # are saved (own/g_raw/ap re-derive from env or layout.env, so the
    # residual adds only the O(U*M) own gains); the backward kernels
    # re-stream the same raw blocks through the same tile lists.
    return tuple(_sort_out(o, layout) for o in out), (env, layout, own)


def _pairwise_up_bwd(interpret, block_u, block_v, block_m, block_n, ap_mode,
                     res, ct):
    env, layout, own = res
    _, g_raw, ap = _up_inputs(_used_env(env, layout))
    d_i, d_x = (_sort_in(c, layout) for c in ct)
    d_wi, d_wp = _noma_pairwise_bwd(own, g_raw, ap, d_i, d_x, True, True,
                                    interpret, block_u, block_v, block_m,
                                    block_n, _bwd_tiles(layout), ap_mode)
    # Forward fed the kernel w_intra = tx * own and w_power = tx; chain back
    # to the one differentiable input. env and layout carry only GD-path
    # constants (zero cotangents, float0 for the int permutations/tiles).
    d_tx = _sort_out(d_wi * own + d_wp, layout)
    return _zeros_cot(env), d_tx, _zeros_cot(layout)


_pairwise_up.defvjp(_pairwise_up_fwd, _pairwise_up_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=_PAIR_NONDIFF)
def _pairwise_dn(env, tx, layout, interpret, block_u, block_v, block_m,
                 block_n, ap_mode):
    return _pairwise_dn_fwd(env, tx, layout, interpret, block_u, block_v,
                            block_m, block_n, ap_mode)[0]


def _pairwise_dn_fwd(env, tx, layout, interpret, block_u, block_v, block_m,
                     block_n, ap_mode):
    own, g_raw, ap = _dn_inputs(_used_env(env, layout))
    tx = _sort_in(tx.astype(jnp.float32), layout)
    out = _noma_pairwise(own, tx, tx, g_raw, ap, False, False,
                         interpret, block_u, block_v, block_m, block_n,
                         _fwd_tiles(layout), ap_mode)
    return tuple(_sort_out(o, layout) for o in out), (env, layout, own)


def _pairwise_dn_bwd(interpret, block_u, block_v, block_m, block_n, ap_mode,
                     res, ct):
    env, layout, own = res
    _, g_raw, ap = _dn_inputs(_used_env(env, layout))
    d_i, d_x = (_sort_in(c, layout) for c in ct)
    d_wi, d_wp = _noma_pairwise_bwd(own, g_raw, ap, d_i, d_x, False, False,
                                    interpret, block_u, block_v, block_m,
                                    block_n, _bwd_tiles(layout), ap_mode)
    # Downlink feeds tx into both weight slots (the receiver-side own-gain
    # factor of eq. 8 is applied by the caller, outside the kernel).
    return _zeros_cot(env), _sort_out(d_wi + d_wp, layout), _zeros_cot(layout)


_pairwise_dn.defvjp(_pairwise_dn_fwd, _pairwise_dn_bwd)


def noma_pairwise_up(
    env: NetworkEnv,
    tx: jax.Array,        # (U, M) beta_up * p_up
    interpret: bool = False,
    block_u: int = DEFAULT_BLOCKS[0],
    block_v: int = DEFAULT_BLOCKS[1],
    block_m: int = DEFAULT_BLOCKS[2],
    block_n: int = DEFAULT_BLOCKS[3],
    layout: CellLayout | None = None,
    ap_mode: str = "iota",
) -> tuple[jax.Array, jax.Array]:
    """Uplink (intra, inter) interference terms of eq. (5) via the Pallas
    kernels: the exact denominators consumed by channel.uplink_sinr.

    Differentiable in tx (jax.custom_vjp): the backward pass re-streams the
    same cell-block kernels in noma_rates.py, so the GD gradient path never
    materializes (U, V, M) in either direction. With a CellLayout
    (kernels/cells.py, built once per env) the intra grid covers only the
    same-cell block-diagonal tiles -- sum-of-cell-sizes^2 work, not U^2 --
    and tx/outputs cross the sort as cheap (U, M) row takes; results are
    returned in the caller's original user order either way.

    Deliberately NOT jitted: the hot callers (channel.uplink_sinr inside
    gd_solve / the engine's compiled programs) are already inside jit, and
    a nested jit only adds a closed-call trace layer. Direct eager callers
    should use noma_pairwise_up_jit."""
    block_u, block_v = _layout_blocks(layout, env, block_u, block_v)
    return _pairwise_up(env, tx, layout, interpret, block_u, block_v,
                        block_m, block_n, ap_mode)


def noma_pairwise_dn(
    env: NetworkEnv,
    tx: jax.Array,        # (U, M) beta_dn * p_dn
    interpret: bool = False,
    block_u: int = DEFAULT_BLOCKS[0],
    block_v: int = DEFAULT_BLOCKS[1],
    block_m: int = DEFAULT_BLOCKS[2],
    block_n: int = DEFAULT_BLOCKS[3],
    layout: CellLayout | None = None,
    ap_mode: str = "iota",
) -> tuple[jax.Array, jax.Array]:
    """Downlink (intra, inter) terms of eq. (8). The returned intra term is
    sum_v stronger*same * tx[v]; the caller multiplies by own-gain (the
    receiver-side factor in eq. 8), matching channel.downlink_sinr.
    Differentiable in tx via the same custom_vjp discipline as the uplink,
    with the same CellLayout contract. Unjitted for in-jit composition; see
    noma_pairwise_up."""
    block_u, block_v = _layout_blocks(layout, env, block_u, block_v)
    return _pairwise_dn(env, tx, layout, interpret, block_u, block_v,
                        block_m, block_n, ap_mode)


def noma_uplink_rates(
    env: NetworkEnv,
    beta_up: jax.Array,   # (U, M)
    p_up: jax.Array,      # (U,)
    interpret: bool = False,
    block_u: int = DEFAULT_BLOCKS[0],
    block_v: int = DEFAULT_BLOCKS[1],
    block_m: int = DEFAULT_BLOCKS[2],
    block_n: int = DEFAULT_BLOCKS[3],
    layout: CellLayout | None = None,
    ap_mode: str = "iota",
) -> jax.Array:
    """Kernel-backed replacement for repro.core.channel.uplink_rates.

    Like channel.uplink_sinr's pallas branch, the channel gains are
    detached so the env gradient is coherently zero (the kernel's
    custom_vjp already returns zero env cotangents). Unjitted for in-jit
    composition; direct eager callers use noma_uplink_rates_jit."""
    own = jax.lax.stop_gradient(env.own_gain_up()).astype(jnp.float32)
    tx = beta_up * p_up[:, None]
    intra, inter = noma_pairwise_up(env, tx, interpret=interpret,
                                    block_u=block_u, block_v=block_v,
                                    block_m=block_m, block_n=block_n,
                                    layout=layout, ap_mode=ap_mode)
    sinr = p_up[:, None] * own / (intra + inter + env.noise_up)
    bw = env.radio.bandwidth_up_hz / env.n_sub
    return beta_up * bw * jnp.log1p(sinr) / LOG2


def noma_downlink_rates(
    env: NetworkEnv,
    beta_dn: jax.Array,   # (U, M)
    p_dn: jax.Array,      # (U,)
    interpret: bool = False,
    block_u: int = DEFAULT_BLOCKS[0],
    block_v: int = DEFAULT_BLOCKS[1],
    block_m: int = DEFAULT_BLOCKS[2],
    block_n: int = DEFAULT_BLOCKS[3],
    layout: CellLayout | None = None,
    ap_mode: str = "iota",
) -> jax.Array:
    """Kernel-backed replacement for repro.core.channel.downlink_rates:
    assembles eq. (8)'s SINR from the pairwise terms (the intra term carries
    the receiver-side own-gain factor) and applies eq. (9). Channel gains
    are detached, as in noma_uplink_rates. Unjitted for in-jit composition;
    direct eager callers use noma_downlink_rates_jit."""
    own = jax.lax.stop_gradient(env.own_gain_dn()).astype(jnp.float32)
    tx = beta_dn * p_dn[:, None]
    intra, inter = noma_pairwise_dn(env, tx, interpret=interpret,
                                    block_u=block_u, block_v=block_v,
                                    block_m=block_m, block_n=block_n,
                                    layout=layout, ap_mode=ap_mode)
    sinr = p_dn[:, None] * own / (intra * own + inter + env.noise_dn)
    bw = env.radio.bandwidth_dn_hz / env.n_sub
    return beta_dn * bw * jnp.log1p(sinr) / LOG2


# Jitted entry points for direct (eager) callers -- benchmarks, notebooks,
# launch scripts. The unjitted functions above remain the composable core:
# re-entering jit from an already-jitted gd_solve/engine program was pure
# trace overhead. layout stays an operand (its tile lists are array leaves;
# the tile COUNT is pytree metadata, so a different cell population
# recompiles -- by design, the grid size is the point).
_NOMA_STATIC = ("interpret", "block_u", "block_v", "block_m", "block_n",
                "ap_mode")
noma_pairwise_up_jit = functools.partial(jax.jit, static_argnames=_NOMA_STATIC)(
    noma_pairwise_up)
noma_pairwise_dn_jit = functools.partial(jax.jit, static_argnames=_NOMA_STATIC)(
    noma_pairwise_dn)
noma_uplink_rates_jit = functools.partial(jax.jit, static_argnames=_NOMA_STATIC)(
    noma_uplink_rates)
noma_downlink_rates_jit = functools.partial(jax.jit, static_argnames=_NOMA_STATIC)(
    noma_downlink_rates)


@functools.partial(jax.jit, static_argnames=("interpret", "block_b", "block_s", "block_w"))
def rg_lru(
    log_a: jax.Array,   # (B, S, W)
    b: jax.Array,
    h0: jax.Array | None = None,
    interpret: bool = False,
    block_b: int = 8,
    block_s: int = 256,
    block_w: int = 128,
) -> jax.Array:
    bsz, s, w = log_a.shape
    bb = min(block_b, bsz)
    bs = min(block_s, s)
    bw = min(block_w, w)
    la = _pad_to(_pad_to(_pad_to(log_a, bb, 0), bs, 1), bw, 2)
    bp = _pad_to(_pad_to(_pad_to(b, bb, 0), bs, 1), bw, 2)
    h0p = None
    if h0 is not None:
        h0p = _pad_to(_pad_to(h0, bb, 0), bw, 1)
    out = rg_lru_kernel(la, bp, h0p, block_b=bb, block_s=bs, block_w=bw,
                        interpret=interpret)
    return out[:bsz, :s, :w]
