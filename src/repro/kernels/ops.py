"""jit'd public wrappers around the Pallas kernels.

Each wrapper handles padding to block multiples, GQA reshapes, and exposes
`interpret=` so the CPU container can execute the kernel bodies for
validation (the compiled Mosaic path needs real TPU hardware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.noma_rates import noma_pairwise_bwd_kernel, noma_pairwise_kernel
from repro.kernels.rg_lru import rg_lru_kernel
from repro.core.types import LOG2, NetworkEnv


def _pad_to(x, mult, axis):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,   # (B, Sq, H, hd)
    k: jax.Array,   # (B, Sk, KV, hd)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, sk))
    qp = _pad_to(qf, bq, 1)
    kp = _pad_to(kf, bk, 1)
    vp = _pad_to(vf, bk, 1)
    out = flash_attention_kernel(
        qp, kp, vp, group=g, causal=causal, window=window,
        block_q=bq, block_k=bk, kv_len=sk, interpret=interpret,
    )[:, :sq]
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


def _noma_pairwise(own, w_intra, w_power, g_raw, oh, uplink, descending,
                   interpret, block_u, block_v, block_m):
    """Run the gather-free forward kernel on the UNPADDED operands.

    The kernel masks boundary blocks in-kernel (clamped cdiv grid), so no
    _pad_to copies -- and no pad ops in the jaxpr -- on any operand; the
    receiver (U) and interferer (V) axes still tile independently
    (block_u vs block_v)."""
    return noma_pairwise_kernel(
        own, own, w_intra, w_power, g_raw, oh, oh,
        descending=descending, uplink=uplink,
        block_u=block_u, block_v=block_v, block_m=block_m,
        interpret=interpret,
    )


def _noma_pairwise_bwd(own, g_raw, oh, d_intra, d_inter, uplink, descending,
                       interpret, block_u, block_v, block_m):
    """Backward twin of _noma_pairwise: the transposed-streaming kernel on
    the same unpadded raw-gain operands; returns (V, M) weight cotangents.
    Receiver boundary blocks are masked in-kernel (the cotangents arrive
    unpadded, so garbage OOB lanes must not contribute)."""
    d_wi, d_wp = noma_pairwise_bwd_kernel(
        own, own, g_raw, oh, oh,
        d_intra.astype(jnp.float32), d_inter.astype(jnp.float32),
        descending=descending, uplink=uplink,
        block_u=block_u, block_v=block_v, block_m=block_m,
        interpret=interpret,
    )
    return d_wi, d_wp


def _zeros_cot(tree):
    """Zero cotangents matching a primal pytree: float leaves get dense
    zeros (weak types preserved via zeros_like), integer leaves get the
    float0 arrays custom_vjp requires for non-differentiable dtypes."""
    def z(x):
        if jnp.issubdtype(jax.core.get_aval(x).dtype, jnp.inexact):
            return jnp.zeros_like(x)
        return np.zeros(jnp.shape(x), jax.dtypes.float0)
    return jax.tree.map(z, tree)


def _ap_onehot(env: NetworkEnv):
    """(U, N) fp32 serving-AP one-hot: the only pairwise-structure input the
    gather-free kernels need (same_cell and the AP-indexed gain selection
    are both derived from it in-kernel)."""
    return jax.nn.one_hot(env.ap, env.n_aps, dtype=jnp.float32)


def _up_inputs(env: NetworkEnv):
    """The uplink kernel inputs derived from the environment (all constants
    of the GD path): own-AP gains, the RAW (V, N, M) uplink gains -- no
    g_up[:, ap, :] gather, the AP selection happens in-kernel -- and the
    AP one-hot."""
    own = env.own_gain_up().astype(jnp.float32)
    g_raw = env.g_up.astype(jnp.float32)
    return own, g_raw, _ap_onehot(env)


def _dn_inputs(env: NetworkEnv):
    """Downlink analogue: the RAW (N, U, M) downlink gains consumed
    receiver-major (no g_dn[ap, :, :] gather, no transpose copy)."""
    own = env.own_gain_dn().astype(jnp.float32)
    g_raw = env.g_dn.astype(jnp.float32)
    return own, g_raw, _ap_onehot(env)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _pairwise_up(env, tx, interpret, block_u, block_v, block_m):
    return _pairwise_up_fwd(env, tx, interpret, block_u, block_v, block_m)[0]


def _pairwise_up_fwd(env, tx, interpret, block_u, block_v, block_m):
    own, g_raw, oh = _up_inputs(env)
    tx = tx.astype(jnp.float32)
    out = _noma_pairwise(own, tx * own, tx, g_raw, oh, True, True,
                         interpret, block_u, block_v, block_m)
    # Residuals are exactly the kernel inputs -- no pairwise intermediates
    # are saved (g_raw aliases env.g_up, so the residual adds only the
    # O(U*M) own gains and the O(U*N) one-hot); the backward kernel
    # re-streams the same raw blocks.
    return out, (env, own, g_raw, oh)


def _pairwise_up_bwd(interpret, block_u, block_v, block_m, res, ct):
    env, own, g_raw, oh = res
    d_wi, d_wp = _noma_pairwise_bwd(own, g_raw, oh, ct[0], ct[1], True, True,
                                    interpret, block_u, block_v, block_m)
    # Forward fed the kernel w_intra = tx * own and w_power = tx; chain back
    # to the one differentiable input. env carries only GD-path constants.
    return _zeros_cot(env), d_wi * own + d_wp


_pairwise_up.defvjp(_pairwise_up_fwd, _pairwise_up_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _pairwise_dn(env, tx, interpret, block_u, block_v, block_m):
    return _pairwise_dn_fwd(env, tx, interpret, block_u, block_v, block_m)[0]


def _pairwise_dn_fwd(env, tx, interpret, block_u, block_v, block_m):
    own, g_raw, oh = _dn_inputs(env)
    tx = tx.astype(jnp.float32)
    out = _noma_pairwise(own, tx, tx, g_raw, oh, False, False,
                         interpret, block_u, block_v, block_m)
    return out, (env, own, g_raw, oh)


def _pairwise_dn_bwd(interpret, block_u, block_v, block_m, res, ct):
    env, own, g_raw, oh = res
    d_wi, d_wp = _noma_pairwise_bwd(own, g_raw, oh, ct[0], ct[1], False, False,
                                    interpret, block_u, block_v, block_m)
    # Downlink feeds tx into both weight slots (the receiver-side own-gain
    # factor of eq. 8 is applied by the caller, outside the kernel).
    return _zeros_cot(env), d_wi + d_wp


_pairwise_dn.defvjp(_pairwise_dn_fwd, _pairwise_dn_bwd)


def noma_pairwise_up(
    env: NetworkEnv,
    tx: jax.Array,        # (U, M) beta_up * p_up
    interpret: bool = False,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Uplink (intra, inter) interference terms of eq. (5) via the Pallas
    kernel: the exact denominators consumed by channel.uplink_sinr.

    Differentiable in tx (jax.custom_vjp): the backward pass is the
    transposed-streaming kernel in noma_rates.py, so the GD gradient path
    never materializes (U, V, M) in either direction.

    Deliberately NOT jitted: the hot callers (channel.uplink_sinr inside
    gd_solve / the engine's compiled programs) are already inside jit, and
    a nested jit only adds a closed-call trace layer. Direct eager callers
    should use noma_pairwise_up_jit."""
    return _pairwise_up(env, tx, interpret, block_u, block_v, block_m)


def noma_pairwise_dn(
    env: NetworkEnv,
    tx: jax.Array,        # (U, M) beta_dn * p_dn
    interpret: bool = False,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Downlink (intra, inter) terms of eq. (8). The returned intra term is
    sum_v stronger*same * tx[v]; the caller multiplies by own-gain (the
    receiver-side factor in eq. 8), matching channel.downlink_sinr.
    Differentiable in tx via the same custom_vjp discipline as the uplink.
    Unjitted for in-jit composition; see noma_pairwise_up."""
    return _pairwise_dn(env, tx, interpret, block_u, block_v, block_m)


def noma_uplink_rates(
    env: NetworkEnv,
    beta_up: jax.Array,   # (U, M)
    p_up: jax.Array,      # (U,)
    interpret: bool = False,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
) -> jax.Array:
    """Kernel-backed replacement for repro.core.channel.uplink_rates.

    Like channel.uplink_sinr's pallas branch, the channel gains are
    detached so the env gradient is coherently zero (the kernel's
    custom_vjp already returns zero env cotangents). Unjitted for in-jit
    composition; direct eager callers use noma_uplink_rates_jit."""
    own = jax.lax.stop_gradient(env.own_gain_up()).astype(jnp.float32)
    tx = beta_up * p_up[:, None]
    intra, inter = noma_pairwise_up(env, tx, interpret=interpret,
                                    block_u=block_u, block_v=block_v,
                                    block_m=block_m)
    sinr = p_up[:, None] * own / (intra + inter + env.noise_up)
    bw = env.radio.bandwidth_up_hz / env.n_sub
    return beta_up * bw * jnp.log1p(sinr) / LOG2


def noma_downlink_rates(
    env: NetworkEnv,
    beta_dn: jax.Array,   # (U, M)
    p_dn: jax.Array,      # (U,)
    interpret: bool = False,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
) -> jax.Array:
    """Kernel-backed replacement for repro.core.channel.downlink_rates:
    assembles eq. (8)'s SINR from the pairwise terms (the intra term carries
    the receiver-side own-gain factor) and applies eq. (9). Channel gains
    are detached, as in noma_uplink_rates. Unjitted for in-jit composition;
    direct eager callers use noma_downlink_rates_jit."""
    own = jax.lax.stop_gradient(env.own_gain_dn()).astype(jnp.float32)
    tx = beta_dn * p_dn[:, None]
    intra, inter = noma_pairwise_dn(env, tx, interpret=interpret,
                                    block_u=block_u, block_v=block_v,
                                    block_m=block_m)
    sinr = p_dn[:, None] * own / (intra * own + inter + env.noise_dn)
    bw = env.radio.bandwidth_dn_hz / env.n_sub
    return beta_dn * bw * jnp.log1p(sinr) / LOG2


# Jitted entry points for direct (eager) callers -- benchmarks, notebooks,
# launch scripts. The unjitted functions above remain the composable core:
# re-entering jit from an already-jitted gd_solve/engine program was pure
# trace overhead.
_NOMA_STATIC = ("interpret", "block_u", "block_v", "block_m")
noma_pairwise_up_jit = functools.partial(jax.jit, static_argnames=_NOMA_STATIC)(
    noma_pairwise_up)
noma_pairwise_dn_jit = functools.partial(jax.jit, static_argnames=_NOMA_STATIC)(
    noma_pairwise_dn)
noma_uplink_rates_jit = functools.partial(jax.jit, static_argnames=_NOMA_STATIC)(
    noma_uplink_rates)
noma_downlink_rates_jit = functools.partial(jax.jit, static_argnames=_NOMA_STATIC)(
    noma_downlink_rates)


@functools.partial(jax.jit, static_argnames=("interpret", "block_b", "block_s", "block_w"))
def rg_lru(
    log_a: jax.Array,   # (B, S, W)
    b: jax.Array,
    h0: jax.Array | None = None,
    interpret: bool = False,
    block_b: int = 8,
    block_s: int = 256,
    block_w: int = 128,
) -> jax.Array:
    bsz, s, w = log_a.shape
    bb = min(block_b, bsz)
    bs = min(block_s, s)
    bw = min(block_w, w)
    la = _pad_to(_pad_to(_pad_to(log_a, bb, 0), bs, 1), bw, 2)
    bp = _pad_to(_pad_to(_pad_to(b, bb, 0), bs, 1), bw, 2)
    h0p = None
    if h0 is not None:
        h0p = _pad_to(_pad_to(h0, bb, 0), bw, 1)
    out = rg_lru_kernel(la, bp, h0p, block_b=bb, block_s=bs, block_w=bw,
                        interpret=interpret)
    return out[:bsz, :s, :w]
