"""jit'd public wrappers around the Pallas kernels.

Each wrapper handles padding to block multiples, GQA reshapes, and exposes
`interpret=` so the CPU container can execute the kernel bodies for
validation (the compiled Mosaic path needs real TPU hardware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.noma_rates import noma_pairwise_bwd_kernel, noma_pairwise_kernel
from repro.kernels.rg_lru import rg_lru_kernel
from repro.core.types import NetworkEnv

LOG2 = 0.6931471805599453


def _pad_to(x, mult, axis):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,   # (B, Sq, H, hd)
    k: jax.Array,   # (B, Sk, KV, hd)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, sk))
    qp = _pad_to(qf, bq, 1)
    kp = _pad_to(kf, bk, 1)
    vp = _pad_to(vf, bk, 1)
    out = flash_attention_kernel(
        qp, kp, vp, group=g, causal=causal, window=window,
        block_q=bq, block_k=bk, kv_len=sk, interpret=interpret,
    )[:, :sq]
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


def _noma_pairwise_padded(own, w_intra, w_power, g_vu, same, descending,
                          interpret, block_u, block_v, block_m):
    """Pad to block multiples, run the kernel, slice back to (U, M).

    The receiver (U) and interferer (V) axes are padded *independently* to
    their own block sizes -- the kernel tiles receivers by block_u and
    streams interferers by block_v, so padding both to block_u would read out
    of bounds (or double-count clamped blocks) whenever block_v != block_u."""
    u, m = own.shape
    bm = min(block_m, m)
    own_u_p = _pad_to(_pad_to(own, block_u, 0), bm, 1)
    own_v_p = _pad_to(_pad_to(own, block_v, 0), bm, 1)
    wi_p = _pad_to(_pad_to(w_intra, block_v, 0), bm, 1)
    wp_p = _pad_to(_pad_to(w_power, block_v, 0), bm, 1)
    g_p = _pad_to(_pad_to(_pad_to(g_vu, block_v, 0), block_u, 1), bm, 2)
    same_p = _pad_to(_pad_to(same, block_u, 0), block_v, 1)
    intra, inter = noma_pairwise_kernel(
        own_u_p, own_v_p, wi_p, wp_p, g_p, same_p,
        descending=descending, block_u=block_u, block_v=block_v, block_m=bm,
        n_valid=u, interpret=interpret,
    )
    return intra[:u, :m], inter[:u, :m]


def _noma_pairwise_bwd_padded(own, g_vu, same, d_intra, d_inter, descending,
                              interpret, block_u, block_v, block_m):
    """Backward twin of _noma_pairwise_padded: pad to block multiples, run
    the transposed-streaming kernel, slice the (V, M) weight cotangents.

    The incoming cotangents are zero-padded on the receiver axis, which IS
    the padded-receiver mask (padded u rows cannot contribute to any sum
    over u); padded interferer rows fall off with the final slice."""
    u, m = own.shape
    bm = min(block_m, m)
    own_u_p = _pad_to(_pad_to(own, block_u, 0), bm, 1)
    own_v_p = _pad_to(_pad_to(own, block_v, 0), bm, 1)
    g_p = _pad_to(_pad_to(_pad_to(g_vu, block_v, 0), block_u, 1), bm, 2)
    same_vu_p = _pad_to(_pad_to(jnp.swapaxes(same, 0, 1), block_v, 0),
                        block_u, 1)
    di_p = _pad_to(_pad_to(d_intra.astype(jnp.float32), block_u, 0), bm, 1)
    dx_p = _pad_to(_pad_to(d_inter.astype(jnp.float32), block_u, 0), bm, 1)
    d_wi, d_wp = noma_pairwise_bwd_kernel(
        own_u_p, own_v_p, g_p, same_vu_p, di_p, dx_p,
        descending=descending, block_u=block_u, block_v=block_v, block_m=bm,
        interpret=interpret,
    )
    return d_wi[:u, :m], d_wp[:u, :m]


def _zeros_cot(tree):
    """Zero cotangents matching a primal pytree: float leaves get dense
    zeros (weak types preserved via zeros_like), integer leaves get the
    float0 arrays custom_vjp requires for non-differentiable dtypes."""
    def z(x):
        if jnp.issubdtype(jax.core.get_aval(x).dtype, jnp.inexact):
            return jnp.zeros_like(x)
        return np.zeros(jnp.shape(x), jax.dtypes.float0)
    return jax.tree.map(z, tree)


def _up_inputs(env: NetworkEnv):
    """The uplink kernel inputs derived from the environment (all constants
    of the GD path): own-AP gains, the interferer-major gain gather
    g_up[v, ap[u], m] -> (V, U, M), and the same-cell mask."""
    own = env.own_gain_up().astype(jnp.float32)
    g_vu = env.g_up[:, env.ap, :].astype(jnp.float32)
    same = env.same_cell().astype(jnp.float32)
    return own, g_vu, same


def _dn_inputs(env: NetworkEnv):
    """Downlink analogue: gain of interferer v's AP at user u,
    g_dn[ap[v], u, m] -> (V, U, M)."""
    own = env.own_gain_dn().astype(jnp.float32)
    g_vu = env.g_dn[env.ap, :, :].astype(jnp.float32)
    same = env.same_cell().astype(jnp.float32)
    return own, g_vu, same


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _pairwise_up(env, tx, interpret, block_u, block_v, block_m):
    return _pairwise_up_fwd(env, tx, interpret, block_u, block_v, block_m)[0]


def _pairwise_up_fwd(env, tx, interpret, block_u, block_v, block_m):
    own, g_vu, same = _up_inputs(env)
    tx = tx.astype(jnp.float32)
    out = _noma_pairwise_padded(own, tx * own, tx, g_vu, same, True,
                                interpret, block_u, block_v, block_m)
    # Residuals are exactly the kernel inputs -- no pairwise intermediates
    # are saved; the backward kernel re-streams the same blocks.
    return out, (env, own, g_vu, same)


def _pairwise_up_bwd(interpret, block_u, block_v, block_m, res, ct):
    env, own, g_vu, same = res
    d_wi, d_wp = _noma_pairwise_bwd_padded(own, g_vu, same, ct[0], ct[1],
                                           True, interpret, block_u, block_v,
                                           block_m)
    # Forward fed the kernel w_intra = tx * own and w_power = tx; chain back
    # to the one differentiable input. env carries only GD-path constants.
    return _zeros_cot(env), d_wi * own + d_wp


_pairwise_up.defvjp(_pairwise_up_fwd, _pairwise_up_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _pairwise_dn(env, tx, interpret, block_u, block_v, block_m):
    return _pairwise_dn_fwd(env, tx, interpret, block_u, block_v, block_m)[0]


def _pairwise_dn_fwd(env, tx, interpret, block_u, block_v, block_m):
    own, g_vu, same = _dn_inputs(env)
    tx = tx.astype(jnp.float32)
    out = _noma_pairwise_padded(own, tx, tx, g_vu, same, False,
                                interpret, block_u, block_v, block_m)
    return out, (env, own, g_vu, same)


def _pairwise_dn_bwd(interpret, block_u, block_v, block_m, res, ct):
    env, own, g_vu, same = res
    d_wi, d_wp = _noma_pairwise_bwd_padded(own, g_vu, same, ct[0], ct[1],
                                           False, interpret, block_u, block_v,
                                           block_m)
    # Downlink feeds tx into both weight slots (the receiver-side own-gain
    # factor of eq. 8 is applied by the caller, outside the kernel).
    return _zeros_cot(env), d_wi + d_wp


_pairwise_dn.defvjp(_pairwise_dn_fwd, _pairwise_dn_bwd)


@functools.partial(jax.jit, static_argnames=("interpret", "block_u", "block_v", "block_m"))
def noma_pairwise_up(
    env: NetworkEnv,
    tx: jax.Array,        # (U, M) beta_up * p_up
    interpret: bool = False,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Uplink (intra, inter) interference terms of eq. (5) via the Pallas
    kernel: the exact denominators consumed by channel.uplink_sinr.

    Differentiable in tx (jax.custom_vjp): the backward pass is the
    transposed-streaming kernel in noma_rates.py, so the GD gradient path
    never materializes (U, V, M) in either direction."""
    return _pairwise_up(env, tx, interpret, block_u, block_v, block_m)


@functools.partial(jax.jit, static_argnames=("interpret", "block_u", "block_v", "block_m"))
def noma_pairwise_dn(
    env: NetworkEnv,
    tx: jax.Array,        # (U, M) beta_dn * p_dn
    interpret: bool = False,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Downlink (intra, inter) terms of eq. (8). The returned intra term is
    sum_v stronger*same * tx[v]; the caller multiplies by own-gain (the
    receiver-side factor in eq. 8), matching channel.downlink_sinr.
    Differentiable in tx via the same custom_vjp discipline as the uplink."""
    return _pairwise_dn(env, tx, interpret, block_u, block_v, block_m)


@functools.partial(jax.jit, static_argnames=("interpret", "block_u", "block_v", "block_m"))
def noma_uplink_rates(
    env: NetworkEnv,
    beta_up: jax.Array,   # (U, M)
    p_up: jax.Array,      # (U,)
    interpret: bool = False,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
) -> jax.Array:
    """Kernel-backed replacement for repro.core.channel.uplink_rates.

    Like channel.uplink_sinr's pallas branch, the channel gains are
    detached so the env gradient is coherently zero (the kernel's
    custom_vjp already returns zero env cotangents)."""
    own = jax.lax.stop_gradient(env.own_gain_up()).astype(jnp.float32)
    tx = beta_up * p_up[:, None]
    intra, inter = noma_pairwise_up(env, tx, interpret=interpret,
                                    block_u=block_u, block_v=block_v,
                                    block_m=block_m)
    sinr = p_up[:, None] * own / (intra + inter + env.noise_up)
    bw = env.radio.bandwidth_up_hz / env.n_sub
    return beta_up * bw * jnp.log1p(sinr) / LOG2


@functools.partial(jax.jit, static_argnames=("interpret", "block_u", "block_v", "block_m"))
def noma_downlink_rates(
    env: NetworkEnv,
    beta_dn: jax.Array,   # (U, M)
    p_dn: jax.Array,      # (U,)
    interpret: bool = False,
    block_u: int = 8,
    block_v: int = 8,
    block_m: int = 128,
) -> jax.Array:
    """Kernel-backed replacement for repro.core.channel.downlink_rates:
    assembles eq. (8)'s SINR from the pairwise terms (the intra term carries
    the receiver-side own-gain factor) and applies eq. (9). Channel gains
    are detached, as in noma_uplink_rates."""
    own = jax.lax.stop_gradient(env.own_gain_dn()).astype(jnp.float32)
    tx = beta_dn * p_dn[:, None]
    intra, inter = noma_pairwise_dn(env, tx, interpret=interpret,
                                    block_u=block_u, block_v=block_v,
                                    block_m=block_m)
    sinr = p_dn[:, None] * own / (intra * own + inter + env.noise_dn)
    bw = env.radio.bandwidth_dn_hz / env.n_sub
    return beta_dn * bw * jnp.log1p(sinr) / LOG2


@functools.partial(jax.jit, static_argnames=("interpret", "block_b", "block_s", "block_w"))
def rg_lru(
    log_a: jax.Array,   # (B, S, W)
    b: jax.Array,
    h0: jax.Array | None = None,
    interpret: bool = False,
    block_b: int = 8,
    block_s: int = 256,
    block_w: int = 128,
) -> jax.Array:
    bsz, s, w = log_a.shape
    bb = min(block_b, bsz)
    bs = min(block_s, s)
    bw = min(block_w, w)
    la = _pad_to(_pad_to(_pad_to(log_a, bb, 0), bs, 1), bw, 2)
    bp = _pad_to(_pad_to(_pad_to(b, bb, 0), bs, 1), bw, 2)
    h0p = None
    if h0 is not None:
        h0p = _pad_to(_pad_to(h0, bb, 0), bw, 1)
    out = rg_lru_kernel(la, bp, h0p, block_b=bb, block_s=bs, block_w=bw,
                        interpret=interpret)
    return out[:bsz, :s, :w]
