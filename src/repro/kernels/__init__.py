# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from repro.kernels.cells import CellLayout, build_cell_layout  # noqa: F401
