"""Version shims for the Pallas TPU API."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams.
_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    return _PARAMS_CLS(dimension_semantics=dimension_semantics)
