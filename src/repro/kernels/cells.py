"""CellLayout: host-side AP-sort precompute for the cell-block-sparse
NOMA kernels.

The intra/SIC term only couples same-cell user pairs, so with users sorted
by serving AP the same-cell mask is block-diagonal and the tile-driven intra
kernel (kernels/noma_rates.py) only needs to visit the per-cell diagonal
tiles: pairwise cost scales as sum-of-cell-sizes^2 instead of U^2, forward
AND backward. The sort is a host-side precompute PAID ONCE PER ENV -- the
permutation of the raw (U, N, M) channel state happens eagerly here, outside
any traced gradient step, so the Li-GD hot loop never sees it. Per call,
only the cheap (U, M) decision variables cross the permutation (tx[perm] in,
out[inv] back out).

Contract for engine callers:

    layout = build_cell_layout(env, block_u=8, block_v=8)  # once per env
    rates  = channel.uplink_rates(env, beta, p, backend="pallas",
                                  layout=layout)           # every iteration

The layout must be rebuilt whenever env.ap or the gains change, and when
the kernel block sizes change (the tile lists are block-granular: they are
built from the EFFECTIVE clamped blocks min(block, U), exactly matching the
kernels' own clamping). ops.py validates both at call time. It is a
registered pytree whose array leaves (sorted env, permutations, tile lists)
flow through jit like any other operand; the tile COUNT is static metadata,
so changing cell populations enough to change the tile list retriggers
compilation -- the intended trade, since the grid size is what the
sparsity buys.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.types import Array, NetworkEnv, _register, static_field


@_register
@dataclasses.dataclass(frozen=True)
class CellLayout:
    """AP-sorted view of a NetworkEnv plus the block-diagonal tile lists.

    env      NetworkEnv with users stably sorted by serving AP (gains and
             ap permuted; radio/comp shared).
    perm     (U,) int32: sorted[i] = original[perm[i]].
    inv      (U,) int32: original[i] = sorted[inv[i]] (inverse permutation).
    tile_u/tile_v          forward intra tile list, sorted by receiver
                           block (tile_u non-decreasing) as the kernel's
                           revisit-accumulate pattern requires.
    bwd_tile_v/bwd_tile_u  the SAME tile set reordered for the backward
                           kernel's swapped roles (tile_v non-decreasing).
    """

    env: NetworkEnv
    perm: Array
    inv: Array
    tile_u: Array
    tile_v: Array
    bwd_tile_v: Array
    bwd_tile_u: Array
    n_tiles: int = static_field(default=0)
    block_u: int = static_field(default=8)
    block_v: int = static_field(default=8)

    @property
    def n_users(self) -> int:
        return self.env.n_users

    def dense_n_tiles(self) -> int:
        """Tile count the dense (no-layout) schedule would launch for this
        env at these blocks -- the U^2 baseline n_tiles is measured against
        (and the analysis.SparseGrid expectation for layout-free programs)."""
        from repro.kernels.noma_rates import dense_tile_count
        return dense_tile_count(self.n_users, self.n_users,
                                self.block_u, self.block_v)

    def max_vmem_block_bytes(self, block_m: int = 128,
                             block_n: int = 8) -> int:
        """Worst-case per-block VMEM of the kernels this layout schedules
        (its own block_u/block_v, maximized over direction x link) -- the
        number the analysis.VmemCeiling budget gates."""
        from repro.kernels.noma_rates import max_vmem_block_bytes
        return max_vmem_block_bytes(self.block_u, self.block_v, block_m,
                                    block_n, n_aps=self.env.n_aps)


def cell_tiles(ap_sorted: np.ndarray, block_u: int, block_v: int):
    """Block-diagonal tile lists for an AP-sorted id vector.

    Returns (tile_u, tile_v, bwd_tile_v, bwd_tile_u) int32 arrays: every
    (u-block, v-block) pair that contains at least one same-cell pair,
    each exactly once (adjacent cells sharing a boundary block would
    otherwise duplicate tiles -- deduped here), fwd list sorted by u-block,
    bwd list by v-block. Covers sum over cells of ceil-block products,
    ~sum-of-cell-sizes^2 work."""
    u = int(ap_sorted.shape[0])
    counts = np.bincount(ap_sorted)
    ends = np.cumsum(counts)
    starts = ends - counts
    tiles = set()
    for s, e in zip(starts, ends):
        if e <= s:
            continue  # empty cell
        ub = range(s // block_u, (e - 1) // block_u + 1)
        vb = range(s // block_v, (e - 1) // block_v + 1)
        tiles.update((i, j) for i in ub for j in vb)
    fwd = sorted(tiles)
    bwd = sorted(tiles, key=lambda t: (t[1], t[0]))
    tu = np.asarray([t[0] for t in fwd], dtype=np.int32)
    tv = np.asarray([t[1] for t in fwd], dtype=np.int32)
    bv = np.asarray([t[1] for t in bwd], dtype=np.int32)
    bu = np.asarray([t[0] for t in bwd], dtype=np.int32)
    assert u == 0 or len(fwd) >= 1
    return tu, tv, bv, bu


def build_cell_layout(env: NetworkEnv, block_u: int = 8,
                      block_v: int = 8) -> CellLayout:
    """Sort users by AP and enumerate the same-cell block tiles.

    One host sync (np.asarray of the (U,) ap vector) and one eager
    permutation of the (U, N, M) gains per call -- do this once per env,
    outside the solver loop. Block sizes are clamped to U exactly as the
    kernels clamp them, so the tile indices always address the grid the
    kernels actually launch."""
    ap = np.asarray(env.ap)
    u = ap.shape[0]
    bu, bv = min(block_u, u), min(block_v, u)
    perm = np.argsort(ap, kind="stable").astype(np.int32)
    inv = np.argsort(perm).astype(np.int32)
    ap_sorted = ap[perm]
    tu, tv, tbv, tbu = cell_tiles(ap_sorted, bu, bv)
    sorted_env = dataclasses.replace(
        env,
        g_up=jnp.asarray(env.g_up)[perm],
        g_dn=jnp.asarray(env.g_dn)[:, perm],
        ap=jnp.asarray(ap_sorted),
    )
    return CellLayout(
        env=sorted_env,
        perm=jnp.asarray(perm),
        inv=jnp.asarray(inv),
        tile_u=jnp.asarray(tu),
        tile_v=jnp.asarray(tv),
        bwd_tile_v=jnp.asarray(tbv),
        bwd_tile_u=jnp.asarray(tbu),
        n_tiles=int(tu.shape[0]),
        block_u=bu,
        block_v=bv,
    )
