"""Pallas TPU flash attention (causal / local-window / bidirectional, GQA).

TPU adaptation (see DESIGN.md Sec. 4): the kernel tiles Q into VMEM blocks of
(block_q, head_dim) and iterates KV blocks as the innermost ("arbitrary")
grid dimension, carrying the online-softmax state (m, l, acc) in fp32 VMEM
scratch across KV steps -- the classic FlashAttention-2 schedule mapped onto
the TPU's sequential grid. Matmul tiles are (block_q x hd) @ (hd x block_k),
MXU-aligned for hd in {64, 128, 256} and blocks that are multiples of 128.

Grid: (batch * kv_heads * group, n_q_blocks, n_kv_blocks).
K/V are laid out (B * KV, S, hd); the index map divides the leading grid
coordinate by `group` so G query heads share one KV head without
materializing repeated KV (GQA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, block_q: int, block_k: int,
            sm_scale: float, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (block_q, hd)
    k = k_ref[0]                       # (block_k, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * sm_scale                        # (block_q, block_k)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,            # (BH_q, Sq, hd)  where BH_q = B * KV * G
    k: jax.Array,            # (BH_kv, Sk, hd) where BH_kv = B * KV
    v: jax.Array,
    group: int,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    kv_len: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, hd = q.shape
    sk = k.shape[1]
    kv_len = sk if kv_len is None else kv_len
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)
    sm_scale = hd ** -0.5

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, sm_scale=sm_scale, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki, g=group: (b // g, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki, g=group: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
