"""Pallas TPU kernel for the RG-LRU linear recurrence (RecurrentGemma).

    h_t = exp(log_a_t) * h_{t-1} + b_t        (per channel)

TPU adaptation: the (B, S, W) problem is tiled as (batch block, width block)
parallel x (sequence block) sequential grid. The hidden state h (BB, BW)
lives in fp32 VMEM scratch and is carried across sequence blocks; inside a
block a fori_loop steps through time on VPU lanes. Width blocks of 128 match
the lane count; the sequential dependence is over S only, so all (B, W)
tiles advance in parallel -- this is the structure a GPU implementation
would express with one CUDA block per (batch, channel-tile), adapted to the
TPU's sequential grid + VMEM carry idiom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _kernel(log_a_ref, b_ref, h0_ref, out_ref, h_ref, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[...]

    def step(i, h):
        h = jnp.exp(log_a_ref[:, i, :]) * h + b_ref[:, i, :]
        out_ref[:, i, :] = h
        return h

    h_ref[...] = jax.lax.fori_loop(0, block_s, step, h_ref[...])


def rg_lru_kernel(
    log_a: jax.Array,   # (B, S, W) fp32
    b: jax.Array,       # (B, S, W) fp32
    h0: jax.Array | None = None,   # (B, W)
    block_b: int = 8,
    block_s: int = 256,
    block_w: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bsz, s, w = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    bb, bs, bw = min(block_b, bsz), min(block_s, s), min(block_w, w)
    grid = (pl.cdiv(bsz, bb), pl.cdiv(w, bw), pl.cdiv(s, bs))

    kernel = functools.partial(_kernel, block_s=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((bb, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((bb, bw), lambda bi, wi, si: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((bb, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bw), jnp.float32)],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(log_a, b, h0)
