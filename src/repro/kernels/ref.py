"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
LOG2 = 0.6931471805599453


def flash_attention_ref(q, k, v, group: int, causal=True, window=0,
                        kv_len=None):
    """q: (B*KV*G, Sq, hd); k/v: (B*KV, Sk, hd). Naive softmax attention."""
    bhq, sq, hd = q.shape
    sk = k.shape[1]
    kv_len = sk if kv_len is None else kv_len
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd**-0.5
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def noma_pairwise_ref(own_u, own_v, w_intra, w_power, g_vu, same_cell,
                      descending: bool):
    """Oracle for the NOMA pairwise-interference kernel.

    own_u: (U, M)    own-cell gain of each receiver user per subchannel
    own_v: (V, M)    own-cell gain of each interferer
    w_intra: (V, M)  intra-cell contribution of v if selected (beta*p*own_v)
    w_power: (V, M)  tx power weight of v (beta*p), for the inter-cell term
    g_vu: (V, U, M)  gain of interferer v at user u's AP
    same_cell: (U, V) bool
    descending: True -> uplink SIC (weaker own-gain interferes with me);
                False -> downlink SIC (stronger own-gain interferes)
    Returns (intra (U, M), inter (U, M)):
      intra[u,m] = sum_v same[u,v] * cmp(v,u) * w_intra[v,m]
      inter[u,m] = sum_v !same[u,v] * w_power[v,m] * g_vu[v,u,m]
    """
    if descending:
        cmp = own_v[None, :, :] < own_u[:, None, :]       # (U, V, M)
    else:
        cmp = own_v[None, :, :] > own_u[:, None, :]
    sc = same_cell[:, :, None]
    intra = jnp.sum(jnp.where(cmp & sc, w_intra[None, :, :], 0.0), axis=1)
    inter = jnp.einsum(
        "uv,vm,vum->um", (~same_cell).astype(w_power.dtype), w_power, g_vu
    )
    return intra, inter


def rg_lru_ref(log_a, b, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + b_t, via associative scan.
    log_a, b: (B, S, W) fp32."""
    a = jnp.exp(log_a)
    bb = b
    if h0 is not None:
        bb = bb.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bb), axis=1)
    return h
