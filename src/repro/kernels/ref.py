"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import LOG2  # noqa: F401  (re-export; single definition)

NEG_INF = -1e30


def flash_attention_ref(q, k, v, group: int, causal=True, window=0,
                        kv_len=None):
    """q: (B*KV*G, Sq, hd); k/v: (B*KV, Sk, hd). Naive softmax attention."""
    bhq, sq, hd = q.shape
    sk = k.shape[1]
    kv_len = sk if kv_len is None else kv_len
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd**-0.5
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def noma_pairwise_ref(own_u, own_v, w_intra, w_power, g_vu, same_cell,
                      descending: bool):
    """Oracle for the NOMA pairwise-interference kernel.

    own_u: (U, M)    own-cell gain of each receiver user per subchannel
    own_v: (V, M)    own-cell gain of each interferer
    w_intra: (V, M)  intra-cell contribution of v if selected (beta*p*own_v)
    w_power: (V, M)  tx power weight of v (beta*p), for the inter-cell term
    g_vu: (V, U, M)  gain of interferer v at user u's AP
    same_cell: (U, V) bool
    descending: True -> uplink SIC (weaker own-gain interferes with me);
                False -> downlink SIC (stronger own-gain interferes)
    Returns (intra (U, M), inter (U, M)):
      intra[u,m] = sum_v same[u,v] * cmp(v,u) * w_intra[v,m]
      inter[u,m] = sum_v !same[u,v] * w_power[v,m] * g_vu[v,u,m]
    """
    if descending:
        cmp = own_v[None, :, :] < own_u[:, None, :]       # (U, V, M)
    else:
        cmp = own_v[None, :, :] > own_u[:, None, :]
    sc = same_cell[:, :, None]
    intra = jnp.sum(jnp.where(cmp & sc, w_intra[None, :, :], 0.0), axis=1)
    inter = jnp.einsum(
        "uv,vm,vum->um", (~same_cell).astype(w_power.dtype), w_power, g_vu
    )
    return intra, inter


def noma_pairwise_gather_free_ref(own_u, own_v, w_intra, w_power, g_raw, ap,
                                  descending: bool, uplink: bool):
    """Oracle for the GATHER-FREE kernel signature (kernels/noma_rates.py).

    Same math as noma_pairwise_ref, but from the raw channel state: the
    AP-indexed gain selection and the same_cell mask are derived from the
    per-user AP assignment, mirroring the in-kernel one-hot contraction.

    g_raw: uplink (V, N, M) raw g_up; downlink (N, U, M) raw g_dn
    ap: (U,) int32 serving-AP ids (U == V: interferers are the same users)
    """
    n_aps = g_raw.shape[1] if uplink else g_raw.shape[0]
    oh = jax.nn.one_hot(ap, n_aps, dtype=w_power.dtype)       # (U, N)
    if descending:
        cmp = own_v[None, :, :] < own_u[:, None, :]           # (U, V, M)
    else:
        cmp = own_v[None, :, :] > own_u[:, None, :]
    same = jnp.einsum("un,vn->uv", oh, oh) > 0.5
    intra = jnp.sum(jnp.where(cmp & same[:, :, None], w_intra[None, :, :], 0.0),
                    axis=1)
    if uplink:
        # inter[u,m] = sum_n oh[u,n] * sum_v (1-oh[v,n]) w_power[v,m] g[v,n,m]
        per_ap = jnp.einsum("vn,vm,vnm->nm", 1.0 - oh, w_power, g_raw)
        inter = jnp.einsum("un,nm->um", oh, per_ap)
    else:
        # inter[u,m] = sum_n (1-oh[u,n]) * g[n,u,m] * sum_v oh[v,n] w_power[v,m]
        ap_tx = jnp.einsum("vn,vm->nm", oh, w_power)
        inter = jnp.einsum("un,num,nm->um", 1.0 - oh, g_raw, ap_tx)
    return intra, inter


def noma_cell_block_ref(own_u, own_v, w_intra, w_power, g_raw, ap,
                        tile_u, tile_v, block_u: int, block_v: int,
                        descending: bool, uplink: bool):
    """Oracle for the CELL-BLOCK schedule (kernels/noma_rates.py +
    kernels/cells.py): the intra/SIC term is accumulated ONLY over the
    given (tile_u, tile_v) block list -- exactly the tiles the Pallas grid
    launches -- so comparing against noma_pairwise_gather_free_ref proves
    the block-diagonal list covers every same-cell pair (and, double-count
    free, each exactly once). The inter term is the factored per-AP form,
    never pairwise. Inputs are in the SORTED user domain when the tile list
    came from a CellLayout.

    ap: (U,) int32 (U == V); tile_u/tile_v: (T,) int block indices.
    """
    import numpy as np

    u, m = own_u.shape
    v = own_v.shape[0]
    intra = jnp.zeros((u, m), jnp.float32)
    same_full = ap[:, None] == ap[None, :]
    if descending:
        cmp_full = own_v[None, :, :] < own_u[:, None, :]
    else:
        cmp_full = own_v[None, :, :] > own_u[:, None, :]
    for ub, vb in zip(np.asarray(tile_u), np.asarray(tile_v)):
        r0, r1 = ub * block_u, min((ub + 1) * block_u, u)
        s0, s1 = vb * block_v, min((vb + 1) * block_v, v)
        keep = (cmp_full[r0:r1, s0:s1, :]
                & same_full[r0:r1, s0:s1, None])
        contrib = jnp.sum(
            jnp.where(keep, w_intra[None, s0:s1, :], 0.0), axis=1)
        intra = intra.at[r0:r1].add(contrib)
    _, inter = noma_pairwise_gather_free_ref(
        own_u, own_v, w_intra, w_power, g_raw, ap,
        descending=descending, uplink=uplink)
    return intra, inter


def rg_lru_ref(log_a, b, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + b_t, via associative scan.
    log_a, b: (B, S, W) fp32."""
    a = jnp.exp(log_a)
    bb = b
    if h0 is not None:
        bb = bb.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bb), axis=1)
    return h
