import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init).
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Per cell this produces, WITHOUT allocating real tensors:
  * proof the 512-chip multi-pod sharding is coherent (compile succeeds),
  * memory_analysis(): per-device bytes (does it fit 16 GB HBM of v5e),
  * cost_analysis()-derived per-device FLOPs / bytes via two reduced-depth
    UNROLLED probe compiles + exact linear extrapolation in depth
    (XLA counts lax.scan while-bodies once -- see EXPERIMENTS.md §Dry-run),
  * the collective schedule (op kinds, shapes, replica groups, trip counts)
    parsed from the optimized HLO of the full-depth compile.

Usage:
  python -m repro.launch.dryrun --all [--multi-pod-only/--single-pod-only]
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json (resumable; --force
recompiles).
"""
import argparse
import dataclasses
import gc
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, shape_applicable
from repro.launch.hlo_analysis import parse_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.models import attention as attention_mod
from repro.models import xlstm as xlstm_mod
from repro.runtime import sharding as shlib
from repro.runtime.train import init_state, jit_train_step
from repro.runtime.serve import jit_decode_step, jit_prefill

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

# TPU v5e targets (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def scaled_cfg(cfg, depth: int):
    kw = dict(n_layers=depth)
    if cfg.encoder_layers:
        kw["encoder_layers"] = depth
    return dataclasses.replace(cfg, **kw)


def probe_depths(cfg) -> tuple[int, int]:
    if cfg.family == "vlm":
        p = cfg.cross_attn_every
    elif cfg.block_pattern:
        p = len(cfg.block_pattern)
    else:
        p = 1
    base = cfg.first_dense_layers
    return base + p, base + 2 * p


def model_flops_active(cfg, vocab_padded: int) -> tuple[float, float]:
    """(total_params, active_params_per_token) from the config."""
    m = Model(cfg)
    shapes = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    total = sum(x.size for x in jax.tree.leaves(shapes))
    inactive = 0
    if cfg.n_experts:
        moe_layers = cfg.n_layers - cfg.first_dense_layers
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        inactive = moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return float(total), float(total - inactive)


def _slstm_correction(cfg, batch: int, seq: int) -> tuple[float, float]:
    """Analytic FLOPs/bytes for the sequential sLSTM time scan (its while
    body is counted once; trips = seq). Returns (flops, bytes) PER DEVICE
    assuming batch sharded over the dp axes (conservative: /16)."""
    if "slstm" not in cfg.block_pattern or seq <= 1:
        return 0.0, 0.0
    n_slstm = sum(1 for i in range(cfg.n_layers)
                  if cfg.block_pattern[i % len(cfg.block_pattern)] == "slstm")
    h, hd = cfg.n_heads, cfg.hd
    b_local = max(1, batch // 16)
    step_flops = 2 * b_local * h * hd * 4 * hd + 24 * b_local * h * hd
    r_bytes = h * hd * 4 * hd * 4
    step_bytes = r_bytes + 14 * b_local * h * hd * 4
    return (seq - 1) * step_flops * n_slstm, (seq - 1) * step_bytes * n_slstm


def lower_cell(arch: str, shape_name: str, mesh, *, depth=None, unroll=False,
               opt=False, probe=False):
    """Build + lower + compile one cell; returns the compiled object.
    opt=True enables the beyond-paper optimizations (§Perf A-D): flat TP
    attention layout, flash-decoding cache sharding, ZeRO-1, chunked CE."""
    cfg = configs.get(arch)
    if depth is not None:
        cfg = scaled_cfg(cfg, depth)
    kind = SHAPES[shape_name]["kind"]
    seq, batch = SHAPES[shape_name]["seq"], SHAPES[shape_name]["batch"]
    tp = mesh.shape.get("model", 1) if opt else None
    model = Model(cfg, remat=True, unroll=unroll, tp_size=tp)
    specs = model.input_specs(shape_name)

    attention_mod.UNROLL_SCANS = unroll
    xlstm_mod.UNROLL_SCANS = unroll
    try:
        with mesh:
            if kind == "train":
                # probes: n_microbatches=1 -- identical per-step math,
                # but the microbatch scan body would otherwise be counted
                # once by cost_analysis. FSDP intentionally NOT in the opt
                # set: GSPMD gathers the full stacked scan weights per layer
                # step (x L x microbatches collective blowup; see §Perf E).
                # Microbatching is adaptive (§Perf F2): only archs whose
                # activations don't fit take the grad-accumulation loop.
                micro = 8 if (opt and cfg.d_model >= 2048) else 1
                make, _ = jit_train_step(model, mesh,
                                         n_microbatches=1 if (probe or not opt)
                                         else micro,
                                         zero1=opt, fsdp=False,
                                         seq_chunk=512 if opt else 0)
                state_shapes = jax.eval_shape(
                    lambda: init_state(model, jax.random.PRNGKey(0)))
                jitted = make(specs)
                lowered = jitted.lower(state_shapes, specs)
            elif kind == "prefill":
                jfn, p_shard = jit_prefill(model, mesh, max_len=seq)
                params_shapes = jax.eval_shape(
                    lambda: model.init(jax.random.PRNGKey(0)))
                lowered = jfn.lower(params_shapes, specs)
            else:  # decode
                step, p_shard, c_shard = jit_decode_step(
                    model, mesh, batch=batch, max_len=seq)
                params_shapes = jax.eval_shape(
                    lambda: model.init(jax.random.PRNGKey(0)))
                lowered = step.lower(params_shapes, specs["caches"],
                                     specs["token"])
            compiled = lowered.compile()
    finally:
        attention_mod.UNROLL_SCANS = False
        xlstm_mod.UNROLL_SCANS = False
    return compiled


def analyze_cell(arch: str, shape_name: str, multi_pod: bool,
                 with_probes: bool = True, opt: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get(arch)
    seq, batch = SHAPES[shape_name]["seq"], SHAPES[shape_name]["batch"]
    kind = SHAPES[shape_name]["kind"]

    out: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": kind, "seq": seq, "batch": batch,
                 "n_devices": mesh.size, "optimized": opt}

    compiled = lower_cell(arch, shape_name, mesh, opt=opt)
    ma = compiled.memory_analysis()
    out["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes_est": ma.argument_size_in_bytes + ma.temp_size_in_bytes
        + ma.output_size_in_bytes - ma.alias_size_in_bytes,
    }
    ca = compiled.cost_analysis()
    out["cost_raw"] = {"flops_body_once": ca.get("flops", 0.0),
                       "bytes_body_once": ca.get("bytes accessed", 0.0)}
    hlo = parse_hlo(compiled.as_text())
    out["hlo_full"] = hlo
    del compiled
    gc.collect()

    if with_probes:
        k1, k2 = probe_depths(cfg)
        probes = {}
        for k in (k1, k2):
            c = lower_cell(arch, shape_name, mesh, depth=k, unroll=True,
                           opt=opt, probe=True)
            pca = c.cost_analysis()
            ph = parse_hlo(c.as_text())
            probes[k] = {
                "flops": pca.get("flops", 0.0),
                "bytes": pca.get("bytes accessed", 0.0),
                "coll_ring": ph["collective_bytes_ring"],
                "coll_spec": ph["collective_bytes_spec"],
            }
            del c
            gc.collect()
        n_full = cfg.n_layers
        scale = (n_full - k1) / (k2 - k1)

        def extrap(key):
            return probes[k1][key] + (probes[k2][key] - probes[k1][key]) * scale

        fl = extrap("flops")
        by = extrap("bytes")
        cf, cb = (0.0, 0.0)
        if cfg.name == "xlstm-125m" and kind != "decode":
            eff_seq = seq if kind != "train" else seq
            cf, cb = _slstm_correction(cfg, batch, eff_seq)
        out["probe"] = {
            "k1": k1, "k2": k2, "points": probes,
            "flops_per_device": fl + cf,
            "bytes_per_device": by + cb,
            # collectives from the FULL compile's trip-aware HLO parse
            # (captures the microbatch loop); probe extrapolation kept for
            # cross-checking.
            "coll_ring_per_device": hlo["collective_bytes_ring"],
            "coll_spec_per_device": hlo["collective_bytes_spec"],
            "coll_ring_probe_extrap": extrap("coll_ring"),
            "slstm_correction": {"flops": cf, "bytes": cb},
        }

    total_p, active_p = model_flops_active(cfg, Model(cfg).vocab_padded)
    tokens = batch * (1 if kind == "decode" else
                      (seq // 4 if cfg.family == "audio" and kind == "train"
                       else seq))
    mult = 6.0 if kind == "train" else 2.0
    out["model_flops_global"] = mult * active_p * tokens
    out["params_total"] = total_p
    out["params_active"] = active_p
    out["elapsed_s"] = round(time.time() - t0, 1)
    return out


def roofline_terms(cell: dict) -> dict:
    p = cell.get("probe")
    if not p:
        return {}
    compute_t = p["flops_per_device"] / PEAK_FLOPS
    memory_t = p["bytes_per_device"] / HBM_BW
    coll_t = p["coll_ring_per_device"] / ICI_BW
    dom = max(("compute", compute_t), ("memory", memory_t),
              ("collective", coll_t), key=lambda x: x[1])[0]
    flops_global = p["flops_per_device"] * cell["n_devices"]
    return {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dom,
        "model_vs_hlo_flops": cell["model_flops_global"] / max(flops_global, 1.0),
        "bound_s": max(compute_t, memory_t, coll_t),
    }


def run_cell(arch, shape, multi_pod, force=False, opt=False):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    art_dir = ART_DIR + ("_opt" if opt else "")
    os.makedirs(art_dir, exist_ok=True)
    path = os.path.join(art_dir, f"{arch}__{shape}__{mesh_name}.json")
    if os.path.exists(path) and not force:
        print(f"[skip] {path} exists")
        return True
    ok, why = shape_applicable(configs.get(arch), shape)
    if not ok:
        json.dump({"arch": arch, "shape": shape, "mesh": mesh_name,
                   "skipped": why}, open(path, "w"), indent=1)
        print(f"[SKIP] {arch} {shape} {mesh_name}: {why}")
        return True
    try:
        cell = analyze_cell(arch, shape, multi_pod,
                            with_probes=not multi_pod, opt=opt)
        if not multi_pod:
            cell["roofline"] = roofline_terms(cell)
        json.dump(cell, open(path, "w"), indent=1)
        mem = cell["memory"]["peak_bytes_est"] / 2**30
        print(f"[OK] {arch} {shape} {mesh_name}: peak {mem:.2f} GiB/dev, "
              f"{cell['elapsed_s']}s"
              + (f", dominant={cell['roofline']['dominant']}"
                 if not multi_pod else ""))
        return True
    except Exception as e:
        traceback.print_exc()
        json.dump({"arch": arch, "shape": shape, "mesh": mesh_name,
                   "error": f"{type(e).__name__}: {e}"},
                  open(path + ".err", "w"), indent=1)
        print(f"[FAIL] {arch} {shape} {mesh_name}: {type(e).__name__}: {e}")
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimizations (artifacts to dryrun_opt)")
    args = ap.parse_args()

    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    archs = [args.arch] if args.arch else configs.all_names()
    shapes = [args.shape] if args.shape else list(SHAPES)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if not run_cell(arch, shape, mp, force=args.force,
                                opt=args.opt):
                    n_fail += 1
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
