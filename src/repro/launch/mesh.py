"""Production mesh construction. A FUNCTION, not a module constant, so
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def _make(shape: tuple, axes: tuple):
    # jax >= 0.5 grew sharding.AxisType; older releases only take (shape, axes).
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Elastic variant: any (shape, axes); used by tests and small runs."""
    return _make(shape, axes)
