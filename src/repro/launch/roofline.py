"""Roofline report generator: reads artifacts/dryrun/*.json, emits the
per-(arch x shape) three-term table as markdown (for EXPERIMENTS.md).

  compute_s    = HLO_FLOPs_per_device / 197 TFLOP/s   (bf16, v5e)
  memory_s     = HLO_bytes_per_device / 819 GB/s
  collective_s = ring collective bytes_per_device / 50 GB/s ICI
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def load_cells(mesh="pod16x16", suffix=""):
    cells = []
    d = ART_DIR + suffix
    for p in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        cells.append(json.load(open(p)))
    return cells


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}µs"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def table(cells, only_dominant=None):
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "MODEL/HLO | peak GiB/dev |")
    sep = "|" + "---|" * 8
    rows = [hdr, sep]
    for c in cells:
        if c.get("skipped"):
            rows.append(
                f"| {c['arch']} | {c['shape']} | — | — | — | "
                f"{c['skipped']} | — | — |")
            continue
        r = c.get("roofline", {})
        if not r:
            continue
        if only_dominant and r["dominant"] != only_dominant:
            continue
        mem = c["memory"]["peak_bytes_est"] / 2**30
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['model_vs_hlo_flops']:.2f} | "
            f"{mem:.2f} |")
    return "\n".join(rows)


def summary(cells):
    live = [c for c in cells if c.get("roofline")]
    worst = sorted(live, key=lambda c: -c["roofline"]["bound_s"])
    coll = sorted(live, key=lambda c: -c["roofline"]["collective_s"])
    lines = ["", "Worst bound cells:"]
    for c in worst[:5]:
        r = c["roofline"]
        lines.append(f"  {c['arch']} {c['shape']}: bound {fmt_s(r['bound_s'])}"
                     f" ({r['dominant']})")
    lines.append("Most collective-bound cells:")
    for c in coll[:5]:
        r = c["roofline"]
        lines.append(f"  {c['arch']} {c['shape']}: coll {fmt_s(r['collective_s'])}"
                     f" vs bound {fmt_s(r['bound_s'])}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--suffix", default="_opt",
                    help="artifact dir suffix: _opt | _baseline | ''")
    args = ap.parse_args()
    cells = load_cells(args.mesh, args.suffix)
    print(table(cells))
    print(summary(cells))


if __name__ == "__main__":
    main()
