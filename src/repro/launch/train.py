"""Fault-tolerant training driver.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --steps 50 --batch 8 --seq 128 --mesh 1x1 --ckpt-dir /tmp/ckpt

On a real cluster: --mesh 16x16 (or 2x16x16 with pod axis) under one
process per host; the data pipeline shards by process index and the
checkpoint manager writes per-host shards.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import Model
from repro.runtime import ft
from repro.runtime.train import TrainState, init_state, jit_train_step


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return make_mesh(dims, axes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = parse_mesh(args.mesh)
    model = Model(cfg, remat=True, moe_capacity=2.0)

    make, state_shard = jit_train_step(model, mesh, args.microbatches)
    frontend_shape = None
    if cfg.family in ("audio", "vlm"):
        ft_tokens = cfg.frontend_tokens if cfg.family == "vlm" else args.seq
        frontend_shape = (ft_tokens, cfg.d_model)
    data = SyntheticLM(args.seed, args.batch, args.seq, cfg.vocab_size,
                       frontend_shape)
    batch0 = next(data)
    step_fn = make(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0))

    mgr = CheckpointManager(args.ckpt_dir)
    with mesh:
        state = init_state(model, jax.random.PRNGKey(args.seed))
        start = mgr.latest_step()
        if start is not None:
            state, start = mgr.restore(state)
            print(f"[resume] from step {start}")
        else:
            start = 0

    data.close()
    data = SyntheticLM(args.seed, args.batch, args.seq, cfg.vocab_size,
                       frontend_shape, start_step=start)
    holder = {"state": state}

    def step_once(i):
        batch = next(data)
        with mesh:
            holder["state"], metrics = step_fn(holder["state"], batch)
        s = start + i
        if s % args.log_every == 0 or i == 0:
            m = jax.device_get(metrics)
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e}",
                  flush=True)
        if s and s % args.ckpt_every == 0:
            mgr.save_async(s, holder["state"])

    def restore_fn():
        mgr.wait()
        st = mgr.latest_step() or 0
        if mgr.latest_step() is not None:
            holder["state"], st = mgr.restore(holder["state"])
        return max(0, st - start)

    t0 = time.time()
    done, retries, stragglers = ft.run_with_retries(
        step_once, args.steps, restore_fn, step_timeout_s=1800.0,
        on_straggler=lambda i, dt: print(f"[straggler] step {i} took {dt:.2f}s"),
    )
    mgr.save_async(start + done, holder["state"])
    mgr.wait()
    dt = time.time() - t0
    print(f"trained {done} steps in {dt:.1f}s "
          f"({args.batch * args.seq * done / dt:.0f} tok/s); "
          f"retries={retries} straggler_steps={stragglers}")


if __name__ == "__main__":
    main()
