"""Post-SPMD HLO text analysis: collective inventory with while-loop
trip-count attribution.

XLA's cost_analysis() counts while (lax.scan) bodies ONCE, so both FLOPs and
collective volumes need trip multiplication. We parse the optimized HLO:
computations, while ops (body/condition edges), trip counts (the loop-bound
constant in the condition), and every collective's result shape + replica
group size. Comm volume per device uses ring formulas:

  all-reduce        2 (g-1)/g * bytes
  all-gather          (g-1)/g * bytes        (bytes = full gathered output)
  reduce-scatter      (g-1)   * bytes_out    (input = g * output)
  all-to-all          (g-1)/g * bytes
  collective-permute  bytes

`bytes_spec` additionally records the plain sum-of-result-bytes (the
assignment's "sum operand sizes" definition).
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, num_partitions: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return max(1, int(m.group(2)))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return num_partitions


def _ring_bytes(kind: str, bytes_res: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * bytes_res
    if kind == "all-gather":
        return (g - 1) / g * bytes_res
    if kind == "reduce-scatter":
        return float((g - 1) * bytes_res)
    if kind == "all-to-all":
        return (g - 1) / g * bytes_res
    return float(bytes_res)  # collective-permute


def parse_hlo(text: str) -> dict:
    num_partitions = 1
    m = re.search(r"num_partitions=(\d+)", text)
    if m:
        num_partitions = int(m.group(1))

    # ---- split into computations
    comps: dict[str, list[str]] = {}
    current = None
    entry = None
    for line in text.splitlines():
        mm = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
        if mm and not line.startswith(" "):
            current = mm.group(2)
            comps[current] = []
            if mm.group(1):
                entry = current
            continue
        if current is not None:
            comps[current].append(line)

    # ---- collectives per computation
    coll: dict[str, list[tuple[str, int, float, int]]] = defaultdict(list)
    # ---- while edges per computation: (body, cond)
    whiles: dict[str, list[tuple[str, str]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            for kind in COLL_KINDS:
                if re.search(rf"\b{kind}(-start)?\(", line):
                    seg = line.split("=", 1)
                    res_seg = seg[1].split(kind)[0] if len(seg) > 1 else line
                    b = _shape_bytes(res_seg)
                    # all-reduce results may be tuples: bytes counted once
                    g = _group_size(line, num_partitions)
                    coll[name].append((kind, b, _ring_bytes(kind, b, g), g))
                    break
            wm = re.search(r"\bwhile\(", line)
            if wm:
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                if cm and bm:
                    whiles[name].append((bm.group(1), cm.group(1)))
            # other computation references (fusion calls) intentionally not
            # traversed: reductions/fusions hold no collectives in XLA HLO.

    # ---- trip counts from condition computations
    def trip_count(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            for c in re.findall(r"constant\((\d+)\)", line):
                best = max(best, int(c))
        return best

    # ---- multiplicity propagation from entry
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        entry = next(iter(comps))
    mult[entry] = 1.0
    frontier = [entry]
    seen = set()
    while frontier:
        c = frontier.pop()
        if c in seen:
            continue
        seen.add(c)
        for body, cond in whiles.get(c, []):
            mult[body] += mult[c] * trip_count(cond)
            frontier.append(body)

    # ---- totals
    per_kind_bytes = defaultdict(float)
    per_kind_ring = defaultdict(float)
    per_kind_count = defaultdict(float)
    schedule = []
    for name, ops in coll.items():
        f = mult.get(name, 1.0 if name == entry else 0.0)
        if f == 0.0 and name != entry:
            # computation not reached via while edges: treat as entry-level
            f = 1.0 if name == entry else mult.get(name, 0.0)
        for kind, b, ring, g in ops:
            per_kind_bytes[kind] += f * b
            per_kind_ring[kind] += f * ring
            per_kind_count[kind] += f
            schedule.append({"kind": kind, "bytes": b, "group": g,
                             "mult": f, "comp": name})

    return {
        "num_partitions": num_partitions,
        "collective_bytes_spec": float(sum(per_kind_bytes.values())),
        "collective_bytes_ring": float(sum(per_kind_ring.values())),
        "per_kind_bytes": dict(per_kind_bytes),
        "per_kind_count": dict(per_kind_count),
        "schedule": sorted(schedule, key=lambda s: -s["bytes"] * s["mult"])[:20],
        "n_whiles": int(sum(len(v) for v in whiles.values())),
    }
