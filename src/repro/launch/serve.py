"""Serving driver with ECC split inference.

The ECC planner (the paper's contribution) picks the split layer s* and the
radio resource allocation for a fleet of devices sharing a NOMA cell; the
runtime then builds the device-side and edge-side programs and serves
batched requests, reporting per-phase times including the simulated NOMA
uplink.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 4 --seq 64 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import GdConfig, make_env, make_weights, profiles
from repro.data import make_batch
from repro.models import Model
from repro.planning import PlannerEngine
from repro.runtime.serve import make_split_serve, transfer_seconds
from repro.core import channel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--users", type=int, default=12)
    ap.add_argument("--aps", type=int, default=3)
    ap.add_argument("--subchannels", type=int, default=4)
    ap.add_argument("--w-delay", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    # 1. ECC planning over the arch's per-block profile. The PlannerEngine
    # owns the compiled solver; a serving deployment keeps it around and
    # replan()s the returned state as the channel evolves.
    env = make_env(jax.random.PRNGKey(args.seed), args.users, args.aps,
                   args.subchannels)
    prof = profiles.from_arch_config(cfg, seq=args.seq)
    w = make_weights(env.n_users, args.w_delay)
    engine = PlannerEngine(prof, weights=w, cfg=GdConfig(max_iters=150))
    plan = engine.plan(env).plan
    s = int(plan.s)
    r_up, _ = channel.user_rates(
        env,
        jax.nn.one_hot(plan.sub_up, env.n_sub),
        jax.nn.one_hot(plan.sub_dn, env.n_sub),
        plan.p_up, plan.p_dn,
    )
    rate0 = float(r_up[0])
    print(f"[plan] split layer s*={s}/{cfg.n_layers}, "
          f"uplink rate {rate0 / 1e6:.2f} Mb/s, "
          f"utility {float(plan.utility):.4f}")

    # 2. build device/edge programs
    model = Model(cfg, remat=False, moe_capacity=4.0)
    params = model.init(jax.random.PRNGKey(1))
    progs = make_split_serve(model, params, s)

    # 3. serve batched requests
    batch = make_batch(args.seed, 0, args.requests, args.seq, cfg.vocab_size)
    tokens = batch["tokens"]
    t0 = time.time()
    act = progs.device_fn(tokens)
    t_dev = time.time() - t0
    t_link = transfer_seconds(tokens.size, cfg.d_model, rate0)
    t0 = time.time()
    logits = progs.edge_fn(act)
    t_edge = time.time() - t0
    nxt = jnp.argmax(logits[:, -1], -1)
    print(f"[serve] {args.requests} reqs x {args.seq} tok: device {t_dev:.3f}s"
          f" + NOMA uplink {t_link:.3f}s (simulated) + edge {t_edge:.3f}s")
    print(f"[serve] first new tokens: {jax.device_get(nxt)[:8]}")

    # greedy continuation (device-side embedding, edge-side rest — each new
    # token repeats the split path)
    seq = tokens
    for i in range(args.new_tokens - 1):
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
        act = progs.device_fn(seq)
        logits = progs.edge_fn(act)
        nxt = jnp.argmax(logits[:, -1], -1)
    print(f"[serve] generated {args.new_tokens} tokens/request; done")


if __name__ == "__main__":
    main()
