"""QoS monitor: latency percentiles + deadline misses -> replan trigger.

Tracks completed-request latencies in a fixed-size ring (device-resident,
donated in place) and maintains per-user deadline-miss EMAs. Every epoch it
produces p50/p95 over the window and a *device boolean* trigger that fires
when either percentile or the miss rate crosses its threshold; the closed
loop reads that one scalar per epoch (mirroring the single s*-sync in
OnlineSplitServer.observe) and, when set, forces a planner replan with the
current measured profile. Hysteresis (``cooldown_epochs``) keeps a noisy
boundary from re-triggering every epoch.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import Array

from repro.online.batcher import Completions


@dataclasses.dataclass(frozen=True)
class QosConfig:
    """Thresholds are in seconds (percentiles) / fraction (miss rate).
    ``window`` is the latency-ring depth; ``miss_decay`` the per-completion
    EMA factor for per-user deadline misses."""

    deadline_s: float = 0.5
    p95_max_s: float = 0.5
    p50_max_s: float = 0.25
    miss_rate_max: float = 0.05
    window: int = 256
    miss_decay: float = 0.9
    cooldown_epochs: int = 10
    # Harden the monitor against corrupt latencies (fault injection): a
    # non-finite latency counts as a deadline miss and enters the ring as a
    # breaching-but-finite sentinel. Without this, one NaN latency poisons
    # the percentile ring -- every comparison against it is False and the
    # QoS trigger goes silently blind, which is exactly the no-ladder
    # failure mode benchmarks/chaos_serve.py records.
    guard_nonfinite: bool = False


class QosState(NamedTuple):
    lat: Array        # (W,) latency ring
    valid: Array      # (W,) bool: ring entry holds a real completion
    head: Array       # () int32 next write position
    miss: Array       # (U,) per-user deadline-miss EMA
    served: Array     # () int32 completions seen
    missed: Array     # () int32 deadline misses seen
    good: Array       # () int32 finite, in-deadline completions (goodput)
    cooldown: Array   # () int32 epochs until the trigger can re-fire
    triggers: Array   # () int32 times the trigger fired


class QosReport(NamedTuple):
    """Per-epoch snapshot, all device scalars. ``trigger`` is the one value
    the loop syncs to host."""

    p50: Array
    p95: Array
    miss_rate: Array
    trigger: Array    # () bool


def qos_update(cfg: QosConfig, state: QosState,
               comp: Completions) -> tuple[QosState, QosReport]:
    """Pure one-epoch update (composable inside a larger jitted program)."""
    w = state.lat.shape[0]

    finite = jnp.isfinite(comp.latency)
    good = state.good + jnp.sum(
        comp.valid & finite & (comp.latency <= cfg.deadline_s)
    ).astype(jnp.int32)
    if cfg.guard_nonfinite:
        # Corrupt latencies become a finite sentinel that is guaranteed to
        # breach (and a miss, below): the monitor reacts instead of going
        # blind on NaN comparisons.
        sentinel = jnp.float32(2.0 * max(cfg.p95_max_s, cfg.deadline_s))
        comp = comp._replace(latency=jnp.where(finite, comp.latency,
                                               sentinel))

    # Ring-write this epoch's completions (at most B of them).
    def push(carry, x):
        lat, valid, head = carry
        is_valid, latency = x
        lat = jnp.where(is_valid, lat.at[head % w].set(latency), lat)
        valid = jnp.where(is_valid, valid.at[head % w].set(True), valid)
        head = head + is_valid.astype(jnp.int32)
        return (lat, valid, head), None

    (lat, valid, head), _ = jax.lax.scan(
        push, (state.lat, state.valid, state.head),
        (comp.valid, comp.latency))

    # Per-user deadline-miss EMA, one step per completing user.
    late = comp.valid & (comp.latency > cfg.deadline_s)

    def fold_miss(miss, x):
        is_valid, uid, is_late = x
        old = miss[uid]
        new = cfg.miss_decay * old + (1.0 - cfg.miss_decay) * (
            is_late.astype(jnp.float32))
        return jnp.where(is_valid, miss.at[uid].set(new), miss), None

    miss, _ = jax.lax.scan(fold_miss, state.miss,
                           (comp.valid, jnp.maximum(comp.user, 0), late))

    served = state.served + jnp.sum(comp.valid).astype(jnp.int32)
    missed = state.missed + jnp.sum(late).astype(jnp.int32)

    # Percentiles over valid ring entries only: invalid slots are pushed to
    # +inf and the percentile rank is rescaled to the valid count
    # (jnp.percentile has no mask argument).
    n_valid = jnp.sum(valid)
    filled = jnp.where(valid, lat, jnp.inf)
    ranked = jnp.sort(filled)
    frac = jnp.maximum(n_valid - 1, 0).astype(jnp.float32)
    idx50 = jnp.round(0.50 * frac).astype(jnp.int32)
    idx95 = jnp.round(0.95 * frac).astype(jnp.int32)
    any_valid = n_valid > 0
    p50 = jnp.where(any_valid, ranked[idx50], 0.0)
    p95 = jnp.where(any_valid, ranked[idx95], 0.0)
    miss_rate = jnp.where(
        served > 0, missed.astype(jnp.float32) / jnp.maximum(served, 1), 0.0)

    breach = any_valid & (
        (p95 > cfg.p95_max_s) | (p50 > cfg.p50_max_s)
        | (miss_rate > cfg.miss_rate_max))
    armed = state.cooldown <= 0
    trigger = breach & armed
    cooldown = jnp.where(trigger, jnp.int32(cfg.cooldown_epochs),
                         jnp.maximum(state.cooldown - 1, 0))

    new = QosState(lat=lat, valid=valid, head=head, miss=miss, served=served,
                   missed=missed, good=good, cooldown=cooldown,
                   triggers=state.triggers + trigger.astype(jnp.int32))
    return new, QosReport(p50=p50, p95=p95, miss_rate=miss_rate,
                          trigger=trigger)


class QosMonitor:
    def __init__(self, cfg: QosConfig, n_users: int):
        if cfg.window < 2:
            raise ValueError(f"window must be >= 2, got {cfg.window}")
        self.cfg = cfg
        self.n_users = int(n_users)

    def init(self) -> QosState:
        w = self.cfg.window
        return QosState(
            lat=jnp.zeros((w,), jnp.float32),
            valid=jnp.zeros((w,), bool),
            head=jnp.int32(0),
            miss=jnp.zeros((self.n_users,), jnp.float32),
            served=jnp.int32(0),
            missed=jnp.int32(0),
            good=jnp.int32(0),
            cooldown=jnp.int32(0),
            triggers=jnp.int32(0),
        )

    @functools.cached_property
    def _update(self):
        return jax.jit(functools.partial(qos_update, self.cfg),
                       donate_argnums=(0,))

    def update(self, state: QosState,
               comp: Completions) -> tuple[QosState, QosReport]:
        """Fold one epoch's completions in; donates ``state`` in place."""
        return self._update(state, comp)
