"""Request streams: per-user Poisson arrivals driving the closed loop.

A RequestStream turns a time-evolving scenario population into per-epoch
split-inference request traffic. Each user slot carries an independent
Poisson arrival process (rate ``arrival_rate_hz`` while its *session* is
active); sessions themselves churn with the same slot-replacement semantics
as ``repro.scenarios.churn`` (a replaced slot is a user leaving and a new
one joining mid-session), so offered load breathes the way a live cell's
does while every array keeps its static (U,)/(U, K) shape.

Everything is a compiled program over device-resident state: ``step``
returns the per-user arrival counts for the epoch as device arrays, and the
PRNG is deterministic per epoch (``jax.random.fold_in(base_key, epoch)``),
so any epoch's traffic can be replayed without replaying the stream.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Array
from repro.scenarios import churn


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Traffic knobs. ``arrival_rate_hz`` is per *active* user; a request's
    service demand is ``tokens_per_request`` edge decode steps; its deadline
    is ``deadline_s`` after arrival. ``session_churn_hz`` replaces user
    sessions wholesale (scenarios.churn slot-replacement semantics);
    ``duty_cycle`` is the long-run fraction of sessions that are active.
    ``max_per_user_epoch`` caps one slot's arrivals per epoch so downstream
    queues can size statically."""

    arrival_rate_hz: float = 4.0
    epoch_dt_s: float = 0.1
    tokens_per_request: int = 8
    deadline_s: float = 0.5
    session_churn_hz: float = 0.0
    duty_cycle: float = 1.0
    max_per_user_epoch: int = 4


class StreamState(NamedTuple):
    session: Array   # (U,) bool: slot currently running an active session
    epoch: Array     # () int32
    offered: Array   # () int32 total requests offered so far


def stream_step(cfg: StreamConfig, n_users: int, base_key: jax.Array,
                state: StreamState) -> tuple[StreamState, Array]:
    """Pure one-epoch step (composable inside a larger jitted program).
    Deterministic per-epoch stream: the epoch index, not a carried key,
    drives the draw -- epoch t's traffic is replayable from (base_key, t)
    alone."""
    u = n_users
    key = jax.random.fold_in(base_key, state.epoch)
    k_arr, k_churn, k_fresh = jax.random.split(key, 3)
    session = state.session
    if cfg.session_churn_hz > 0.0:
        replaced = churn.replacement_mask(
            k_churn, u, cfg.session_churn_hz, cfg.epoch_dt_s)
        fresh = jax.random.bernoulli(k_fresh, cfg.duty_cycle, (u,))
        session = jnp.where(replaced, fresh, session)
    lam = cfg.arrival_rate_hz * cfg.epoch_dt_s
    counts = jax.random.poisson(k_arr, lam, (u,), dtype=jnp.int32)
    counts = jnp.minimum(counts, cfg.max_per_user_epoch)
    counts = jnp.where(session, counts, 0)
    new = StreamState(session=session, epoch=state.epoch + 1,
                      offered=state.offered + jnp.sum(counts))
    return new, counts


class RequestStream:
    """Deterministic per-user Poisson request generator for U user slots."""

    def __init__(self, cfg: StreamConfig, n_users: int):
        if cfg.max_per_user_epoch < 1:
            raise ValueError(
                f"max_per_user_epoch must be >= 1, got {cfg.max_per_user_epoch}")
        if not 0.0 < cfg.duty_cycle <= 1.0:
            raise ValueError(f"duty_cycle must be in (0, 1], got {cfg.duty_cycle}")
        self.cfg = cfg
        self.n_users = int(n_users)

    def init(self, key: jax.Array) -> StreamState:
        active = jax.random.bernoulli(key, self.cfg.duty_cycle,
                                      (self.n_users,))
        return StreamState(session=active, epoch=jnp.int32(0),
                           offered=jnp.int32(0))

    @functools.cached_property
    def _step(self):
        return jax.jit(
            functools.partial(stream_step, self.cfg, self.n_users))

    def step(self, base_key: jax.Array,
             state: StreamState) -> tuple[StreamState, Array]:
        """Advance one epoch: (new state, per-user arrival counts (U,))."""
        return self._step(base_key, state)
