"""Continuous batching on the edge decode path.

The edge serves a fixed-capacity batch of B request *slots*: arrivals are
enqueued into a bounded FIFO ring, free slots are refilled from the queue
head every epoch (admit), and one batched edge step serves every active
slot at once (tick) -- edge throughput scales with concurrency instead of
serializing per request, exactly the stream_router/task_dispatcher shape of
the related sparse_framework serving stack. Everything is a compiled
program over a single BatchState pytree (donated across epochs by the
loop); slot admission, eviction, and completion accounting are where/scan
ops, so the hot path never syncs to host.

Two consumers:

* The planning-only closed loop (repro.online.loop, benchmarks) drives the
  queueing core alone: per-request service time comes from the measured
  delay model and occupancy converts to slot epochs.
* Real split-serving reuses the decode-step cache machinery from
  runtime/serve.py: ``DecodeBatcher`` keeps one capacity-sized KV/state
  cache (model.make_caches) alive across requests, writes a per-request
  prefill into its slot at admission (slot_update), and advances every
  active slot with one masked decode step per epoch (inactive slots'
  caches are frozen via slot_where and overwritten at their next
  admission). ``EdgeBatcher`` is the single-shot analogue over stacked
  split activations for the paper's CNN-style one-pass inference.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Array


class BatchState(NamedTuple):
    """Slots + FIFO ring + counters; all device arrays, static shapes."""

    # slots (capacity B)
    active: Array    # (B,) bool
    user: Array      # (B,) int32, -1 when free
    t_arr: Array     # (B,) f32 arrival time (s) of the occupying request
    wait: Array      # (B,) f32 queue wait (s) accrued before admission
    serv: Array      # (B,) f32 modeled service seconds of the request
    work: Array      # (B,) int32 remaining edge steps
    # FIFO ring (depth Q)
    q_user: Array    # (Q,) int32
    q_t: Array       # (Q,) f32 arrival times
    q_head: Array    # () int32
    q_size: Array    # () int32
    # counters
    dropped: Array   # () int32 arrivals rejected on a full ring
    completed: Array  # () int32 requests fully served
    shed: Array      # () int32 requests shed by the degradation ladder
                     # (admission control under faults; 0 when disabled)


class Completions(NamedTuple):
    """Per-epoch completion record, fixed shape (B,): at most one request
    per slot completes per tick."""

    valid: Array     # (B,) bool
    user: Array      # (B,) int32
    latency: Array   # (B,) f32 end-to-end seconds (wait + service)
    wait: Array      # (B,) f32 queue-wait component
    serv: Array      # (B,) f32 service component


def init_state(capacity: int, queue_depth: int) -> BatchState:
    b, q = int(capacity), int(queue_depth)
    return BatchState(
        active=jnp.zeros((b,), bool),
        user=jnp.full((b,), -1, jnp.int32),
        t_arr=jnp.zeros((b,), jnp.float32),
        wait=jnp.zeros((b,), jnp.float32),
        serv=jnp.zeros((b,), jnp.float32),
        work=jnp.zeros((b,), jnp.int32),
        q_user=jnp.full((q,), -1, jnp.int32),
        q_t=jnp.zeros((q,), jnp.float32),
        q_head=jnp.int32(0),
        q_size=jnp.int32(0),
        dropped=jnp.int32(0),
        completed=jnp.int32(0),
        shed=jnp.int32(0),
    )


def enqueue(state: BatchState, counts: Array, now: Array,
            max_per_user: int) -> BatchState:
    """Append this epoch's arrivals (per-user counts, capped at
    ``max_per_user``) to the FIFO ring; overflow increments ``dropped``."""
    u = counts.shape[0]
    q = state.q_user.shape[0]
    # (U, K) candidate grid flattened in user-major order: request j of user
    # i exists iff j < counts[i].
    k = int(max_per_user)
    valid = (jnp.arange(k)[None, :] < counts[:, None]).reshape(-1)
    users = jnp.broadcast_to(jnp.arange(u, dtype=jnp.int32)[:, None],
                             (u, k)).reshape(-1)

    def push(carry, x):
        q_user, q_t, head, size, dropped = carry
        is_valid, uid = x
        fits = is_valid & (size < q)
        slot = (head + size) % q
        q_user = jnp.where(fits, q_user.at[slot].set(uid), q_user)
        q_t = jnp.where(fits, q_t.at[slot].set(now.astype(jnp.float32)), q_t)
        size = size + fits.astype(jnp.int32)
        dropped = dropped + (is_valid & ~fits).astype(jnp.int32)
        return (q_user, q_t, head, size, dropped), None

    (q_user, q_t, head, size, dropped), _ = jax.lax.scan(
        push, (state.q_user, state.q_t, state.q_head, state.q_size,
               state.dropped), (valid, users))
    return state._replace(q_user=q_user, q_t=q_t, q_size=size,
                          dropped=dropped)


def admit(state: BatchState, now: Array, service_s: Array,
          work_steps: Array, shed: Array | None = None) -> BatchState:
    """Refill free slots from the queue head (FIFO). ``service_s``: (U,)
    modeled service seconds per user at the current operating point;
    ``work_steps``: (U,) int32 slot epochs the request will occupy.

    ``shed`` (optional, (U,) bool) is the degradation ladder's admission
    gate: a queue head whose user is flagged is popped and counted into
    ``state.shed`` instead of occupying a slot -- under a persistent deep
    fade its modeled work would pin the slot for ``max_work_epochs``,
    starving every healthy user behind it. None preserves the exact
    ungated behavior."""
    q = state.q_user.shape[0]

    def fill(carry, slot):
        st = carry
        free = ~st.active[slot]
        have = st.q_size > 0
        pop = free & have
        uid = st.q_user[st.q_head % q]
        t0 = st.q_t[st.q_head % q]
        if shed is None:
            doomed = jnp.bool_(False)
        else:
            doomed = pop & shed[jnp.maximum(uid, 0)]
        take = pop & ~doomed
        nowf = now.astype(jnp.float32)
        st = st._replace(
            active=st.active.at[slot].set(jnp.where(take, True,
                                                    st.active[slot])),
            user=st.user.at[slot].set(jnp.where(take, uid, st.user[slot])),
            t_arr=st.t_arr.at[slot].set(jnp.where(take, t0, st.t_arr[slot])),
            wait=st.wait.at[slot].set(jnp.where(take, nowf - t0,
                                                st.wait[slot])),
            serv=st.serv.at[slot].set(jnp.where(take, service_s[uid],
                                                st.serv[slot])),
            work=st.work.at[slot].set(jnp.where(take, work_steps[uid],
                                                st.work[slot])),
            q_head=(st.q_head + pop.astype(jnp.int32)) % q,
            q_size=st.q_size - pop.astype(jnp.int32),
            shed=st.shed + doomed.astype(jnp.int32),
        )
        return st, take

    b = state.active.shape[0]
    state, admitted = jax.lax.scan(fill, state, jnp.arange(b))
    del admitted
    return state


def tick(state: BatchState) -> tuple[BatchState, Completions]:
    """One batched edge step: every active slot advances one unit of work;
    slots reaching zero complete and free."""
    work = state.work - state.active.astype(jnp.int32)
    done = state.active & (work <= 0)
    comp = Completions(
        valid=done,
        user=jnp.where(done, state.user, -1),
        latency=jnp.where(done, state.wait + state.serv, 0.0),
        wait=jnp.where(done, state.wait, 0.0),
        serv=jnp.where(done, state.serv, 0.0),
    )
    state = state._replace(
        active=state.active & ~done,
        user=jnp.where(done, -1, state.user),
        work=jnp.maximum(work, 0),
        completed=state.completed + jnp.sum(done).astype(jnp.int32),
    )
    return state, comp


def occupancy(state: BatchState) -> Array:
    """() int32: active slots (the edge batch's instantaneous load)."""
    return jnp.sum(state.active).astype(jnp.int32)


def backlog(state: BatchState) -> Array:
    """() int32: requests waiting in the ring behind the batch."""
    return state.q_size


class ContinuousBatcher:
    """The queueing core as one compiled per-epoch program.

    ``step(state, counts, now, service_s, work_steps)`` runs
    enqueue -> admit -> tick and returns (state', completions). The state
    argument is donated: the caller threads the returned state, so XLA
    reuses the buffers in place across epochs."""

    def __init__(self, capacity: int, queue_depth: int,
                 max_per_user_epoch: int):
        if capacity < 1 or queue_depth < 1:
            raise ValueError(
                f"capacity/queue_depth must be >= 1, got "
                f"{capacity}/{queue_depth}")
        self.capacity = int(capacity)
        self.queue_depth = int(queue_depth)
        self.max_per_user_epoch = int(max_per_user_epoch)

    def init(self) -> BatchState:
        return init_state(self.capacity, self.queue_depth)

    @functools.cached_property
    def _step(self):
        k = self.max_per_user_epoch

        def step(state, counts, now, service_s, work_steps):
            state = enqueue(state, counts, now, k)
            state = admit(state, now, service_s, work_steps)
            return tick(state)

        return jax.jit(step, donate_argnums=(0,))

    def step(self, state: BatchState, counts: Array, now: Array,
             service_s: Array, work_steps: Array
             ) -> tuple[BatchState, Completions]:
        return self._step(state, counts, now, service_s, work_steps)


# --------------------------------------------------------------------------
# real-model edge batching: slot-masked programs over serve.py machinery
# --------------------------------------------------------------------------
def _slot_axis(path) -> int:
    """Batch-axis index of a cache leaf: stage caches are stacked over the
    stage's layers first (make_cache leaves are (L, B, ...)), everything
    else (pos, enc_out, frontend) leads with B."""
    return 1 if any(getattr(p, "key", None) == "stages" for p in path) else 0


def slot_update(caches, slot: Array | int, one):
    """Write a single-request cache pytree (batch dim 1, e.g. from
    model.prefill at batch 1) into slot ``slot`` of a capacity-sized cache:
    the decode-cache analogue of admitting a request."""
    def write(path, full, single):
        ax = _slot_axis(path)
        return jax.lax.dynamic_update_index_in_dim(
            full, jnp.take(single, 0, axis=ax).astype(full.dtype), slot, ax)
    return jax.tree_util.tree_map_with_path(write, caches, one)


def slot_where(active: Array, new, old):
    """Per-slot select over a cache pytree: active slots take ``new``,
    inactive keep ``old`` (frozen until their next admission)."""
    def sel(path, n, o):
        ax = _slot_axis(path)
        shape = [1] * n.ndim
        shape[ax] = active.shape[0]
        return jnp.where(active.reshape(shape), n, o)
    return jax.tree_util.tree_map_with_path(sel, new, old)


class EdgeBatcher:
    """Single-shot split inference over stacked activations: admitted
    requests write their device-side activation into a (B, S, D) buffer;
    one edge_fn call per epoch serves every active slot (masked-slot
    continuous batching -- inactive lanes compute garbage that is never
    read, the standard slot-batching tradeoff)."""

    def __init__(self, capacity: int, seq: int, d_model: int,
                 dtype=jnp.float32):
        self.capacity = int(capacity)
        self.buf = jnp.zeros((capacity, seq, d_model), dtype)

    def write(self, buf: Array, slot: Array | int, act: Array) -> Array:
        """Insert one request's (S, D) (or (1, S, D)) activation at slot."""
        if act.ndim == 3:
            act = act[0]
        return jax.lax.dynamic_update_index_in_dim(
            buf, act.astype(buf.dtype), slot, 0)

    def run(self, edge_fn, buf: Array) -> Array:
        """One batched edge pass over the whole buffer: (B, S, vocab)."""
        return edge_fn(buf)


class DecodeBatcher:
    """Edge decode path with per-slot KV/state caches, reusing the
    runtime/serve.py decode-step machinery: one capacity-sized cache from
    model.make_caches, per-request prefill written into its slot at
    admission, one masked decode step per epoch for all active slots."""

    def __init__(self, model, params, capacity: int, max_len: int):
        self.model = model
        self.params = params
        self.capacity = int(capacity)
        self.max_len = int(max_len)
        self.caches = model.make_caches(capacity, max_len)
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, {"tokens": toks}, max_len))

        def masked_step(params, caches, token, active):
            token = jnp.where(active[:, None], token, 0)
            logits, new_caches = model.decode_step(params, caches, token)
            # Inactive slots' caches are frozen (their next admission
            # overwrites them); their logits lanes are garbage by contract.
            return logits, slot_where(active, new_caches, caches)

        self._step = jax.jit(masked_step, donate_argnums=(1,))

    def admit(self, slot: int, tokens: Array) -> Array:
        """Prefill one request (tokens (1, S)) into ``slot``; returns its
        next-token logits (vocab,)."""
        logits, one = self._prefill(self.params, tokens)
        self.caches = slot_update(self.caches, slot, one)
        return logits[0]

    def step(self, token: Array, active: Array) -> Array:
        """One masked decode step: token (B, 1), active (B,) bool ->
        logits (B, vocab). Every active slot advances together."""
        logits, self.caches = self._step(self.params, self.caches, token,
                                         active)
        return logits

    def export_caches(self):
        """Host copies of the slot caches (repro.state serving snapshot):
        device_get on the caller's thread, so the returned tree is immune
        to the donated in-place update of the next step()."""
        return jax.device_get(self.caches)

    def import_caches(self, caches) -> None:
        """Restore exported slot caches. Avals must match the live caches
        (same model/capacity/max_len) or the compiled masked step would
        retrace; a mismatch raises ValueError."""
        live = jax.tree_util.tree_flatten(self.caches)
        new = jax.tree_util.tree_flatten(caches)
        if live[1] != new[1]:
            raise ValueError("cache treedef mismatch on import")
        for i, (a, b) in enumerate(zip(new[0], live[0])):
            if (jnp.shape(a) != jnp.shape(b)
                    or jnp.result_type(a) != jnp.result_type(b)):
                raise ValueError(
                    f"cache leaf {i}: got {jnp.result_type(a)}"
                    f"{list(jnp.shape(a))}, live caches have "
                    f"{jnp.result_type(b)}{list(jnp.shape(b))}")
        self.caches = jax.tree_util.tree_unflatten(
            new[1], [jnp.asarray(x) for x in new[0]])
