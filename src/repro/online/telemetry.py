"""Measured-profile telemetry: live timings folded back into a ModelProfile.

The planner's static profile says what each layer *should* cost; serving
says what it *does* cost under real load (edge contention, radio fades the
channel model didn't price, thermal throttling on device). Telemetry closes
that gap without changing the planner at all: observed per-layer wall times
are converted back into the planner's native units -- *effective FLOPs* at
the speed of whichever side executed the layer, and *effective bits* at the
priced NOMA rate for the transfer -- EMA-smoothed into a TelemetryState
whose arrays are shaped exactly like the static profile's tables. Each
feedback epoch, ``profile()`` rebuilds a ModelProfile via
``ModelProfile.like`` (same shapes, dtypes, and static name), so the
measured profile is a plain operand swap for every already-compiled
planner program: zero recompiles, zero cache growth.

Attribution (the one modeling choice): a single shared ``fl`` table cannot
express one-sided edge congestion -- the planner divides the same fl[i] by
*both* sides' speeds, so uniformly inflated entries cancel out of the
split comparison. The telemetry therefore keeps ``fl`` congestion-
normalized (device layers: ``t_obs * c_device``; edge layers:
``t_obs * lam(r) * c_min_edge / kappa``) and captures congestion in the
one scalar that survives the division: ``kappa``, the edge slowdown
estimated from the suffix layers' observed-vs-intrinsic times. ``kappa``
is then folded into the measured ``m_down`` as effective extra downlink
bits, ``suf(s') * (kappa - 1) / (lam(r) c_min) * rate_dn``, which makes
the planner's t_dn(s') reproduce the *true* congested edge delay for
every candidate split s' -- an exact representation of one-sided
congestion inside ModelProfile's parameterization. Under edge load the
whole offload branch of the utility curve rises and s* moves upward (keep
more layers local); when nothing is offloaded the suffix is unobservable
and kappa relaxes toward 1 (optimistic re-probing, damped by the QoS
cooldown). The split upload is re-priced directly: ``w_meas[s] = t_up_obs
* rate_up`` at the priced NOMA rate, touched only at index s (a
where-mask, so unvisited split points keep their prior).

The update is one jitted program with the state donated in place; nothing
here syncs to host. ``jax.transfer_guard('disallow')`` holds around the
steady-state loop (audited by repro.analysis.online_audit).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import Array, ComputeConstants, ModelProfile, lam


class TelemetryState(NamedTuple):
    """EMA-smoothed effective per-layer tables, shaped like the static
    profile (fl (F,), w (F+1,), m_down (F+1,)), plus the congestion
    estimate and the rate/compute references needed to express it."""

    fl: Array
    w: Array
    m_down: Array     # the *static* m_down prior; kappa is folded in by
                      # profile(), not accumulated here
    kappa: Array      # () f32 estimated edge slowdown (1 = uncongested)
    rate_dn: Array    # () f32 EMA mean downlink rate (bit/s)
    r_units: Array    # () f32 EMA mean edge compute units
    updates: Array    # () int32


class Observation(NamedTuple):
    """One feedback epoch's measurements, all device scalars/arrays.

    t_layer  (F,) observed wall seconds of each layer on the side that
             executed it (device for i < s, edge for i >= s)
    t_up     ()  observed split-upload seconds
    rate_up  ()  priced NOMA uplink rate (bit/s) the upload actually got
    rate_dn  ()  priced NOMA downlink rate (bit/s) for the result return
    r_units  ()  edge compute units serving the suffix (for lam(r))
    """

    t_layer: Array
    t_up: Array
    rate_up: Array
    rate_dn: Array
    r_units: Array


def telemetry_update(comp: ComputeConstants, decay: float, static_fl: Array,
                     state: TelemetryState, s: Array,
                     obs: Observation) -> TelemetryState:
    """Pure one-epoch update (composable inside a larger jitted program).
    ``static_fl`` is the static profile's per-layer FLOPs, the intrinsic-
    cost reference the edge-slowdown estimate is measured against."""
    a = decay
    f = state.fl.shape[0]
    on_device = jnp.arange(f) < s
    edge_speed = lam(obs.r_units, comp) * comp.c_min_edge

    # Edge slowdown: observed suffix seconds vs the intrinsic suffix cost at
    # the nominal edge speed. With nothing offloaded (s = F) the edge is
    # unobservable and the estimate relaxes toward 1 -- optimistic
    # re-probing, so a drained edge gets offered load again.
    suf_static = jnp.sum(jnp.where(on_device, 0.0, static_fl))
    t_edge = jnp.sum(jnp.where(on_device, 0.0, obs.t_layer))
    kappa_obs = jnp.where(suf_static > 0.0,
                          t_edge * edge_speed / jnp.maximum(suf_static, 1.0),
                          1.0)
    kappa = jnp.maximum(a * state.kappa + (1.0 - a) * kappa_obs, 1.0)

    # Congestion-normalized intrinsic cost: both sides' observations agree
    # on fl up to noise, so every layer updates.
    speed = jnp.where(on_device, comp.c_device, edge_speed / kappa_obs)
    fl_obs = obs.t_layer * speed
    fl = a * state.fl + (1.0 - a) * fl_obs

    # Re-price the upload only at the split actually exercised; the terminal
    # entry w[F] is structurally zero (no upload).
    at_s = jnp.arange(f + 1) == s
    w_obs = obs.t_up * obs.rate_up
    w = jnp.where(at_s & (jnp.arange(f + 1) < f),
                  a * state.w + (1.0 - a) * w_obs, state.w)
    return TelemetryState(
        fl=fl.astype(state.fl.dtype),
        w=w.astype(state.w.dtype),
        m_down=state.m_down,
        kappa=kappa.astype(jnp.float32),
        rate_dn=(a * state.rate_dn
                 + (1.0 - a) * obs.rate_dn).astype(jnp.float32),
        r_units=(a * state.r_units
                 + (1.0 - a) * obs.r_units).astype(jnp.float32),
        updates=state.updates + 1,
    )


def measured_profile(comp: ComputeConstants, prof: ModelProfile,
                     state: TelemetryState) -> ModelProfile:
    """Pure rebuild of the measured profile from a TelemetryState.

    The congestion estimate is folded into m_down: candidate split s'
    suffers ``suf(s') * (kappa - 1) / (lam(r) c_min)`` extra edge seconds,
    expressed as downlink bits at the EMA rate so the planner's t_dn
    reproduces the congested delay curve exactly."""
    fl = state.fl
    prefix = jnp.concatenate([jnp.zeros((1,), fl.dtype), jnp.cumsum(fl)])
    suffix = jnp.sum(fl) - prefix
    edge_speed = lam(state.r_units, comp) * comp.c_min_edge
    extra_s = suffix * (state.kappa - 1.0) / jnp.maximum(edge_speed, 1.0)
    m_down = state.m_down + extra_s * state.rate_dn
    return prof.like(state.fl, state.w, m_down)


class Telemetry:
    """Accumulates observations into a measured ModelProfile.

    Built from the *same* static profile the planner was constructed with
    (``validate_like`` enforces this once, at loop start); the static
    tables are both the prior and the EMA initial state."""

    def __init__(self, prof: ModelProfile, comp: ComputeConstants,
                 decay: float = 0.9):
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        self.prof = prof
        self.comp = comp
        self.decay = float(decay)

    def init(self, prof: ModelProfile | None = None) -> TelemetryState:
        """Initial state from the planner's profile. If a (measured or
        otherwise substituted) ``prof`` is passed, it is validated against
        the static profile here -- the loop-start shape check."""
        p = self.prof if prof is None else self.prof.validate_like(prof)
        # Copies, not aliases: the update donates the state in place, and
        # donating the profile's own buffers would delete them.
        return TelemetryState(fl=jnp.array(p.fl, copy=True),
                              w=jnp.array(p.w, copy=True),
                              m_down=jnp.array(p.m_down, copy=True),
                              kappa=jnp.float32(1.0),
                              rate_dn=jnp.float32(0.0),
                              r_units=jnp.float32(self.comp.r_min),
                              updates=jnp.int32(0))

    @functools.cached_property
    def _update(self):
        return jax.jit(
            functools.partial(telemetry_update, self.comp, self.decay,
                              self.prof.fl),
            donate_argnums=(0,))

    def update(self, state: TelemetryState, s: Array,
               obs: Observation) -> TelemetryState:
        """Fold one epoch's observation in; donates ``state`` in place."""
        return self._update(state, s, obs)

    @functools.cached_property
    def _profile(self):
        # jitted (not eager): eager dispatch would re-transfer the python
        # compute constants to device every epoch and trip
        # jax.transfer_guard('disallow') in the steady-state loop.
        return jax.jit(
            functools.partial(measured_profile, self.comp, self.prof))

    def profile(self, state: TelemetryState) -> ModelProfile:
        """The measured profile as a planner operand: same shapes, dtypes
        and static name as the static profile (ModelProfile.like via
        ``measured_profile``), so it hits every compiled planner program
        without retracing. One compiled program, no host sync."""
        return self._profile(state)
