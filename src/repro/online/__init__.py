"""Closed-loop online serving: request streams, continuous batching,
measured-profile telemetry, and a QoS monitor that drives the planner --
the ECC planner operating on live traffic instead of static profiles."""
from repro.online.streams import (  # noqa: F401
    RequestStream,
    StreamConfig,
    StreamState,
    stream_step,
)
from repro.online.batcher import (  # noqa: F401
    BatchState,
    Completions,
    ContinuousBatcher,
    DecodeBatcher,
    EdgeBatcher,
    slot_update,
    slot_where,
)
from repro.online.telemetry import (  # noqa: F401
    Observation,
    Telemetry,
    TelemetryState,
    measured_profile,
    telemetry_update,
)
from repro.online.qos import (  # noqa: F401
    QosConfig,
    QosMonitor,
    QosReport,
    QosState,
    qos_update,
)
from repro.online.loop import (  # noqa: F401
    EpochOut,
    OnlineLoop,
    ServiceConfig,
)
from repro.faults.degrade import LadderConfig  # noqa: F401
from repro.faults.injectors import FaultConfig  # noqa: F401
