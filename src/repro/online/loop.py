"""The closed loop: streams -> batcher -> telemetry -> QoS -> planner.

One epoch of online serving is ONE compiled program (`kind "online_epoch"`
in planning.compile_log) plus one host decision point:

  device (compiled, state donated in place):
    1. scenario.step/env      -- mobility + fading advance, env materializes
    2. streams.stream_step    -- per-user Poisson arrivals for the epoch
    3. service model          -- per-user end-to-end seconds under the
                                 *current* plan and the measured edge
                                 congestion (occupancy + backlog inflate the
                                 suffix compute), plus the per-layer
                                 Observation the telemetry folds in
    4. batcher enqueue/admit/tick -- continuous batching; completions out
    5. qos_update             -- percentiles, miss EMAs, trigger bool
    6. telemetry_update       -- measured profile EMA

  host (per epoch):
    - read the QoS trigger (one scalar sync, the loop's decision point)
    - OnlineSplitServer.observe(env, prof=measured, force=trigger): replan
      on schedule or on trigger; its one sync is s* (the re-cut decision)

Because the plan enters the epoch program as a SplitPlan operand and the
measured profile enters the planner as a ModelProfile operand (same avals
every epoch -- planning._strong_typed + ModelProfile.like), a steady-state
episode compiles each program exactly once and moves no arrays to host
beyond the two decision scalars. Both properties are machine-checked:
planning.compile_log in tests, repro.analysis.online_audit in CI.

Chaos hardening (PR 9) rides the same discipline: fault injection
(repro.faults.injectors) is traced into the epoch program with the rates
as f32-scalar operands and the persistent outage masks as one more donated
state pytree; in-jit guards (repro.faults.guards) pack every health check
into ONE extra int32 synced per epoch; and the host-side degradation
ladder (repro.faults.degrade) turns that word into reject-and-hold /
quarantine / baseline-fallback / backed-off-cold-replan decisions. A loop
constructed without ``degrade=`` is byte-for-byte the PR 8 behavior.

The service model is where the closed loop earns its keep: the edge's
effective speed degrades with load (`1 + load_gain * (occupancy + backlog)
/ capacity`), which the *static* profile cannot see. The telemetry
attributes the inflated suffix times back into effective FLOPs, the
measured profile makes the planner price edge compute honestly, and s*
rises (keep more layers on device) exactly when the edge saturates --
the requests/sec-vs-concurrency benchmark (benchmarks/online_serve.py)
demonstrates the divergence from the static-profile plan.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import channel
from repro.core.types import Array, ModelProfile, SplitPlan, lam, make_weights
from repro.faults import degrade as degradelib
from repro.faults import guards, injectors
from repro.faults.degrade import DegradeLadder, EpochWatchdog, LadderConfig
from repro.faults.injectors import FaultConfig, FaultState
from repro.planning.engine import _recorded
from repro.runtime.serve import OnlineSplitServer
from repro.online import batcher as batcherlib
from repro.online.batcher import BatchState, ContinuousBatcher
from repro.online.qos import QosConfig, QosMonitor, QosReport, QosState, qos_update
from repro.online.streams import RequestStream, StreamConfig, StreamState, stream_step
from repro.online.telemetry import (
    Observation,
    Telemetry,
    TelemetryState,
    telemetry_update,
)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Edge service knobs. ``edge_capacity`` is the continuous batch size B;
    ``queue_depth`` the admission ring; ``load_gain`` how hard contention
    degrades the edge (effective suffix cost scales by ``1 + load_gain *
    (occupancy + backlog) / capacity`` -- 0 makes the edge ideal and the
    closed loop converges to the static plan); ``replan_every`` the
    scheduled replan cadence in epochs; ``max_work_epochs`` caps one
    request's slot occupancy."""

    edge_capacity: int = 8
    queue_depth: int = 32
    load_gain: float = 0.0
    replan_every: int = 10
    telemetry_decay: float = 0.9
    max_work_epochs: int = 1000


class EpochOut(NamedTuple):
    """Device-resident per-epoch outputs handed back to the host loop."""

    env: object          # NetworkEnv of the new epoch (the replan operand;
                         # fault-masked gains when injection is active)
    report: QosReport
    counts: Array        # (U,) arrivals this epoch
    completed: Array     # () int32 completions this epoch
    occupancy: Array     # () int32 active slots after the tick
    backlog: Array       # () int32 queued requests after the tick
    congestion: Array    # () f32 edge slowdown factor used this epoch
    health: Array        # () int32 packed health word (faults.guards)
    faulted: Array       # () int32 users in deep fade this epoch


class OnlineLoop:
    """Closed-loop serving over one time-evolving scenario.

    feedback=True plans against the telemetry's measured profile;
    feedback=False is the open-loop control (static profile), same epochs,
    same traffic -- the benchmark's comparison arm."""

    def __init__(self, scenario, engine, stream_cfg: StreamConfig,
                 service_cfg: ServiceConfig = ServiceConfig(),
                 qos_cfg: QosConfig | None = None,
                 model=None, params=None, feedback: bool = True,
                 faults: FaultConfig | None = None,
                 degrade: LadderConfig | None = None):
        u = scenario.cfg.n_users
        self.scenario = scenario
        self.engine = engine
        self.stream_cfg = stream_cfg
        self.service_cfg = service_cfg
        self.feedback = bool(feedback)
        # Fault injection (zero-rate config is an exact identity) and the
        # degradation ladder. ``degrade`` hardens the loop: plan guarding
        # at the server, telemetry quarantine, admission shedding, QoS
        # non-finite guarding, baseline fallback, epoch watchdog. A loop
        # without it behaves exactly as PR 8 shipped -- the chaos
        # benchmark's no-ladder arm.
        self.fault_cfg = faults or FaultConfig()
        self._rates = self.fault_cfg.rates()
        self.ladder = DegradeLadder(degrade) if degrade is not None else None
        self._hardened = degrade is not None
        ladder_cfg = degrade if degrade is not None else LadderConfig()
        self._kappa_max = float(ladder_cfg.kappa_max)
        self._shed_factor = (float(ladder_cfg.shed_service_factor)
                             if self._hardened else 0.0)
        self._watchdog = (EpochWatchdog(ladder_cfg.watchdog_timeout_s)
                          if self._hardened
                          and ladder_cfg.watchdog_timeout_s > 0 else None)
        self.qos_cfg = qos_cfg or QosConfig(
            deadline_s=stream_cfg.deadline_s,
            guard_nonfinite=self._hardened)
        self.stream = RequestStream(stream_cfg, u)
        self.batcher = ContinuousBatcher(
            service_cfg.edge_capacity, service_cfg.queue_depth,
            stream_cfg.max_per_user_epoch)
        self.qos = QosMonitor(self.qos_cfg, u)
        self.telemetry = Telemetry(engine.prof, scenario.cfg.comp,
                                   service_cfg.telemetry_decay)
        self.server = OnlineSplitServer(engine, model, params,
                                        replan_every=service_cfg.replan_every,
                                        guard_plans=self._hardened)
        # episode state (device pytrees), populated by reset()
        self._sc = self._st = self._bt = self._qs = self._tel = None
        self._fs: FaultState | None = None
        self._plan: SplitPlan | None = None
        self._key: jax.Array | None = None
        self._fb_jit = None                  # jitted fallback plan builder
        self._plan_template = None           # engine plan avals (eval_shape)
        # durable serving (repro.state): host epoch clock, the attached
        # flight recorder, and cached engine PlanState avals by treedef kind
        self.host_epoch = 0
        self._recorder = None
        self._state_avals: dict[str, object] = {}

    # -- the compiled epoch program ---------------------------------------
    def _service_and_observation(self, env, plan: SplitPlan,
                                 congestion: Array):
        """Per-user modeled service seconds + the telemetry Observation,
        both priced at the *discrete* plan (one-hot subchannels, planned
        powers/compute units) with the measured congestion inflating the
        edge suffix. The static profile is the simulator's ground truth."""
        prof, comp = self.engine.prof, self.scenario.cfg.comp
        s = plan.s
        pre = prof.prefix_flops()[s]
        suf = prof.suffix_flops()[s]
        beta_up = jax.nn.one_hot(plan.sub_up, env.n_sub, dtype=env.g_up.dtype)
        beta_dn = jax.nn.one_hot(plan.sub_dn, env.n_sub, dtype=env.g_up.dtype)
        r_up = jnp.maximum(
            jnp.sum(channel.uplink_rates(env, beta_up, plan.p_up), -1), 1e-9)
        r_dn = jnp.maximum(
            jnp.sum(channel.downlink_rates(env, beta_dn, plan.p_dn), -1), 1e-9)
        speed_edge = lam(plan.r, comp) * comp.c_min_edge
        t_dev = pre / comp.c_device
        t_up = prof.w[s] / r_up
        t_edge = suf * congestion / speed_edge
        t_dn = prof.m_down[s] / r_dn
        service = t_dev + t_up + t_edge + t_dn                     # (U,)

        f = prof.n_layers
        r_mean = jnp.mean(plan.r)
        on_device = jnp.arange(f) < s
        t_layer = jnp.where(
            on_device, prof.fl / comp.c_device,
            prof.fl * congestion / (lam(r_mean, comp) * comp.c_min_edge))
        rate_mean = jnp.mean(r_up)
        obs = Observation(t_layer=t_layer,
                          t_up=prof.w[s] / rate_mean,
                          rate_up=rate_mean,
                          rate_dn=jnp.mean(r_dn),
                          r_units=r_mean)
        return service, obs

    @functools.cached_property
    def _epoch(self):
        scen, svc = self.scenario, self.service_cfg
        stream_cfg, qos_cfg = self.stream_cfg, self.qos_cfg
        comp_consts = scen.cfg.comp
        dt = stream_cfg.epoch_dt_s
        cap = float(svc.edge_capacity)
        n_users = scen.cfg.n_users
        hardened = self._hardened
        kappa_max = self._kappa_max
        shed_thr = self._shed_factor * stream_cfg.deadline_s

        def epoch(base_key, plan: SplitPlan, rates: injectors.FaultRates,
                  sc, st: StreamState, bt: BatchState, qs: QosState,
                  tel: TelemetryState, fs: FaultState):
            k_ep = jax.random.fold_in(base_key, st.epoch)
            k_sc = jax.random.fold_in(k_ep, 1)
            k_fault = jax.random.fold_in(k_ep, 2)
            sc = scen.step(k_sc, sc)
            env = scen.env(sc)
            # Faults realize before anything observes the epoch: the masked
            # gains ARE this epoch's channel, for service and replans alike.
            fs, draw = injectors.fault_step(rates, k_fault, fs)
            env = injectors.apply_env_faults(env, draw, rates)
            st, counts = stream_step(stream_cfg, n_users, base_key, st)
            # Congestion from the load the edge is already carrying when
            # this epoch's work lands.
            load = (batcherlib.occupancy(bt) + batcherlib.backlog(bt)
                    ).astype(jnp.float32)
            congestion = 1.0 + svc.load_gain * load / cap
            service, obs = self._service_and_observation(env, plan,
                                                         congestion)
            service = injectors.spike_service(service, draw)
            obs = injectors.corrupt_observation(obs, draw, rates)
            work = jnp.clip(jnp.ceil(service / dt).astype(jnp.int32), 1,
                            svc.max_work_epochs)
            now = st.epoch.astype(jnp.float32) * dt
            if hardened and shed_thr > 0:
                # Admission shedding: a user whose modeled service blows
                # past the deadline by the shed factor (deep fade, AP
                # blackout) would jam a batch slot for max_work_epochs --
                # drop its arrivals (and queued heads, in admit) instead of
                # starving the healthy users behind it.
                doomed = (service > shed_thr) | ~jnp.isfinite(service)
                shed_n = jnp.sum(jnp.where(doomed, counts, 0)
                                 ).astype(jnp.int32)
                bt = batcherlib.enqueue(bt, jnp.where(doomed, 0, counts),
                                        now, stream_cfg.max_per_user_epoch)
                bt = bt._replace(shed=bt.shed + shed_n)
                bt = batcherlib.admit(bt, now, service, work, shed=doomed)
            else:
                bt = batcherlib.enqueue(bt, counts, now,
                                        stream_cfg.max_per_user_epoch)
                bt = batcherlib.admit(bt, now, service, work)
            bt, comps = batcherlib.tick(bt)
            qs, report = qos_update(qos_cfg, qs, comps)
            tel_new = telemetry_update(comp_consts, svc.telemetry_decay,
                                       self.engine.prof.fl, tel, plan.s, obs)
            obs_word = guards.observation_health(obs)
            if hardened:
                # Rung 2, in-jit half: a corrupt observation never enters
                # the EMA -- the telemetry state holds, the host-side
                # quarantine decides when to trust the profile again.
                tel = guards.tree_select(obs_word == 0, tel_new, tel)
            else:
                tel = tel_new
            health = guards.pack_health(
                obs_word, guards.service_health(service),
                guards.telemetry_health(tel, kappa_max))
            out = EpochOut(env=env, report=report, counts=counts,
                           completed=jnp.sum(comps.valid).astype(jnp.int32),
                           occupancy=batcherlib.occupancy(bt),
                           backlog=batcherlib.backlog(bt),
                           congestion=congestion,
                           health=health,
                           faulted=jnp.sum(draw.link_down
                                           ).astype(jnp.int32))
            return sc, st, bt, qs, tel, fs, out

        # _recorded: each trace of the epoch program logs "online_epoch" to
        # planning.compile_log sinks -- the steady-state compile-once
        # property is asserted against this, exactly like the engine kinds.
        # The fault rates (arg 2) are NOT donated: the same operand tuple
        # re-enters every epoch (and swapping it is how the benchmark
        # sweeps outage rates without retracing).
        return jax.jit(_recorded(epoch, "online_epoch"),
                       donate_argnums=(3, 4, 5, 6, 7, 8))

    # -- episode driving ---------------------------------------------------
    def set_fault_rates(self, cfg: FaultConfig) -> None:
        """Swap the fault mix mid-episode. The rates are operands of the
        compiled epoch program (same avals for every config), so this never
        retraces -- the chaos benchmark's outage-rate sweep is this call.
        With a flight recorder attached, the swap is journaled (it is host
        input the deterministic replay cannot re-derive)."""
        self.fault_cfg = cfg
        self._rates = cfg.rates()
        if self._recorder is not None:
            self._recorder.record_rates(self.host_epoch,
                                        dataclasses.asdict(cfg))

    def attach_recorder(self, recorder) -> None:
        """Attach a repro.state.FlightRecorder: every epoch's host trace
        (the packed plan/health word, the QoS trigger, the ladder stage)
        and every fault-rate swap are journaled for deterministic replay.
        Recording syncs s* per epoch (one extra scalar beyond the loop's
        decision reads); pass None to detach."""
        self._recorder = recorder

    def _fallback(self, env) -> SplitPlan:
        """The ladder's rung-3 plan, cast to engine-plan avals (so serving
        it never retraces the epoch program) by a jitted program that is
        warmed at reset -- a mid-episode escalation traces nothing."""
        if self._fb_jit is None:
            w = (self.engine.weights if self.engine.weights is not None
                 else make_weights(self.scenario.cfg.n_users))
            mode = self.ladder.cfg.fallback
            template = self._plan_template
            prof = self.engine.prof

            def fb(env):
                return degradelib.fallback_plan(env, prof, w,
                                                template=template, mode=mode)

            self._fb_jit = jax.jit(_recorded(fb, "fallback_plan"))
        return self._fb_jit(env)

    def reset(self, key: jax.Array) -> None:
        """Initialize scenario/stream/batch/QoS/telemetry/fault state and
        take the initial (cold) plan. The telemetry starts at the static
        profile, so feedback and static arms are identical until load
        appears. Hardened loops also warm the fallback-plan program here,
        so a mid-episode ladder escalation traces nothing."""
        k_sc, k_st, self._key = jax.random.split(key, 3)
        self.host_epoch = 0
        self._state_avals.clear()
        self._sc = self.scenario.init(k_sc)
        self._st = self.stream.init(k_st)
        self._bt = self.batcher.init()
        self._qs = self.qos.init()
        self._tel = self.telemetry.init()
        self._fs = injectors.init_fault_state(self.scenario.cfg.n_users,
                                              self.scenario.cfg.n_aps)
        env0 = self.scenario.env(self._sc)
        if self._hardened:
            # Engine-plan avals without executing the solver: the fallback
            # template (and the epoch program's stability across the
            # planner -> fallback -> planner switches) comes from
            # eval_shape of the cold-plan program.
            plan_fn = self.engine.program("plan", env0)
            shapes = jax.eval_shape(
                plan_fn, *self.engine.program_args("plan", env0))
            self._state_avals["cold"] = shapes
            self._plan_template = shapes.plan
        self.server.observe(env0)          # epoch 0 is always scheduled
        if self.ladder is not None:
            self.ladder.post_replan(self.server.last_plan_ok,
                                    self.server.last_replanned)
        if self.server.state is not None:
            self._plan = self.server.state.plan
            if self._hardened:
                jax.block_until_ready(self._fallback(env0).utility)  # warm
        else:
            # The very first plan was rejected by the guard: serve the
            # baseline fallback until the ladder recovers a real plan.
            self._plan = self._fallback(env0)
        if self.feedback:
            self.measured_profile()        # warm the profile rebuild

    def measured_profile(self) -> ModelProfile:
        """The telemetry's current measured profile (a planner operand)."""
        return self.telemetry.profile(self._tel)

    def epoch_args(self) -> tuple:
        """The epoch program's current operand tuple (post-reset), for
        trace-only audits (analysis.fault_audit)."""
        return (self._key, self._plan, self._rates, self._sc, self._st,
                self._bt, self._qs, self._tel, self._fs)

    # -- durable serving (repro.state hooks) -------------------------------
    def _plan_state_avals(self, kind: str):
        """Engine PlanState avals by treedef kind: "cold"/"none" states come
        from the plan program (warm_rho is None there), "warm" from replan.
        jax.eval_shape only -- no solver executes. Cached per episode."""
        want = "cold" if kind == "none" else kind
        if want not in self._state_avals:
            env0 = self.scenario.env(self._sc)
            if "cold" not in self._state_avals:
                self._state_avals["cold"] = jax.eval_shape(
                    self.engine.program("plan", env0),
                    *self.engine.program_args("plan", env0))
            if want == "warm":
                self._state_avals["warm"] = jax.eval_shape(
                    self.engine.program("replan", env0),
                    *self.engine.program_args(
                        "replan", env0, prev=self._state_avals["cold"]))
        return self._state_avals[want]

    def serving_state(self) -> tuple[dict, dict]:
        """The loop's complete episode state as ``(device_tree, host)``.

        ``device_tree`` holds every device-resident pytree the epoch program
        and the planner thread through epochs (PRNG base key, served plan,
        fault rates, scenario/stream/batch/QoS/telemetry/fault state, the
        server's PlanState + GD-iteration accumulator). ``host`` holds the
        JSON-scalar control-plane state (epoch clock, server counters,
        ladder state machine). Restoring both via load_serving_state makes
        the next epoch bit-identical to the uninterrupted run: all per-epoch
        randomness is fold_in(base_key, epoch), and every host decision is a
        deterministic function of the restored counters.

        A rejected-first-plan server (state None) snapshots a zero-filled
        cold-shaped PlanState with ``plan_state_kind == "none"`` so the
        device treedef stays constant across snapshot kinds."""
        if self._st is None:
            raise RuntimeError("serving_state() before reset()")
        if self.server.state is not None:
            ps = self.server.state
            kind = "warm" if ps.warm_rho is not None else "cold"
        else:
            kind = "none"
            ps = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype),
                              self._plan_state_avals("none"))
        device = {
            "key": self._key, "plan": self._plan, "rates": self._rates,
            "sc": self._sc, "st": self._st, "bt": self._bt, "qs": self._qs,
            "tel": self._tel, "fs": self._fs,
            "server_state": ps, "iters_acc": self.server._iters_acc,
        }
        host = {
            "host_epoch": self.host_epoch,
            "plan_state_kind": kind,
            "server": self.server.export_host(),
            "ladder": (self.ladder.export_state()
                       if self.ladder is not None else None),
        }
        return device, host

    def state_template(self, kind: str):
        """Avals (ShapeDtypeStructs) of serving_state()'s device tree for a
        snapshot whose PlanState treedef kind was ``kind`` -- the
        restore-side validation target. Built from the live episode state
        plus eval_shape of the engine programs, so any stored leaf that
        fails to match these avals is exactly a leaf that would have
        retraced the (already compiled) epoch or planner programs."""
        device, _ = self.serving_state()
        device["server_state"] = self._plan_state_avals(kind)
        return jax.tree.map(
            lambda x: (x if isinstance(x, jax.ShapeDtypeStruct)
                       else jax.ShapeDtypeStruct(jnp.shape(x),
                                                 jnp.result_type(x))),
            device)

    def load_serving_state(self, device: dict, host: dict) -> None:
        """Overwrite the episode with a restored serving_state(). The loop
        must be reset() first (the compiled programs, templates and warmed
        fallback come from reset; the snapshot supplies only state)."""
        if self._st is None:
            raise RuntimeError("load_serving_state() before reset()")
        self._key = device["key"]
        self._plan = device["plan"]
        self._rates = device["rates"]
        self._sc = device["sc"]
        self._st = device["st"]
        self._bt = device["bt"]
        self._qs = device["qs"]
        self._tel = device["tel"]
        self._fs = device["fs"]
        self.host_epoch = int(host["host_epoch"])
        self.server.import_host(host["server"], device["iters_acc"])
        self.server.state = (None if host["plan_state_kind"] == "none"
                             else device["server_state"])
        if self.ladder is not None and host["ladder"] is not None:
            self.ladder.import_state(host["ladder"])

    def config_fingerprint(self) -> str:
        """Hash of everything that shapes the compiled programs and the host
        policy. A snapshot taken under one configuration must not restore
        into a loop built under another (the restored leaves would hit
        different programs); fault *rates* are excluded -- they are operands
        and travel inside the snapshot."""
        parts = repr((self.scenario.cfg, self.stream_cfg, self.service_cfg,
                      self.qos_cfg, self.engine.cfg, self.engine.method,
                      self.engine.rounding, self.engine.warm_rho_min,
                      self.engine.warm_moment_decay,
                      self.ladder.cfg if self.ladder is not None else None,
                      self.feedback))
        return hashlib.sha256(parts.encode()).hexdigest()[:16]

    def _step_epoch_inner(self) -> tuple[EpochOut, bool]:
        (self._sc, self._st, self._bt, self._qs, self._tel, self._fs,
         out) = self._epoch(self._key, self._plan, self._rates, self._sc,
                            self._st, self._bt, self._qs, self._tel,
                            self._fs)
        trigger = bool(out.report.trigger)   # the per-epoch decision sync
        if self.ladder is None:
            prof = self.measured_profile() if self.feedback else None
            self.server.observe(out.env, prof=prof, force=trigger)
            self._plan = self.server.state.plan
            return out, trigger
        # Hardened path: one extra scalar (the packed health word) feeds
        # the ladder; the ladder shapes the replan and the served plan.
        dec = self.ladder.pre_replan(int(out.health))
        if dec.force_cold:
            self.server.reset_warm()
        prof = (self.measured_profile()
                if self.feedback and dec.use_measured else None)
        self.server.observe(out.env, prof=prof,
                            force=trigger or dec.force, hold=dec.hold)
        self.ladder.post_replan(self.server.last_plan_ok,
                                self.server.last_replanned)
        if self.server.state is None or self.ladder.serve_fallback:
            self._plan = self._fallback(out.env)
        else:
            self._plan = self.server.state.plan
        return out, trigger

    def step_epoch(self) -> tuple[EpochOut, bool]:
        """One closed-loop epoch. Returns the device-resident EpochOut and
        whether a QoS trigger forced an off-schedule replan (the host-side
        decision read). Hardened loops run under the epoch watchdog: an
        overrun keeps its result (state stays consistent) but escalates
        the ladder. Advances the host epoch clock and, with a flight
        recorder attached, journals the epoch's host trace."""
        if self._watchdog is None:
            out, trigger = self._step_epoch_inner()
        else:
            (out, trigger), fired = self._watchdog.guard(
                self._step_epoch_inner)
            if fired and self.ladder is not None:
                self.ladder.on_timeout()
        self.host_epoch += 1
        if self._recorder is not None:
            self._recorder.record_epoch(
                self.host_epoch, s=int(self._plan.s), health=int(out.health),
                trigger=bool(trigger),
                stage=self.ladder.stage if self.ladder is not None
                else "normal")
        return out, trigger

    def run(self, key: jax.Array, n_epochs: int,
            record: bool = False) -> dict:
        """Drive a fresh episode for ``n_epochs``. With record=True, per-
        epoch scalars are pulled to host for analysis (benchmark mode; the
        steady-state no-transfer property is audited with record=False).
        Returns summary metrics (and, when recording, the trajectory)."""
        self.reset(key)
        hist = self.history_init()
        for _ in range(n_epochs):
            out, trigger = self.step_epoch()
            if record:
                self.record_history(hist, out, trigger)
        m = self.metrics()
        if record:
            m["history"] = hist
        return m

    def history_init(self) -> dict[str, list]:
        """An empty per-epoch trajectory dict (run()'s record=True columns).
        The crash supervisor shares these helpers so a recovered episode's
        history is column-compatible with an uninterrupted run's."""
        return {k: [] for k in
                ("s", "p50", "p95", "miss_rate", "occupancy", "backlog",
                 "completed", "congestion", "trigger", "health", "faulted",
                 "plan_finite", "stage")}

    def record_history(self, hist: dict[str, list], out: EpochOut,
                       trigger: bool) -> None:
        """Append one epoch's host-visible scalars to ``hist``."""
        hist["s"].append(int(self._plan.s))
        hist["p50"].append(float(out.report.p50))
        hist["p95"].append(float(out.report.p95))
        hist["miss_rate"].append(float(out.report.miss_rate))
        hist["occupancy"].append(int(out.occupancy))
        hist["backlog"].append(int(out.backlog))
        hist["completed"].append(int(out.completed))
        hist["congestion"].append(float(out.congestion))
        hist["trigger"].append(bool(trigger))
        hist["health"].append(int(out.health))
        hist["faulted"].append(int(out.faulted))
        # Was the plan on the air this epoch finite? The chaos
        # benchmark's "no NaN plans served" gate reads this.
        hist["plan_finite"].append(bool(jnp.isfinite(self._plan.utility)))
        hist["stage"].append(self.ladder.stage if self.ladder
                             else "normal")

    def metrics(self) -> dict:
        """End-of-episode summary. Syncs the episode counters once."""
        m = dict(self.server.metrics())
        m.update({
            "offered": int(self._st.offered),
            "completed": int(self._bt.completed),
            "dropped": int(self._bt.dropped),
            "shed": int(self._bt.shed),
            "served": int(self._qs.served),
            "deadline_missed": int(self._qs.missed),
            "goodput": int(self._qs.good),
            "qos_triggers": int(self._qs.triggers),
            "epochs": int(self._st.epoch),
            "duration_s": float(self._st.epoch) * self.stream_cfg.epoch_dt_s,
        })
        dur = max(m["duration_s"], 1e-9)
        m["requests_per_s"] = m["completed"] / dur
        m["offered_per_s"] = m["offered"] / dur
        m["goodput_per_s"] = m["goodput"] / dur
        if self.ladder is not None:
            m.update(self.ladder.metrics())
        return m
