"""The closed loop: streams -> batcher -> telemetry -> QoS -> planner.

One epoch of online serving is ONE compiled program (`kind "online_epoch"`
in planning.compile_log) plus one host decision point:

  device (compiled, state donated in place):
    1. scenario.step/env      -- mobility + fading advance, env materializes
    2. streams.stream_step    -- per-user Poisson arrivals for the epoch
    3. service model          -- per-user end-to-end seconds under the
                                 *current* plan and the measured edge
                                 congestion (occupancy + backlog inflate the
                                 suffix compute), plus the per-layer
                                 Observation the telemetry folds in
    4. batcher enqueue/admit/tick -- continuous batching; completions out
    5. qos_update             -- percentiles, miss EMAs, trigger bool
    6. telemetry_update       -- measured profile EMA

  host (per epoch):
    - read the QoS trigger (one scalar sync, the loop's decision point)
    - OnlineSplitServer.observe(env, prof=measured, force=trigger): replan
      on schedule or on trigger; its one sync is s* (the re-cut decision)

Because the plan enters the epoch program as a SplitPlan operand and the
measured profile enters the planner as a ModelProfile operand (same avals
every epoch -- planning._strong_typed + ModelProfile.like), a steady-state
episode compiles each program exactly once and moves no arrays to host
beyond the two decision scalars. Both properties are machine-checked:
planning.compile_log in tests, repro.analysis.online_audit in CI.

The service model is where the closed loop earns its keep: the edge's
effective speed degrades with load (`1 + load_gain * (occupancy + backlog)
/ capacity`), which the *static* profile cannot see. The telemetry
attributes the inflated suffix times back into effective FLOPs, the
measured profile makes the planner price edge compute honestly, and s*
rises (keep more layers on device) exactly when the edge saturates --
the requests/sec-vs-concurrency benchmark (benchmarks/online_serve.py)
demonstrates the divergence from the static-profile plan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import channel
from repro.core.types import Array, ModelProfile, SplitPlan, lam
from repro.planning.engine import _recorded
from repro.runtime.serve import OnlineSplitServer
from repro.online import batcher as batcherlib
from repro.online.batcher import BatchState, ContinuousBatcher
from repro.online.qos import QosConfig, QosMonitor, QosReport, QosState, qos_update
from repro.online.streams import RequestStream, StreamConfig, StreamState, stream_step
from repro.online.telemetry import (
    Observation,
    Telemetry,
    TelemetryState,
    telemetry_update,
)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Edge service knobs. ``edge_capacity`` is the continuous batch size B;
    ``queue_depth`` the admission ring; ``load_gain`` how hard contention
    degrades the edge (effective suffix cost scales by ``1 + load_gain *
    (occupancy + backlog) / capacity`` -- 0 makes the edge ideal and the
    closed loop converges to the static plan); ``replan_every`` the
    scheduled replan cadence in epochs; ``max_work_epochs`` caps one
    request's slot occupancy."""

    edge_capacity: int = 8
    queue_depth: int = 32
    load_gain: float = 0.0
    replan_every: int = 10
    telemetry_decay: float = 0.9
    max_work_epochs: int = 1000


class EpochOut(NamedTuple):
    """Device-resident per-epoch outputs handed back to the host loop."""

    env: object          # NetworkEnv of the new epoch (the replan operand)
    report: QosReport
    counts: Array        # (U,) arrivals this epoch
    completed: Array     # () int32 completions this epoch
    occupancy: Array     # () int32 active slots after the tick
    backlog: Array       # () int32 queued requests after the tick
    congestion: Array    # () f32 edge slowdown factor used this epoch


class OnlineLoop:
    """Closed-loop serving over one time-evolving scenario.

    feedback=True plans against the telemetry's measured profile;
    feedback=False is the open-loop control (static profile), same epochs,
    same traffic -- the benchmark's comparison arm."""

    def __init__(self, scenario, engine, stream_cfg: StreamConfig,
                 service_cfg: ServiceConfig = ServiceConfig(),
                 qos_cfg: QosConfig | None = None,
                 model=None, params=None, feedback: bool = True):
        u = scenario.cfg.n_users
        self.scenario = scenario
        self.engine = engine
        self.stream_cfg = stream_cfg
        self.service_cfg = service_cfg
        self.qos_cfg = qos_cfg or QosConfig(deadline_s=stream_cfg.deadline_s)
        self.feedback = bool(feedback)
        self.stream = RequestStream(stream_cfg, u)
        self.batcher = ContinuousBatcher(
            service_cfg.edge_capacity, service_cfg.queue_depth,
            stream_cfg.max_per_user_epoch)
        self.qos = QosMonitor(self.qos_cfg, u)
        self.telemetry = Telemetry(engine.prof, scenario.cfg.comp,
                                   service_cfg.telemetry_decay)
        self.server = OnlineSplitServer(engine, model, params,
                                        replan_every=service_cfg.replan_every)
        # episode state (device pytrees), populated by reset()
        self._sc = self._st = self._bt = self._qs = self._tel = None
        self._plan: SplitPlan | None = None
        self._key: jax.Array | None = None

    # -- the compiled epoch program ---------------------------------------
    def _service_and_observation(self, env, plan: SplitPlan,
                                 congestion: Array):
        """Per-user modeled service seconds + the telemetry Observation,
        both priced at the *discrete* plan (one-hot subchannels, planned
        powers/compute units) with the measured congestion inflating the
        edge suffix. The static profile is the simulator's ground truth."""
        prof, comp = self.engine.prof, self.scenario.cfg.comp
        s = plan.s
        pre = prof.prefix_flops()[s]
        suf = prof.suffix_flops()[s]
        beta_up = jax.nn.one_hot(plan.sub_up, env.n_sub, dtype=env.g_up.dtype)
        beta_dn = jax.nn.one_hot(plan.sub_dn, env.n_sub, dtype=env.g_up.dtype)
        r_up = jnp.maximum(
            jnp.sum(channel.uplink_rates(env, beta_up, plan.p_up), -1), 1e-9)
        r_dn = jnp.maximum(
            jnp.sum(channel.downlink_rates(env, beta_dn, plan.p_dn), -1), 1e-9)
        speed_edge = lam(plan.r, comp) * comp.c_min_edge
        t_dev = pre / comp.c_device
        t_up = prof.w[s] / r_up
        t_edge = suf * congestion / speed_edge
        t_dn = prof.m_down[s] / r_dn
        service = t_dev + t_up + t_edge + t_dn                     # (U,)

        f = prof.n_layers
        r_mean = jnp.mean(plan.r)
        on_device = jnp.arange(f) < s
        t_layer = jnp.where(
            on_device, prof.fl / comp.c_device,
            prof.fl * congestion / (lam(r_mean, comp) * comp.c_min_edge))
        rate_mean = jnp.mean(r_up)
        obs = Observation(t_layer=t_layer,
                          t_up=prof.w[s] / rate_mean,
                          rate_up=rate_mean,
                          rate_dn=jnp.mean(r_dn),
                          r_units=r_mean)
        return service, obs

    @functools.cached_property
    def _epoch(self):
        scen, svc = self.scenario, self.service_cfg
        stream_cfg, qos_cfg = self.stream_cfg, self.qos_cfg
        comp_consts = scen.cfg.comp
        dt = stream_cfg.epoch_dt_s
        cap = float(svc.edge_capacity)
        n_users = scen.cfg.n_users

        def epoch(base_key, plan: SplitPlan, sc, st: StreamState,
                  bt: BatchState, qs: QosState, tel: TelemetryState):
            k_sc = jax.random.fold_in(jax.random.fold_in(base_key, st.epoch),
                                      1)
            sc = scen.step(k_sc, sc)
            env = scen.env(sc)
            st, counts = stream_step(stream_cfg, n_users, base_key, st)
            # Congestion from the load the edge is already carrying when
            # this epoch's work lands.
            load = (batcherlib.occupancy(bt) + batcherlib.backlog(bt)
                    ).astype(jnp.float32)
            congestion = 1.0 + svc.load_gain * load / cap
            service, obs = self._service_and_observation(env, plan,
                                                         congestion)
            work = jnp.clip(jnp.ceil(service / dt).astype(jnp.int32), 1,
                            svc.max_work_epochs)
            now = st.epoch.astype(jnp.float32) * dt
            bt = batcherlib.enqueue(bt, counts, now,
                                    stream_cfg.max_per_user_epoch)
            bt = batcherlib.admit(bt, now, service, work)
            bt, comps = batcherlib.tick(bt)
            qs, report = qos_update(qos_cfg, qs, comps)
            tel = telemetry_update(comp_consts, svc.telemetry_decay,
                                   self.engine.prof.fl, tel, plan.s, obs)
            out = EpochOut(env=env, report=report, counts=counts,
                           completed=jnp.sum(comps.valid).astype(jnp.int32),
                           occupancy=batcherlib.occupancy(bt),
                           backlog=batcherlib.backlog(bt),
                           congestion=congestion)
            return sc, st, bt, qs, tel, out

        # _recorded: each trace of the epoch program logs "online_epoch" to
        # planning.compile_log sinks -- the steady-state compile-once
        # property is asserted against this, exactly like the engine kinds.
        return jax.jit(_recorded(epoch, "online_epoch"),
                       donate_argnums=(2, 3, 4, 5, 6))

    # -- episode driving ---------------------------------------------------
    def reset(self, key: jax.Array) -> None:
        """Initialize scenario/stream/batch/QoS/telemetry state and take the
        initial (cold) plan. The telemetry starts at the static profile, so
        feedback and static arms are identical until load appears."""
        k_sc, k_st, self._key = jax.random.split(key, 3)
        self._sc = self.scenario.init(k_sc)
        self._st = self.stream.init(k_st)
        self._bt = self.batcher.init()
        self._qs = self.qos.init()
        self._tel = self.telemetry.init()
        env0 = self.scenario.env(self._sc)
        self.server.observe(env0)          # epoch 0 is always scheduled
        self._plan = self.server.state.plan

    def measured_profile(self) -> ModelProfile:
        """The telemetry's current measured profile (a planner operand)."""
        return self.telemetry.profile(self._tel)

    def step_epoch(self) -> tuple[EpochOut, bool]:
        """One closed-loop epoch. Returns the device-resident EpochOut and
        whether a QoS trigger forced an off-schedule replan (the host-side
        decision read)."""
        (self._sc, self._st, self._bt, self._qs, self._tel,
         out) = self._epoch(self._key, self._plan, self._sc, self._st,
                            self._bt, self._qs, self._tel)
        trigger = bool(out.report.trigger)   # the per-epoch decision sync
        prof = self.measured_profile() if self.feedback else None
        self.server.observe(out.env, prof=prof, force=trigger)
        self._plan = self.server.state.plan
        return out, trigger

    def run(self, key: jax.Array, n_epochs: int,
            record: bool = False) -> dict:
        """Drive a fresh episode for ``n_epochs``. With record=True, per-
        epoch scalars are pulled to host for analysis (benchmark mode; the
        steady-state no-transfer property is audited with record=False).
        Returns summary metrics (and, when recording, the trajectory)."""
        self.reset(key)
        hist: dict[str, list] = {k: [] for k in
                                 ("s", "p50", "p95", "miss_rate", "occupancy",
                                  "backlog", "completed", "congestion",
                                  "trigger")}
        for _ in range(n_epochs):
            out, trigger = self.step_epoch()
            if record:
                hist["s"].append(int(self._plan.s))
                hist["p50"].append(float(out.report.p50))
                hist["p95"].append(float(out.report.p95))
                hist["miss_rate"].append(float(out.report.miss_rate))
                hist["occupancy"].append(int(out.occupancy))
                hist["backlog"].append(int(out.backlog))
                hist["completed"].append(int(out.completed))
                hist["congestion"].append(float(out.congestion))
                hist["trigger"].append(bool(trigger))
        m = self.metrics()
        if record:
            m["history"] = hist
        return m

    def metrics(self) -> dict:
        """End-of-episode summary. Syncs the episode counters once."""
        m = dict(self.server.metrics())
        m.update({
            "offered": int(self._st.offered),
            "completed": int(self._bt.completed),
            "dropped": int(self._bt.dropped),
            "served": int(self._qs.served),
            "deadline_missed": int(self._qs.missed),
            "qos_triggers": int(self._qs.triggers),
            "epochs": int(self._st.epoch),
            "duration_s": float(self._st.epoch) * self.stream_cfg.epoch_dt_s,
        })
        dur = max(m["duration_s"], 1e-9)
        m["requests_per_s"] = m["completed"] / dur
        m["offered_per_s"] = m["offered"] / dur
        return m
