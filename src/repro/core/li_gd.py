"""The Loop-iteration Gradient Descent (Li-GD) optimizer — paper Table I.

Design notes
------------
* The solver works in *normalized* coordinates: subchannel shares beta live on
  the probability simplex (constraint 18.e/18.f) with a small floor beta_min;
  powers and compute units are mapped to [0, 1] via their boxes (18.c/18.d).
  Normalization makes a single scalar step size meaningful across variables
  with wildly different physical scales (Watts vs compute units); it is a
  reparameterization, not a change of the optimization problem.
* Gradients come from jax.grad of the utility (paper derives them by hand in
  eqs. 23-30; autodiff computes the same derivatives exactly).
* The per-split-point solve is a lax.while_loop with the paper's stopping
  rules (Table I lines 6/9): a gradient criterion, |Gamma_{k+1}-Gamma_k| <
  eps, or max variable change < eps, capped at max_iters. The gradient
  criterion is configurable (GdConfig.stop_rule): the paper's raw ||g|| < eps
  never fires at a *constrained* optimum (the gradient does not vanish on the
  simplex/box boundary, it only becomes normal to the feasible set), so the
  default is the projected-gradient residual ||x - P(x - alpha*g)|| / alpha,
  which is zero exactly at a KKT point of the constrained problem.
* Li-GD chains split points via lax.scan, warm-starting layer s+1 from the
  optimum of layer s (Table I lines 13-16). plain_gd is the cold-start
  baseline used to validate Corollary 4 (iteration-count reduction).
* Online (cross-epoch) warm starts can resume the Adam state: gd_solve
  accepts and returns the first/second moments and the cumulative step count
  (for bias correction), so a re-plan continues the optimizer trajectory
  instead of re-biasing from zero -- without this, sign-like early Adam steps
  near the previous optimum defeat early stopping and warm starts can *lose*
  to cold starts at moderate epoch-to-epoch correlation.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.utility import utility as _utility
from repro.core.types import (
    Array,
    EccWeights,
    GdConfig,
    GdVars,
    ModelProfile,
    NetworkEnv,
    SplitPlan,
)


# --------------------------------------------------------------------------
# projections
# --------------------------------------------------------------------------
def project_simplex(y: Array, total: float = 1.0) -> Array:
    """Euclidean projection of each row of y onto {x >= 0, sum x = total}."""
    m = y.shape[-1]
    u = jnp.sort(y, axis=-1)[..., ::-1]
    css = jnp.cumsum(u, axis=-1) - total
    idx = jnp.arange(1, m + 1, dtype=y.dtype)
    cond = (u - css / idx) > 0
    rho = jnp.maximum(jnp.sum(cond, axis=-1), 1)
    theta = jnp.take_along_axis(css, rho[..., None] - 1, axis=-1) / rho[..., None].astype(y.dtype)
    return jnp.maximum(y - theta, 0.0)


def project_simplex_floor(y: Array, floor: float) -> Array:
    """Projection onto {x >= floor, sum x = 1} (rows).

    The floored simplex is nonempty only when m * floor <= 1 (Corollary 1's
    feasibility condition beta_min <= 1/M). A larger floor is clamped to 1/m
    -- the set then degenerates to the single point x = ones/m -- instead of
    silently producing sum(x) != 1 from a negative residual budget."""
    m = y.shape[-1]
    f = jnp.minimum(jnp.asarray(floor, dtype=y.dtype), 1.0 / m)
    z = project_simplex(y - f, total=1.0 - m * f)
    return z + f


def _project(norm: dict, beta_min: float) -> dict:
    return {
        "beta_up": project_simplex_floor(norm["beta_up"], beta_min),
        "beta_dn": project_simplex_floor(norm["beta_dn"], beta_min),
        "p_up": jnp.clip(norm["p_up"], 0.0, 1.0),
        "p_dn": jnp.clip(norm["p_dn"], 0.0, 1.0),
        "r": jnp.clip(norm["r"], 0.0, 1.0),
    }


def to_physical(norm: dict, env: NetworkEnv) -> GdVars:
    rc, cc = env.radio, env.comp
    return GdVars(
        beta_up=norm["beta_up"],
        beta_dn=norm["beta_dn"],
        p_up=rc.p_up_min_w + norm["p_up"] * (rc.p_up_max_w - rc.p_up_min_w),
        p_dn=rc.p_dn_min_w + norm["p_dn"] * (rc.p_dn_max_w - rc.p_dn_min_w),
        r=cc.r_min + norm["r"] * (cc.r_max - cc.r_min),
    )


def cold_init(env: NetworkEnv) -> dict:
    """Table I line 1: start mid-box / uniform simplex, no prior knowledge."""
    u, m = env.n_users, env.n_sub
    one = jnp.ones((u, m)) / m
    half = jnp.full((u,), 0.5)
    return {"beta_up": one, "beta_dn": one, "p_up": half, "p_dn": half, "r": half}


# --------------------------------------------------------------------------
# online warm-gate: epoch-to-epoch channel correlation, traced in jax
# --------------------------------------------------------------------------
def rho_estimate(prev_gains: Array, gains: Array) -> Array:
    """Estimate the epoch-to-epoch fading correlation rho from two gain
    tensors of one scenario (vmap for fleets). For the Gauss-Markov process
    corr(|h_t|^2, |h_{t+1}|^2) = rho^2, so rho_hat = sqrt(clip(corr, 0, 1)).

    Pure jnp so the estimate lives *inside* the compiled replan program: the
    warm-vs-cold gate is selected on device and dispatch never syncs to host.
    Gains are path-loss scaled (~1e-12 at paper geometry), so both tensors
    are max-normalized before the correlation -- it is scale-invariant and
    this keeps the fp32 sums far from underflow."""
    a = prev_gains.reshape(-1).astype(jnp.float32)
    b = gains.reshape(-1).astype(jnp.float32)
    a = a / jnp.maximum(jnp.max(jnp.abs(a)), 1e-30)
    b = b / jnp.maximum(jnp.max(jnp.abs(b)), 1e-30)
    a = a - jnp.mean(a)
    b = b - jnp.mean(b)
    denom = jnp.sqrt(jnp.sum(a * a) * jnp.sum(b * b))
    corr = jnp.sum(a * b) / jnp.maximum(denom, 1e-30)
    return jnp.sqrt(jnp.clip(corr, 0.0, 1.0))


# --------------------------------------------------------------------------
# single-split-point projected GD (Table I lines 3-12)
# --------------------------------------------------------------------------
class GdResult(NamedTuple):
    norm: dict
    gamma: Array
    iters: Array
    grad_norm: Array
    mom: tuple       # final Adam moments (m1, m2) -- zeros when optimizer="sgd"
    opt_steps: Array # () int32 cumulative optimizer steps behind `mom`
                     # (init_steps + iters; drives Adam bias correction on resume)


def _tree_norm(t) -> Array:
    leaves = jax.tree_util.tree_leaves(t)
    return jnp.sqrt(sum(jnp.sum(x * x) for x in leaves))


def _tree_maxdiff(a, b) -> Array:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return jnp.max(jnp.stack([jnp.max(jnp.abs(x - y)) for x, y in zip(la, lb)]))


def gd_solve(
    env: NetworkEnv,
    prof: ModelProfile,
    s: Array,
    w: EccWeights,
    init_norm: dict,
    cfg: GdConfig,
    init_mom: tuple | None = None,
    init_steps: Array | None = None,
) -> GdResult:
    """Projected (Adam-)GD for one split point.

    init_mom/init_steps resume a previous solve's optimizer state (online
    warm restarts): the Adam moments keep their accumulated history and the
    bias correction continues from init_steps instead of restarting at t=1.
    """
    if cfg.stop_rule not in ("pgd", "raw"):
        raise ValueError(f"stop_rule must be 'pgd' or 'raw', got {cfg.stop_rule!r}")
    beta_min = env.radio.beta_min

    def gamma_fn(norm):
        return _utility(env, prof, s, to_physical(norm, env), w,
                        backend=cfg.sinr_backend)

    grad_fn = jax.value_and_grad(gamma_fn)
    adam = cfg.optimizer == "adam"
    steps0 = jnp.int32(0) if init_steps is None else init_steps.astype(jnp.int32)

    def cond(state):
        _, _, _, it, done = state
        return jnp.logical_and(it < cfg.max_iters, jnp.logical_not(done))

    def body(state):
        norm, mom, gamma_prev, it, _ = state
        gamma, g = grad_fn(norm)
        if adam:
            m1, m2 = mom
            m1 = jax.tree.map(lambda a, b: cfg.adam_b1 * a + (1 - cfg.adam_b1) * b, m1, g)
            m2 = jax.tree.map(lambda a, b: cfg.adam_b2 * a + (1 - cfg.adam_b2) * b * b, m2, g)
            t = (steps0 + it + 1).astype(jnp.float32)
            step = jax.tree.map(
                lambda a, b: cfg.step_size
                * (a / (1 - cfg.adam_b1**t))
                / (jnp.sqrt(b / (1 - cfg.adam_b2**t)) + 1e-8),
                m1,
                m2,
            )
            mom = (m1, m2)
        else:
            step = jax.tree.map(lambda x: cfg.step_size * x, g)
        new = _project(jax.tree.map(lambda a, b: a - b, norm, step), beta_min)
        gamma_new = gamma_fn(new)
        if cfg.stop_rule == "pgd":
            # Projected-gradient residual: the raw-gradient probe step is
            # independent of the optimizer, so Adam's rescaled steps cannot
            # mask (or fake) convergence on the constraint boundary.
            probe = new if not adam else _project(
                jax.tree.map(lambda a, b: a - cfg.step_size * b, norm, g), beta_min)
            gcrit = _tree_norm(jax.tree.map(lambda a, b: a - b, norm, probe))
            gcrit = gcrit / cfg.step_size
        else:
            gcrit = _tree_norm(g)
        done = jnp.logical_or(
            gcrit < cfg.eps,
            jnp.logical_or(
                jnp.abs(gamma_new - gamma) < cfg.eps * jnp.maximum(1.0, jnp.abs(gamma)),
                _tree_maxdiff(new, norm) < cfg.eps,
            ),
        )
        return new, mom, gamma_new, it + 1, done

    zero_mom = (
        jax.tree.map(jnp.zeros_like, init_norm),
        jax.tree.map(jnp.zeros_like, init_norm),
    )
    mom0 = zero_mom if init_mom is None else init_mom
    norm0 = _project(init_norm, beta_min)
    state0 = (norm0, mom0, gamma_fn(norm0), jnp.int32(0), jnp.bool_(False))
    norm, mom, gamma, it, _ = jax.lax.while_loop(cond, body, state0)
    _, g = grad_fn(norm)
    return GdResult(norm=norm, gamma=gamma, iters=it, grad_norm=_tree_norm(g),
                    mom=mom, opt_steps=steps0 + it)


# --------------------------------------------------------------------------
# split-point loop (Table I), unified over warm-start policies
# --------------------------------------------------------------------------
class LoopResult(NamedTuple):
    gammas: Array      # (F+1,)
    iters: Array       # (F+1,)
    norms: dict        # stacked per-split optima, leaves lead with (F+1, ...)
    total_iters: Array
    moms: tuple        # stacked per-split Adam moments (m1, m2), leaves (F+1, ...)
    opt_steps: Array   # (F+1,) int32 cumulative optimizer steps per split
    used_warm: Array   # (F+1,) bool: split started from the cross-epoch state


def gd_loop(
    env: NetworkEnv,
    prof: ModelProfile,
    w: EccWeights,
    cfg: GdConfig,
    *,
    chain: bool = True,
    warm: dict | None = None,
    warm_mom: tuple | None = None,
    warm_steps: Array | None = None,
    use_warm: Array | bool = True,
) -> LoopResult:
    """Solve all F+1 split points with one warm-start policy.

    chain=True,  warm=None  -- paper Li-GD (Table I lines 13-16): split s+1
                               starts from split s's optimum.
    chain=False, warm=None  -- plain GD: every split starts from cold_init
                               (the paper's 'traditional GD' baseline).
    warm=stacked norms      -- online mode (leaves lead with (F+1, ...)):
                               warm[s] is the previous *epoch's* optimum at
                               split s. Each split starts from the BETTER of
                               warm[s] and the Li-GD chain carry (split s-1's
                               fresh optimum), judged by one extra utility
                               evaluation: under high epoch-to-epoch
                               correlation the temporal start is near-optimal
                               and stops almost immediately, while a stale
                               start (channel moved) silently degrades to the
                               paper's chain -- so online mode is never worse
                               than a cold Li-GD sweep. warm_mom / warm_steps
                               resume the per-split Adam moments and
                               bias-correction step counts (from a previous
                               LoopResult.moms/opt_steps) whenever the
                               temporal start is chosen, so the optimizer
                               continues its trajectory instead of re-biasing
                               from zero; the chain start always uses fresh
                               moments, matching Table I.
    use_warm (warm mode)    -- scalar bool (traced OK; vmap it for per-member
                               fleet selection): False disables the temporal
                               starts entirely, making the solve *exactly*
                               the paper's chained Li-GD. The engine's
                               rho-adaptive selector drives this.

    The returned moms/opt_steps always carry each split's final optimizer
    state for the next epoch's resume.
    """
    splits = jnp.arange(prof.n_layers + 1, dtype=jnp.int32)
    init = cold_init(env)

    if warm is not None:
        if warm_mom is None:
            warm_mom = (jax.tree.map(jnp.zeros_like, warm),
                        jax.tree.map(jnp.zeros_like, warm))
        if warm_steps is None:
            warm_steps = jnp.zeros_like(splits)
        use_warm = jnp.asarray(use_warm, dtype=bool)
        beta_min = env.radio.beta_min

        def step(carry_norm, xs):
            s, w0, m1, m2, st0 = xs

            def gamma_at(n):
                return _utility(env, prof, s, to_physical(n, env), w,
                                backend=cfg.sinr_backend)

            pick_warm = jnp.logical_and(use_warm,
                                        gamma_at(w0) <= gamma_at(carry_norm))
            sel = lambda a, b: jnp.where(pick_warm, a, b)
            start = jax.tree.map(sel, w0, carry_norm)
            mom0 = jax.tree.map(lambda x: jnp.where(pick_warm, x, 0.0),
                                (m1, m2))
            res = gd_solve(env, prof, s, w, start, cfg, init_mom=mom0,
                           init_steps=jnp.where(pick_warm, st0, 0))
            return res.norm, (res.gamma, res.iters, res.norm, res.mom,
                              res.opt_steps, pick_warm)

        init = _project(init, beta_min)
        _, (gammas, iters, norms, moms, opt_steps, used_warm) = jax.lax.scan(
            step, init, (splits, warm, warm_mom[0], warm_mom[1], warm_steps))
    else:
        def step(carry_norm, s):
            res = gd_solve(env, prof, s, w, carry_norm, cfg)
            return (res.norm if chain else carry_norm), (
                res.gamma, res.iters, res.norm, res.mom, res.opt_steps)

        _, (gammas, iters, norms, moms, opt_steps) = jax.lax.scan(
            step, init, splits)
        used_warm = jnp.zeros_like(splits, dtype=bool)
    return LoopResult(gammas=gammas, iters=iters, norms=norms,
                      total_iters=jnp.sum(iters), moms=moms,
                      opt_steps=opt_steps, used_warm=used_warm)


def li_gd_loop(
    env: NetworkEnv, prof: ModelProfile, w: EccWeights, cfg: GdConfig
) -> LoopResult:
    return gd_loop(env, prof, w, cfg, chain=True)


def plain_gd_loop(
    env: NetworkEnv, prof: ModelProfile, w: EccWeights, cfg: GdConfig
) -> LoopResult:
    """Cold-start GD per split point (the paper's 'traditional GD' baseline)."""
    return gd_loop(env, prof, w, cfg, chain=False)


# --------------------------------------------------------------------------
# rounding (Table I lines 17-20 + Corollary 5) and plan assembly
# --------------------------------------------------------------------------
def round_beta(beta: Array, paper_rule: bool = True) -> tuple[Array, Array, Array]:
    """Paper rule: beta > 0.5 -> 1 else 0. Returns (onehot, chosen, violations).

    When the 0.5-rule breaks constraint (18.e) (no entry > 0.5 -- possible
    since rows live on the simplex), we repair with argmax and count it."""
    if paper_rule:
        hard = (beta > 0.5).astype(beta.dtype)
        viol = jnp.sum(jnp.abs(jnp.sum(hard, axis=-1) - 1.0) > 0.5)
    else:
        viol = jnp.zeros((), beta.dtype)
    chosen = jnp.argmax(beta, axis=-1).astype(jnp.int32)
    onehot = jax.nn.one_hot(chosen, beta.shape[-1], dtype=beta.dtype)
    return onehot, chosen, viol


def greedy_round_up(env: NetworkEnv, beta: Array, p: Array) -> Array:
    """Load-aware sequential rounding (beyond-paper; see EXPERIMENTS §Perf).

    At high SINR log2(1+SINR) compresses channel differences, so the relaxed
    optimum is interior (near-uniform beta) and both the paper's 0.5-rule and
    naive argmax collapse users onto one channel. Greedy: assign users one by
    one to the subchannel maximizing their SINR given interference from the
    users already assigned."""
    own = env.own_gain_up()                          # (U, M)

    def step(assigned_interf, u):
        # assigned_interf: (U, M) interference each user would see at its AP
        sinr = p[u] * own[u] / (assigned_interf[u] + env.noise_up)
        m = jnp.argmax(beta[u] * jnp.log1p(sinr))
        # gain of user u at every other user's AP, gathered per scan step:
        # (U, M) at rest, never the full (U, U, M) pairwise tensor (the
        # analysis.NoGatherAbove rule gates the whole plan program on this).
        g_at_u = jnp.take(env.g_up, u, axis=0)[env.ap, :]
        add = p[u] * g_at_u * jax.nn.one_hot(m, env.n_sub)[None, :]
        return assigned_interf + add, m.astype(jnp.int32)

    init = jnp.zeros_like(own)
    _, subs = jax.lax.scan(step, init, jnp.arange(env.n_users))
    return subs


def greedy_round_dn(env: NetworkEnv, beta: Array, p: Array) -> Array:
    """Downlink analogue: interference at the *user* from other APs' tx."""
    own = env.own_gain_dn()                          # (U, M)
    g_all = jnp.swapaxes(env.g_dn, 0, 1)             # (U, N, M) AP->user gains
    cell = jax.nn.one_hot(env.ap, env.n_aps)         # (U, N)

    def step(ap_tx, u):
        # ap_tx: (N, M) power each AP already spends per subchannel.
        # Other-AP interference via a masked sum (no full-sum-minus-own-AP
        # subtraction: fp32-safe, matching the channel.py convention).
        interf = jnp.einsum("nm,nm,n->m", ap_tx, g_all[u], 1.0 - cell[u])
        sinr = p[u] * own[u] / (interf + env.noise_dn)
        m = jnp.argmax(beta[u] * jnp.log1p(sinr))
        add = p[u] * jnp.outer(cell[u], jax.nn.one_hot(m, env.n_sub))
        return ap_tx + add, m.astype(jnp.int32)

    _, subs = jax.lax.scan(step, jnp.zeros((env.n_aps, env.n_sub)),
                           jnp.arange(env.n_users))
    return subs


def assemble_plan(
    env: NetworkEnv, loop: LoopResult, prof: ModelProfile,
    rounding: str = "best", w: EccWeights | None = None,
    backend: str | None = None,
) -> SplitPlan:
    s_star = jnp.argmin(loop.gammas).astype(jnp.int32)
    best = jax.tree.map(lambda x: x[s_star], loop.norms)
    v = to_physical(best, env)
    _, sub_up, viol_up = round_beta(v.beta_up)
    _, sub_dn, viol_dn = round_beta(v.beta_dn)
    if rounding in ("greedy", "best"):
        g_up = greedy_round_up(env, v.beta_up, v.p_up)
        g_dn = greedy_round_dn(env, v.beta_dn, v.p_dn)
        if rounding == "greedy":
            sub_up, sub_dn = g_up, g_dn
        else:
            # best-of: evaluate the discrete utility under both roundings
            # (beyond-paper; the paper's 0.5-rule is kept for Cor.5 metrics).
            assert w is not None

            def disc_util(su, sd):
                vv = GdVars(
                    beta_up=jax.nn.one_hot(su, env.n_sub),
                    beta_dn=jax.nn.one_hot(sd, env.n_sub),
                    p_up=v.p_up, p_dn=v.p_dn, r=v.r,
                )
                return _utility(env, prof, s_star, vv, w, backend=backend)

            u_argmax = disc_util(sub_up, sub_dn)
            u_greedy = disc_util(g_up, g_dn)
            pick = (u_greedy < u_argmax)
            sub_up = jnp.where(pick, g_up, sub_up)
            sub_dn = jnp.where(pick, g_dn, sub_dn)
    return SplitPlan(
        s=s_star,
        sub_up=sub_up,
        sub_dn=sub_dn,
        p_up=v.p_up,
        p_dn=v.p_dn,
        r=v.r,
        utility=loop.gammas[s_star],
        per_layer_utility=loop.gammas,
        iters=loop.iters,
        rounding_violations=viol_up + viol_dn,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "method", "rounding"))
def solve(
    env: NetworkEnv,
    prof: ModelProfile,
    w: EccWeights,
    cfg: GdConfig = GdConfig(),
    method: str = "li_gd",
    rounding: str = "best",
) -> SplitPlan:
    if method not in ("li_gd", "gd"):
        raise KeyError(method)
    loop = gd_loop(env, prof, w, cfg, chain=(method == "li_gd"))
    return assemble_plan(env, loop, prof, rounding=rounding, w=w,
                         backend=cfg.sinr_backend)
