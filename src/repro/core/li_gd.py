"""The Loop-iteration Gradient Descent (Li-GD) optimizer — paper Table I.

Design notes
------------
* The solver works in *normalized* coordinates: subchannel shares beta live on
  the probability simplex (constraint 18.e/18.f) with a small floor beta_min;
  powers and compute units are mapped to [0, 1] via their boxes (18.c/18.d).
  Normalization makes a single scalar step size meaningful across variables
  with wildly different physical scales (Watts vs compute units); it is a
  reparameterization, not a change of the optimization problem.
* Gradients come from jax.grad of the utility (paper derives them by hand in
  eqs. 23-30; autodiff computes the same derivatives exactly).
* The per-split-point solve is a lax.while_loop with the paper's stopping
  rules (Table I lines 6/9): ||g|| < eps, |Gamma_{k+1}-Gamma_k| < eps, or
  max variable change < eps, capped at max_iters.
* Li-GD chains split points via lax.scan, warm-starting layer s+1 from the
  optimum of layer s (Table I lines 13-16). plain_gd is the cold-start
  baseline used to validate Corollary 4 (iteration-count reduction).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.utility import utility as _utility
from repro.core.types import (
    Array,
    EccWeights,
    GdConfig,
    GdVars,
    ModelProfile,
    NetworkEnv,
    SplitPlan,
)


# --------------------------------------------------------------------------
# projections
# --------------------------------------------------------------------------
def project_simplex(y: Array, total: float = 1.0) -> Array:
    """Euclidean projection of each row of y onto {x >= 0, sum x = total}."""
    m = y.shape[-1]
    u = jnp.sort(y, axis=-1)[..., ::-1]
    css = jnp.cumsum(u, axis=-1) - total
    idx = jnp.arange(1, m + 1, dtype=y.dtype)
    cond = (u - css / idx) > 0
    rho = jnp.maximum(jnp.sum(cond, axis=-1), 1)
    theta = jnp.take_along_axis(css, rho[..., None] - 1, axis=-1) / rho[..., None].astype(y.dtype)
    return jnp.maximum(y - theta, 0.0)


def project_simplex_floor(y: Array, floor: float) -> Array:
    """Projection onto {x >= floor, sum x = 1} (rows)."""
    m = y.shape[-1]
    z = project_simplex(y - floor, total=1.0 - m * floor)
    return z + floor


def _project(norm: dict, beta_min: float) -> dict:
    return {
        "beta_up": project_simplex_floor(norm["beta_up"], beta_min),
        "beta_dn": project_simplex_floor(norm["beta_dn"], beta_min),
        "p_up": jnp.clip(norm["p_up"], 0.0, 1.0),
        "p_dn": jnp.clip(norm["p_dn"], 0.0, 1.0),
        "r": jnp.clip(norm["r"], 0.0, 1.0),
    }


def to_physical(norm: dict, env: NetworkEnv) -> GdVars:
    rc, cc = env.radio, env.comp
    return GdVars(
        beta_up=norm["beta_up"],
        beta_dn=norm["beta_dn"],
        p_up=rc.p_up_min_w + norm["p_up"] * (rc.p_up_max_w - rc.p_up_min_w),
        p_dn=rc.p_dn_min_w + norm["p_dn"] * (rc.p_dn_max_w - rc.p_dn_min_w),
        r=cc.r_min + norm["r"] * (cc.r_max - cc.r_min),
    )


def cold_init(env: NetworkEnv) -> dict:
    """Table I line 1: start mid-box / uniform simplex, no prior knowledge."""
    u, m = env.n_users, env.n_sub
    one = jnp.ones((u, m)) / m
    half = jnp.full((u,), 0.5)
    return {"beta_up": one, "beta_dn": one, "p_up": half, "p_dn": half, "r": half}


# --------------------------------------------------------------------------
# single-split-point projected GD (Table I lines 3-12)
# --------------------------------------------------------------------------
class GdResult(NamedTuple):
    norm: dict
    gamma: Array
    iters: Array
    grad_norm: Array


def _tree_norm(t) -> Array:
    leaves = jax.tree_util.tree_leaves(t)
    return jnp.sqrt(sum(jnp.sum(x * x) for x in leaves))


def _tree_maxdiff(a, b) -> Array:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return jnp.max(jnp.stack([jnp.max(jnp.abs(x - y)) for x, y in zip(la, lb)]))


def gd_solve(
    env: NetworkEnv,
    prof: ModelProfile,
    s: Array,
    w: EccWeights,
    init_norm: dict,
    cfg: GdConfig,
) -> GdResult:
    beta_min = env.radio.beta_min

    def gamma_fn(norm):
        return _utility(env, prof, s, to_physical(norm, env), w)

    grad_fn = jax.value_and_grad(gamma_fn)
    adam = cfg.optimizer == "adam"

    def cond(state):
        _, _, _, it, done = state
        return jnp.logical_and(it < cfg.max_iters, jnp.logical_not(done))

    def body(state):
        norm, mom, gamma_prev, it, _ = state
        gamma, g = grad_fn(norm)
        gnorm = _tree_norm(g)
        if adam:
            m1, m2 = mom
            m1 = jax.tree.map(lambda a, b: cfg.adam_b1 * a + (1 - cfg.adam_b1) * b, m1, g)
            m2 = jax.tree.map(lambda a, b: cfg.adam_b2 * a + (1 - cfg.adam_b2) * b * b, m2, g)
            t = (it + 1).astype(jnp.float32)
            step = jax.tree.map(
                lambda a, b: cfg.step_size
                * (a / (1 - cfg.adam_b1**t))
                / (jnp.sqrt(b / (1 - cfg.adam_b2**t)) + 1e-8),
                m1,
                m2,
            )
            mom = (m1, m2)
        else:
            step = jax.tree.map(lambda x: cfg.step_size * x, g)
        new = _project(jax.tree.map(lambda a, b: a - b, norm, step), beta_min)
        gamma_new = gamma_fn(new)
        done = jnp.logical_or(
            gnorm < cfg.eps,
            jnp.logical_or(
                jnp.abs(gamma_new - gamma) < cfg.eps * jnp.maximum(1.0, jnp.abs(gamma)),
                _tree_maxdiff(new, norm) < cfg.eps,
            ),
        )
        return new, mom, gamma_new, it + 1, done

    zero_mom = (
        jax.tree.map(jnp.zeros_like, init_norm),
        jax.tree.map(jnp.zeros_like, init_norm),
    )
    norm0 = _project(init_norm, beta_min)
    state0 = (norm0, zero_mom, gamma_fn(norm0), jnp.int32(0), jnp.bool_(False))
    norm, _, gamma, it, _ = jax.lax.while_loop(cond, body, state0)
    _, g = grad_fn(norm)
    return GdResult(norm=norm, gamma=gamma, iters=it, grad_norm=_tree_norm(g))


# --------------------------------------------------------------------------
# split-point loop (Table I), unified over warm-start policies
# --------------------------------------------------------------------------
class LoopResult(NamedTuple):
    gammas: Array      # (F+1,)
    iters: Array       # (F+1,)
    norms: dict        # stacked per-split optima, leaves lead with (F+1, ...)
    total_iters: Array


def gd_loop(
    env: NetworkEnv,
    prof: ModelProfile,
    w: EccWeights,
    cfg: GdConfig,
    *,
    chain: bool = True,
    warm: dict | None = None,
) -> LoopResult:
    """Solve all F+1 split points with one warm-start policy.

    chain=True,  warm=None  -- paper Li-GD (Table I lines 13-16): split s+1
                               starts from split s's optimum.
    chain=False, warm=None  -- plain GD: every split starts from cold_init
                               (the paper's 'traditional GD' baseline).
    warm=stacked norms      -- online mode: split s starts from warm[s], the
                               previous *epoch's* optimum at the same split
                               (leaves lead with (F+1, ...)). Under correlated
                               fading this is the Li-GD trick applied across
                               time instead of across split points.
    """
    splits = jnp.arange(prof.n_layers + 1, dtype=jnp.int32)
    init = cold_init(env)

    if warm is not None:
        def step(carry, xs):
            s, w0 = xs
            res = gd_solve(env, prof, s, w, w0, cfg)
            return carry, (res.gamma, res.iters, res.norm)

        _, (gammas, iters, norms) = jax.lax.scan(step, 0, (splits, warm))
    else:
        def step(carry_norm, s):
            res = gd_solve(env, prof, s, w, carry_norm, cfg)
            return (res.norm if chain else carry_norm), (res.gamma, res.iters, res.norm)

        _, (gammas, iters, norms) = jax.lax.scan(step, init, splits)
    return LoopResult(gammas=gammas, iters=iters, norms=norms,
                      total_iters=jnp.sum(iters))


def li_gd_loop(
    env: NetworkEnv, prof: ModelProfile, w: EccWeights, cfg: GdConfig
) -> LoopResult:
    return gd_loop(env, prof, w, cfg, chain=True)


def plain_gd_loop(
    env: NetworkEnv, prof: ModelProfile, w: EccWeights, cfg: GdConfig
) -> LoopResult:
    """Cold-start GD per split point (the paper's 'traditional GD' baseline)."""
    return gd_loop(env, prof, w, cfg, chain=False)


# --------------------------------------------------------------------------
# rounding (Table I lines 17-20 + Corollary 5) and plan assembly
# --------------------------------------------------------------------------
def round_beta(beta: Array, paper_rule: bool = True) -> tuple[Array, Array, Array]:
    """Paper rule: beta > 0.5 -> 1 else 0. Returns (onehot, chosen, violations).

    When the 0.5-rule breaks constraint (18.e) (no entry > 0.5 -- possible
    since rows live on the simplex), we repair with argmax and count it."""
    if paper_rule:
        hard = (beta > 0.5).astype(beta.dtype)
        viol = jnp.sum(jnp.abs(jnp.sum(hard, axis=-1) - 1.0) > 0.5)
    else:
        viol = jnp.zeros((), beta.dtype)
    chosen = jnp.argmax(beta, axis=-1).astype(jnp.int32)
    onehot = jax.nn.one_hot(chosen, beta.shape[-1], dtype=beta.dtype)
    return onehot, chosen, viol


def greedy_round_up(env: NetworkEnv, beta: Array, p: Array) -> Array:
    """Load-aware sequential rounding (beyond-paper; see EXPERIMENTS §Perf).

    At high SINR log2(1+SINR) compresses channel differences, so the relaxed
    optimum is interior (near-uniform beta) and both the paper's 0.5-rule and
    naive argmax collapse users onto one channel. Greedy: assign users one by
    one to the subchannel maximizing their SINR given interference from the
    users already assigned."""
    own = env.own_gain_up()                          # (U, M)
    # gain of user v at user u's AP: (U_v, U_u, M)
    g_at = env.g_up[:, env.ap, :]

    def step(assigned_interf, u):
        # assigned_interf: (U, M) interference each user would see at its AP
        sinr = p[u] * own[u] / (assigned_interf[u] + env.noise_up)
        m = jnp.argmax(beta[u] * jnp.log1p(sinr))
        add = p[u] * g_at[u] * jax.nn.one_hot(m, env.n_sub)[None, :]
        return assigned_interf + add, m.astype(jnp.int32)

    init = jnp.zeros_like(own)
    _, subs = jax.lax.scan(step, init, jnp.arange(env.n_users))
    return subs


def greedy_round_dn(env: NetworkEnv, beta: Array, p: Array) -> Array:
    """Downlink analogue: interference at the *user* from other APs' tx."""
    own = env.own_gain_dn()                          # (U, M)
    g_all = jnp.swapaxes(env.g_dn, 0, 1)             # (U, N, M) AP->user gains
    cell = jax.nn.one_hot(env.ap, env.n_aps)         # (U, N)

    def step(ap_tx, u):
        # ap_tx: (N, M) power each AP already spends per subchannel
        interf = jnp.einsum("nm,nm->m", ap_tx, g_all[u]) - ap_tx[env.ap[u]] * own[u]
        interf = jnp.maximum(interf, 0.0)
        sinr = p[u] * own[u] / (interf + env.noise_dn)
        m = jnp.argmax(beta[u] * jnp.log1p(sinr))
        add = p[u] * jnp.outer(cell[u], jax.nn.one_hot(m, env.n_sub))
        return ap_tx + add, m.astype(jnp.int32)

    _, subs = jax.lax.scan(step, jnp.zeros((env.n_aps, env.n_sub)),
                           jnp.arange(env.n_users))
    return subs


def assemble_plan(
    env: NetworkEnv, loop: LoopResult, prof: ModelProfile,
    rounding: str = "best", w: EccWeights | None = None,
) -> SplitPlan:
    s_star = jnp.argmin(loop.gammas).astype(jnp.int32)
    best = jax.tree.map(lambda x: x[s_star], loop.norms)
    v = to_physical(best, env)
    _, sub_up, viol_up = round_beta(v.beta_up)
    _, sub_dn, viol_dn = round_beta(v.beta_dn)
    if rounding in ("greedy", "best"):
        g_up = greedy_round_up(env, v.beta_up, v.p_up)
        g_dn = greedy_round_dn(env, v.beta_dn, v.p_dn)
        if rounding == "greedy":
            sub_up, sub_dn = g_up, g_dn
        else:
            # best-of: evaluate the discrete utility under both roundings
            # (beyond-paper; the paper's 0.5-rule is kept for Cor.5 metrics).
            assert w is not None

            def disc_util(su, sd):
                vv = GdVars(
                    beta_up=jax.nn.one_hot(su, env.n_sub),
                    beta_dn=jax.nn.one_hot(sd, env.n_sub),
                    p_up=v.p_up, p_dn=v.p_dn, r=v.r,
                )
                return _utility(env, prof, s_star, vv, w)

            u_argmax = disc_util(sub_up, sub_dn)
            u_greedy = disc_util(g_up, g_dn)
            pick = (u_greedy < u_argmax)
            sub_up = jnp.where(pick, g_up, sub_up)
            sub_dn = jnp.where(pick, g_dn, sub_dn)
    return SplitPlan(
        s=s_star,
        sub_up=sub_up,
        sub_dn=sub_dn,
        p_up=v.p_up,
        p_dn=v.p_dn,
        r=v.r,
        utility=loop.gammas[s_star],
        per_layer_utility=loop.gammas,
        iters=loop.iters,
        rounding_violations=viol_up + viol_dn,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "method", "rounding"))
def solve(
    env: NetworkEnv,
    prof: ModelProfile,
    w: EccWeights,
    cfg: GdConfig = GdConfig(),
    method: str = "li_gd",
    rounding: str = "best",
) -> SplitPlan:
    if method not in ("li_gd", "gd"):
        raise KeyError(method)
    loop = gd_loop(env, prof, w, cfg, chain=(method == "li_gd"))
    return assemble_plan(env, loop, prof, rounding=rounding, w=w)
