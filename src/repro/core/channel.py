"""NOMA channel model: environment sampling, SINR and achievable rates.

Implements paper eqs. (5)-(10):
  * uplink SIC at the AP: stronger users decoded first, so user i is interfered
    by same-cell users on the same subchannel with *weaker* own-cell gain,
    plus all other-cell users transmitting on that subchannel (inter-cell),
    plus noise.
  * downlink SIC at the user: weaker users decode first; user i is interfered
    by same-cell users with *stronger* gain, plus other APs' transmissions on
    the subchannel.

The relaxed subchannel variable beta[u, m] in [0, 1] (rows sum to 1) scales both
the interference a user causes and the bandwidth share it gets, matching the
paper's relaxation (Corollary 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import (
    LOG2,
    Array,
    ComputeConstants,
    NetworkEnv,
    RadioConstants,
)

# SINR backend: 'einsum' is the XLA reference; 'pallas' routes the pairwise
# interference reductions through the cell-block kernels in
# repro.kernels.noma_rates (custom_vjp: forward AND backward stream blocked
# tiles, so the GD gradient path runs tiled at paper scale), falling back to
# interpret mode off-TPU; 'pallas_interpret' forces interpret mode. The
# kernels are GATHER-FREE: they consume the raw (U, N, M) channel state plus
# the int32 AP ids -- no g[:, ap, :] materialization, no same_cell mask
# input, no padded operand copies -- and their VMEM budget is O(BN),
# independent of the AP count. Passing a precomputed CellLayout
# (repro.kernels.cells.build_cell_layout, once per env) additionally
# restricts the intra/SIC grid to same-cell block-diagonal tiles:
# sum-of-cell-sizes^2 pairwise work instead of U^2, forward and backward.
# Both backends produce identical gradients to 1e-5
# (tests/test_grad_kernels.py, tests/test_cell_layout.py).
SINR_BACKENDS = ("einsum", "pallas", "pallas_interpret")
_SINR_BACKEND = "einsum"


def set_sinr_backend(backend: str) -> str:
    """Select the default SINR backend; returns the previous one.

    The global is resolved at *trace* time: programs already jitted keep the
    backend they were traced with (no retrace on switch). Inside long-lived
    jitted code, pass backend= explicitly instead of relying on the global."""
    global _SINR_BACKEND
    if backend not in SINR_BACKENDS:
        raise ValueError(f"backend must be one of {SINR_BACKENDS}, got {backend!r}")
    prev, _SINR_BACKEND = _SINR_BACKEND, backend
    return prev


def _pallas_interpret(backend: str) -> bool:
    return backend == "pallas_interpret" or jax.default_backend() != "tpu"


def make_env(
    key: jax.Array,
    n_users: int,
    n_aps: int,
    n_sub: int,
    radio: RadioConstants = RadioConstants(),
    comp: ComputeConstants = ComputeConstants(),
) -> NetworkEnv:
    """Sample user/AP positions and i.i.d. Rayleigh fading per subchannel."""
    k_ap, k_user, k_up, k_dn = jax.random.split(key, 4)
    side = radio.cell_radius_m * max(1.0, n_aps**0.5)
    ap_pos = jax.random.uniform(k_ap, (n_aps, 2), minval=0.0, maxval=side)
    user_pos = jax.random.uniform(k_user, (n_users, 2), minval=0.0, maxval=side)
    d = jnp.linalg.norm(user_pos[:, None, :] - ap_pos[None, :, :], axis=-1)
    d = jnp.maximum(d, 1.0)
    path = d ** (-radio.path_loss_exp)  # (U, N)
    # Rayleigh fading: |h|^2 ~ Exp(1), i.i.d. per (user, AP, subchannel).
    fad_up = jax.random.exponential(k_up, (n_users, n_aps, n_sub))
    fad_dn = jax.random.exponential(k_dn, (n_users, n_aps, n_sub))
    g_up = path[:, :, None] * fad_up
    g_dn = jnp.swapaxes(path[:, :, None] * fad_dn, 0, 1)  # (N, U, M)
    # Nearest-AP policy == maximum average channel gain (paper [48]).
    ap = jnp.argmax(path, axis=1).astype(jnp.int32)
    return NetworkEnv(g_up=g_up, g_dn=g_dn, ap=ap, radio=radio, comp=comp)


def _cell_onehot(env: NetworkEnv) -> Array:
    """(U, N) one-hot of the serving AP."""
    return jax.nn.one_hot(env.ap, env.n_aps, dtype=env.g_up.dtype)


def uplink_sinr(env: NetworkEnv, beta_up: Array, p_up: Array,
                backend: str | None = None, layout=None) -> Array:
    """Paper eq. (5). Returns SINR (U, M). layout: optional CellLayout
    (kernels backend only) restricting the SIC grid to same-cell tiles."""
    backend = _SINR_BACKEND if backend is None else backend
    if backend not in SINR_BACKENDS:
        raise ValueError(f"backend must be one of {SINR_BACKENDS}, got {backend!r}")
    own = env.own_gain_up()                      # (U, M) gain to own AP
    tx = beta_up * p_up[:, None]                  # (U, M) effective tx power
    if backend != "einsum":
        from repro.kernels import ops
        # The kernel's custom_vjp treats the channel gains as constants
        # (zero env cotangents); detach the outside-kernel own-gain uses too
        # so the pallas env-gradient is coherently zero rather than a silent
        # mixture. Differentiating w.r.t. gains requires backend="einsum".
        own = jax.lax.stop_gradient(own)
        intra, inter = ops.noma_pairwise_up(env, tx, layout=layout,
                                            interpret=_pallas_interpret(backend))
    else:
        cell = _cell_onehot(env)                  # (U, N)
        # Inter-cell interference received at AP n from users NOT in cell n,
        # computed directly with an off-cell mask (no subtraction: fp32-safe).
        inter_at = jnp.einsum("vn,vm,vnm->nm", 1.0 - cell, tx, env.g_up)  # (N, M)
        inter = jnp.einsum("un,nm->um", cell, inter_at)
        same = env.same_cell().astype(own.dtype)  # (U, U)
        # Intra-cell: same-cell users with weaker own-gain (decoded after me).
        weaker = (own[None, :, :] < own[:, None, :]).astype(own.dtype)  # (U, V, M)
        intra = jnp.einsum("uvm,vm->um", weaker * same[:, :, None], tx * own)
    sig = p_up[:, None] * own
    return sig / (intra + inter + env.noise_up)


def uplink_rates(env: NetworkEnv, beta_up: Array, p_up: Array,
                 backend: str | None = None, layout=None) -> Array:
    """Paper eq. (6): per-(user, subchannel) rate in bit/s; sum over m gives
    the user's total rate under the relaxation."""
    sinr = uplink_sinr(env, beta_up, p_up, backend=backend, layout=layout)
    bw = env.radio.bandwidth_up_hz / env.n_sub
    return beta_up * bw * jnp.log1p(sinr) / LOG2


def downlink_sinr(env: NetworkEnv, beta_dn: Array, p_dn: Array,
                  backend: str | None = None, layout=None) -> Array:
    """Paper eq. (8). Returns SINR (U, M). layout as in uplink_sinr."""
    backend = _SINR_BACKEND if backend is None else backend
    if backend not in SINR_BACKENDS:
        raise ValueError(f"backend must be one of {SINR_BACKENDS}, got {backend!r}")
    own = env.own_gain_dn()                       # (U, M) gain my AP -> me
    tx = beta_dn * p_dn[:, None]                  # (U, M) power my AP spends on me
    if backend != "einsum":
        from repro.kernels import ops
        # See uplink_sinr: gains are constants under the kernel backend.
        own = jax.lax.stop_gradient(own)
        intra, inter = ops.noma_pairwise_dn(env, tx, layout=layout,
                                            interpret=_pallas_interpret(backend))
        intra = intra * own
    else:
        cell = _cell_onehot(env)                  # (U, N)
        # Total tx power of AP n on subchannel m: (N, M)
        ap_tx = jnp.einsum("un,um->nm", cell, tx)
        # Interference from *other* APs received at me, masked directly
        # (no subtraction: fp32-safe): sum_{l != ap(u)} ap_tx[l,m] * g_dn[l,u,m]
        g_all = jnp.swapaxes(env.g_dn, 0, 1)      # (U, N, M)
        inter = jnp.einsum("nm,unm,un->um", ap_tx, g_all, 1.0 - cell)
        # Intra-cell: same-cell users with *stronger* downlink gain (decoded after me)
        same = env.same_cell().astype(own.dtype)
        stronger = (own[None, :, :] > own[:, None, :]).astype(own.dtype)
        intra = jnp.einsum("uvm,vm->um", stronger * same[:, :, None], tx) * own
    sig = p_dn[:, None] * own
    return sig / (intra + inter + env.noise_dn)


def downlink_rates(env: NetworkEnv, beta_dn: Array, p_dn: Array,
                   backend: str | None = None, layout=None) -> Array:
    """Paper eq. (9)."""
    sinr = downlink_sinr(env, beta_dn, p_dn, backend=backend, layout=layout)
    bw = env.radio.bandwidth_dn_hz / env.n_sub
    return beta_dn * bw * jnp.log1p(sinr) / LOG2


def user_rates(
    env: NetworkEnv, beta_up: Array, beta_dn: Array, p_up: Array, p_dn: Array,
    backend: str | None = None, layout=None,
) -> tuple[Array, Array]:
    """Total uplink/downlink rate per user (bit/s), floored for stability.

    Differentiable in (beta, p) under every backend: the Pallas path
    carries a custom_vjp whose backward kernels re-stream interferer blocks
    (see kernels/noma_rates.py), so the GD gradient path (utility ->
    user_rates) may run tiled at paper scale. Gradients w.r.t. the channel
    gains exist only under "einsum" -- the kernel backend stop_gradients
    the env (coherently zero, never a partial mixture). None resolves the
    module default at trace time; the solver passes GdConfig.sinr_backend
    explicitly. layout: optional precomputed CellLayout for the kernel
    backends (same-cell block-diagonal SIC grid), ignored under einsum."""
    r_up = jnp.sum(uplink_rates(env, beta_up, p_up, backend=backend,
                                layout=layout), axis=-1)
    r_dn = jnp.sum(downlink_rates(env, beta_dn, p_dn, backend=backend,
                                  layout=layout), axis=-1)
    return jnp.maximum(r_up, 1e-9), jnp.maximum(r_dn, 1e-9)


def oma_rates(env: NetworkEnv, p_up: Array, p_dn: Array) -> tuple[Array, Array]:
    """OMA baseline: each user gets a dedicated share of its best subchannel,
    TDMA-style equal split within the cell; no intra-cell interference, but
    also no frequency reuse gain (spectrum divided among same-cell users)."""
    own_up = env.own_gain_up()
    own_dn = env.own_gain_dn()
    # Users per cell -> each gets 1/|U_n| of the band.
    counts = jnp.sum(env.same_cell(), axis=1).astype(own_up.dtype)
    bw_up = env.radio.bandwidth_up_hz / counts
    bw_dn = env.radio.bandwidth_dn_hz / counts
    g_up = jnp.max(own_up, axis=1)
    g_dn = jnp.max(own_dn, axis=1)
    snr_up = p_up * g_up / (env.noise_up * env.n_sub)   # full-band noise share
    snr_dn = p_dn * g_dn / (env.noise_dn * env.n_sub)
    r_up = bw_up * jnp.log1p(snr_up) / LOG2
    r_dn = bw_dn * jnp.log1p(snr_dn) / LOG2
    return jnp.maximum(r_up, 1e-9), jnp.maximum(r_dn, 1e-9)
