"""Evaluation baselines the paper compares against (Sec. VI):

  Device-Only    whole model on the device; no radio use.
  Edge-Only      whole model offloaded (split s=0), max power, best channel.
  Neurosurgeon   [38] latency-only split per user, OMA channel, full edge res.
  DNN-Surgery    [14] latency-only split, OMA, edge resources shared fairly.
  ECC-OMA        the paper's ECC optimizer but over OMA channels.

All return per-user (T, E) so figures can be normalized the way the paper
normalizes (to Device-Only, or to Neurosurgeon for Fig.4/5).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import channel
from repro.core.utility import delay_energy as _delay_energy
from repro.core.types import (
    GdConfig,
    Array,
    EccWeights,
    GdVars,
    ModelProfile,
    NetworkEnv,
)


class Outcome(NamedTuple):
    T: Array   # (U,) seconds
    E: Array   # (U,) joules
    s: Array   # () or (U,) split index


def device_only(env: NetworkEnv, prof: ModelProfile) -> Outcome:
    comp = env.comp
    z = jnp.sum(prof.fl)
    u = env.n_users
    T = jnp.full((u,), z / comp.c_device)
    E = jnp.full((u,), comp.xi_device * comp.c_device**2 * z)
    return Outcome(T=T, E=E, s=jnp.full((), prof.n_layers, jnp.int32))


def _greedy_vars(env: NetworkEnv, r_scale: Array | float = 1.0) -> GdVars:
    """Max power, best own-gain subchannel, full edge allocation."""
    rc, cc = env.radio, env.comp
    best_up = jnp.argmax(env.own_gain_up(), axis=-1)
    best_dn = jnp.argmax(env.own_gain_dn(), axis=-1)
    m = env.n_sub
    u = env.n_users
    return GdVars(
        beta_up=jax.nn.one_hot(best_up, m),
        beta_dn=jax.nn.one_hot(best_dn, m),
        p_up=jnp.full((u,), rc.p_up_max_w),
        p_dn=jnp.full((u,), rc.p_dn_max_w),
        r=jnp.full((u,), cc.r_max) * r_scale,
    )


def edge_only(env: NetworkEnv, prof: ModelProfile) -> Outcome:
    v = _greedy_vars(env)
    s = jnp.zeros((), jnp.int32)
    T, E = _delay_energy(env, prof, s, v)
    return Outcome(T=T, E=E, s=s)


def _oma_outcome_per_split(env, prof, v, r_cap):
    """(T, E) per (split, user) with OMA rates; used by latency-only planners."""
    comp = env.comp
    r_up, r_dn = channel.oma_rates(env, v.p_up, v.p_dn)
    pre = prof.prefix_flops()[:, None]            # (F+1, 1)
    suf = prof.suffix_flops()[:, None]
    w = prof.w[:, None]
    m_dn = prof.m_down[:, None]
    speed = jnp.power(r_cap, comp.lam_exponent) * comp.c_min_edge
    T = (pre / comp.c_device + suf / speed + w / r_up[None, :] + m_dn / r_dn[None, :])
    E = (
        comp.xi_device * comp.c_device**2 * pre
        + comp.xi_edge * speed**2 * suf
        + v.p_up[None, :] * w / r_up[None, :]
        + v.p_dn[None, :] * m_dn / r_dn[None, :]
    )
    return T, E  # (F+1, U)


def neurosurgeon(env: NetworkEnv, prof: ModelProfile) -> Outcome:
    """Latency-optimal split per user; ignores energy and edge contention."""
    v = _greedy_vars(env)
    T, E = _oma_outcome_per_split(env, prof, v, env.comp.r_max)
    s = jnp.argmin(T, axis=0)                     # (U,) per-user split
    take = lambda a: jnp.take_along_axis(a, s[None, :], axis=0)[0]
    return Outcome(T=take(T), E=take(E), s=s.astype(jnp.int32))


def dnn_surgery(env: NetworkEnv, prof: ModelProfile) -> Outcome:
    """Latency-only split but edge compute is shared across the cell's
    offloaders ([14] models limited edge resources)."""
    counts = jnp.sum(env.same_cell(), axis=1).astype(jnp.float32)
    r_cap = jnp.maximum(env.comp.r_max / counts, env.comp.r_min)  # (U,)
    v = _greedy_vars(env)
    T, E = _oma_outcome_per_split(env, prof, v, r_cap[None, :])
    s = jnp.argmin(T, axis=0)
    take = lambda a: jnp.take_along_axis(a, s[None, :], axis=0)[0]
    return Outcome(T=take(T), E=take(E), s=s.astype(jnp.int32))


def ecc_oma(
    env: NetworkEnv, prof: ModelProfile, w: EccWeights, cfg: GdConfig = GdConfig()
) -> Outcome:
    """The ECC tradeoff optimizer over OMA channels: GD on (p, r) per split
    with warm starts (no subchannel variable -- OMA pre-assigns spectrum)."""
    comp = env.comp
    rc = env.radio

    def phys(norm):
        return (
            rc.p_up_min_w + norm["p_up"] * (rc.p_up_max_w - rc.p_up_min_w),
            rc.p_dn_min_w + norm["p_dn"] * (rc.p_dn_max_w - rc.p_dn_min_w),
            comp.r_min + norm["r"] * (comp.r_max - comp.r_min),
        )

    pre = prof.prefix_flops()
    suf = prof.suffix_flops()

    def gamma_fn(norm, s):
        p_up, p_dn, r = phys(norm)
        r_up, r_dn = channel.oma_rates(env, p_up, p_dn)
        speed = jnp.power(r, comp.lam_exponent) * comp.c_min_edge
        T = pre[s] / comp.c_device + suf[s] / speed + prof.w[s] / r_up + prof.m_down[s] / r_dn
        E = (
            comp.xi_device * comp.c_device**2 * pre[s]
            + comp.xi_edge * speed**2 * suf[s]
            + p_up * prof.w[s] / r_up
            + p_dn * prof.m_down[s] / r_dn
        )
        return jnp.sum(w.w_T * T + w.w_E * E)

    grad_fn = jax.value_and_grad(gamma_fn)

    def solve_one(carry, s):
        def body(state):
            norm, _, it, _ = state
            g0, g = grad_fn(norm, s)
            new = jax.tree.map(
                lambda a, b: jnp.clip(a - cfg.step_size * b, 0.0, 1.0), norm, g
            )
            g1 = gamma_fn(new, s)
            done = jnp.abs(g1 - g0) < cfg.eps * jnp.maximum(1.0, jnp.abs(g0))
            return new, g1, it + 1, done

        def cond(state):
            _, _, it, done = state
            return jnp.logical_and(it < cfg.max_iters, jnp.logical_not(done))

        norm, gamma, _, _ = jax.lax.while_loop(
            cond, body, (carry, gamma_fn(carry, s), jnp.int32(0), jnp.bool_(False))
        )
        return norm, (gamma, norm)

    u = env.n_users
    init = {"p_up": jnp.full((u,), 0.5), "p_dn": jnp.full((u,), 0.5),
            "r": jnp.full((u,), 0.5)}
    splits = jnp.arange(prof.n_layers + 1, dtype=jnp.int32)
    _, (gammas, norms) = jax.lax.scan(solve_one, init, splits)
    s_star = jnp.argmin(gammas).astype(jnp.int32)
    best = jax.tree.map(lambda x: x[s_star], norms)
    p_up, p_dn, r = phys(best)
    r_up, r_dn = channel.oma_rates(env, p_up, p_dn)
    speed = jnp.power(r, comp.lam_exponent) * comp.c_min_edge
    T = (pre[s_star] / comp.c_device + suf[s_star] / speed
         + prof.w[s_star] / r_up + prof.m_down[s_star] / r_dn)
    E = (comp.xi_device * comp.c_device**2 * pre[s_star]
         + comp.xi_edge * speed**2 * suf[s_star]
         + p_up * prof.w[s_star] / r_up + p_dn * prof.m_down[s_star] / r_dn)
    return Outcome(T=T, E=E, s=s_star)


def evaluate_plan(env: NetworkEnv, prof: ModelProfile, plan, w: EccWeights) -> Outcome:
    """Evaluate a discrete SplitPlan under the true NOMA rate model."""
    v = GdVars(
        beta_up=jax.nn.one_hot(plan.sub_up, env.n_sub),
        beta_dn=jax.nn.one_hot(plan.sub_dn, env.n_sub),
        p_up=plan.p_up,
        p_dn=plan.p_dn,
        r=plan.r,
    )
    T, E = _delay_energy(env, prof, plan.s, v)
    return Outcome(T=T, E=E, s=plan.s)
