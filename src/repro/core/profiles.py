"""Model profiles: per-layer FLOPs + inter-layer activation sizes.

Three sources:
  * chain CNNs the paper evaluates (NiN-9, YOLOv2-17, VGG16-24), built from
    real conv arithmetic (MACs, feature-map sizes) on CIFAR-scale inputs;
  * any assigned LM architecture config (per-transformer-block profile), so
    the ECC planner applies to all 10 assigned archs (DESIGN.md Sec. 5);
  * *measured* profiles produced by the closed-loop serving telemetry
    (repro.online.telemetry): EMA-smoothed effective per-layer costs under
    live traffic, rebuilt every feedback epoch via ``ModelProfile.like`` so
    they are shape-, dtype-, and name-compatible with the static profile
    here and hit the planner's already-compiled programs as plain operands.
    The static profiles below are both the planner's prior and the
    telemetry accumulator's initial state; ``ModelProfile.validate_like``
    enforces the contract once at loop start (clear layer-count error
    instead of a recompile or a failure inside a jitted trace).

Layer enumeration follows the paper's stated counts (NiN 9 / YOLOv2 17 /
VGG16 24): ReLUs are folded into their producing layer; VGG pools, flatten
and softmax are kept as explicit (cheap) layers to reach the paper's count.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.types import ModelProfile

ACT_BITS = 16          # activations transmitted as fp16/bf16
INPUT_BITS = 8         # raw images are 8-bit per channel
RESULT_BITS_CLS = 10 * 32   # 10-class logits


def _conv_chain(layers, in_hwc, result_bits, name) -> ModelProfile:
    """layers: list of ('conv', out_c, k, stride) | ('pool', k, stride) |
    ('fc', out_dim) | ('gap',) | ('softmax',). Pools may also be folded via
    ('conv+pool', out_c, k, stride, pool_k)."""
    h, w, c = in_hwc
    fl, acts = [], []
    for spec in layers:
        kind = spec[0]
        if kind in ("conv", "conv+pool"):
            out_c, k, stride = spec[1], spec[2], spec[3]
            h = max(1, (h + stride - 1) // stride)
            w = max(1, (w + stride - 1) // stride)
            flops = 2.0 * k * k * c * out_c * h * w
            c = out_c
            if kind == "conv+pool":
                pk = spec[4]
                flops += float(h * w * c * pk * pk)
                h, w = max(1, h // pk), max(1, w // pk)
        elif kind == "pool":
            k, stride = spec[1], spec[2]
            flops = float(h * w * c * k * k)
            h, w = max(1, h // stride), max(1, w // stride)
        elif kind == "gap":
            flops = float(h * w * c)
            h, w = 1, 1
        elif kind == "fc":
            out_dim = spec[1]
            flops = 2.0 * (h * w * c) * out_dim
            h, w, c = 1, 1, out_dim
        elif kind == "norm":
            flops = 2.0 * h * w * c
        elif kind == "flatten":
            flops = 0.0
        elif kind == "softmax":
            flops = 5.0 * c
        else:
            raise ValueError(kind)
        fl.append(flops)
        acts.append(h * w * c * ACT_BITS)
    f = len(fl)
    w_bits = np.empty(f + 1)
    w_bits[0] = in_hwc[0] * in_hwc[1] * in_hwc[2] * INPUT_BITS
    w_bits[1:] = acts
    w_bits[f] = 0.0                       # split at F: nothing uploaded
    m_down = np.full(f + 1, float(result_bits))
    m_down[f] = 0.0                       # split at F: nothing comes back
    return ModelProfile(
        fl=jnp.asarray(fl, jnp.float32),
        w=jnp.asarray(w_bits, jnp.float32),
        m_down=jnp.asarray(m_down, jnp.float32),
        name=name,
    )


def nin() -> ModelProfile:
    """Network-in-Network, 9 conv/mlpconv layers (pools folded), CIFAR-10."""
    layers = [
        ("conv", 192, 5, 1), ("conv", 160, 1, 1), ("conv+pool", 96, 1, 1, 2),
        ("conv", 192, 5, 1), ("conv", 192, 1, 1), ("conv+pool", 192, 1, 1, 2),
        ("conv", 192, 3, 1), ("conv", 192, 1, 1), ("conv", 10, 1, 1),
    ]
    return _conv_chain(layers, (32, 32, 3), RESULT_BITS_CLS, "nin")


def yolov2() -> ModelProfile:
    """YOLOv2-style chain, 17 conv layers (pools folded), 64x64 input."""
    layers = [
        ("conv+pool", 32, 3, 1, 2),
        ("conv+pool", 64, 3, 1, 2),
        ("conv", 128, 3, 1), ("conv", 64, 1, 1), ("conv+pool", 128, 3, 1, 2),
        ("conv", 256, 3, 1), ("conv", 128, 1, 1), ("conv+pool", 256, 3, 1, 2),
        ("conv", 512, 3, 1), ("conv", 256, 1, 1), ("conv", 512, 3, 1),
        ("conv", 256, 1, 1), ("conv+pool", 512, 3, 1, 2),
        ("conv", 1024, 3, 1), ("conv", 512, 1, 1), ("conv", 1024, 3, 1),
        ("conv", 125, 1, 1),
    ]
    # detection output: SxSx125 fp16
    return _conv_chain(layers, (64, 64, 3), 2 * 2 * 125 * ACT_BITS, "yolov2")


def vgg16() -> ModelProfile:
    """VGG16, enumerated to the paper's 24 layers (input-norm + 13 conv +
    5 pool + flatten + 3 fc + softmax)."""
    layers = [
        ("norm",),
        ("conv", 64, 3, 1), ("conv", 64, 3, 1), ("pool", 2, 2),
        ("conv", 128, 3, 1), ("conv", 128, 3, 1), ("pool", 2, 2),
        ("conv", 256, 3, 1), ("conv", 256, 3, 1), ("conv", 256, 3, 1), ("pool", 2, 2),
        ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("pool", 2, 2),
        ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("conv", 512, 3, 1), ("pool", 2, 2),
        ("flatten",),
        ("fc", 512), ("fc", 512), ("fc", 10),
        ("softmax",),
    ]
    return _conv_chain(layers, (32, 32, 3), RESULT_BITS_CLS, "vgg16")


PAPER_MODELS = {"nin": nin, "yolov2": yolov2, "vgg16": vgg16}


# --------------------------------------------------------------------------
# LM architecture profiles (per-transformer-block), for the assigned archs
# --------------------------------------------------------------------------
def lm_block_flops(cfg, seq: int) -> tuple[float, float]:
    """(dense_block_flops, moe_block_flops_active) for one token batch of
    length `seq` through one block. GQA-aware; counts fwd only (inference)."""
    d = cfg.d_model
    hd = d // cfg.n_heads
    kv_dim = cfg.n_kv_heads * hd
    attn_proj = 2.0 * seq * d * (d + 2 * kv_dim + d)          # q,k,v,o matmuls
    attn_core = 4.0 * seq * seq * d                            # scores + AV
    if getattr(cfg, "window", None):
        w = min(cfg.window, seq)
        attn_core = 4.0 * seq * w * d
    if cfg.d_ff > 0:
        mlp = 6.0 * seq * d * cfg.d_ff                         # SwiGLU: 3 matmuls
    else:
        mlp = 0.0
    moe_mlp = mlp
    if getattr(cfg, "n_experts", 0):
        active = cfg.top_k + getattr(cfg, "n_shared_experts", 0)
        moe_mlp = active * 6.0 * seq * d * cfg.moe_d_ff
    return attn_proj + attn_core + mlp, attn_proj + attn_core + moe_mlp


def from_arch_config(cfg, seq: int, batch: int = 1) -> ModelProfile:
    """Per-block profile of an assigned LM arch: fl[i] = FLOPs of block i,
    w[s] = bits of the residual-stream activation crossing the split."""
    dense_f, moe_f = lm_block_flops(cfg, seq)
    n = cfg.n_layers
    fl = np.empty(n)
    for i in range(n):
        is_moe = bool(getattr(cfg, "n_experts", 0)) and (
            i % max(1, getattr(cfg, "moe_every", 1)) == 0
        )
        fl[i] = (moe_f if is_moe else dense_f) * batch
    act_bits = batch * seq * cfg.d_model * ACT_BITS
    w = np.full(n + 1, float(act_bits))
    w[0] = batch * seq * 32.0  # raw token ids
    w[n] = 0.0
    m_down = np.full(n + 1, float(batch * cfg.vocab_size * ACT_BITS))
    m_down[n] = 0.0
    return ModelProfile(
        fl=jnp.asarray(fl, jnp.float32),
        w=jnp.asarray(w, jnp.float32),
        m_down=jnp.asarray(m_down, jnp.float32),
        name=getattr(cfg, "name", "lm"),
    )
