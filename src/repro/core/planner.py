"""ECC planner: the public API that turns (network env, model profile,
QoS weights) into a discrete SplitPlan. This is the paper's contribution
packaged as the framework's first-class feature -- the serving runtime
(repro.runtime.split_serve) consumes SplitPlan to place stage boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines, li_gd, profiles
from repro.core.types import (
    EccWeights,
    GdConfig,
    ModelProfile,
    NetworkEnv,
    SplitPlan,
    make_weights,
)


def plan(
    env: NetworkEnv,
    prof: ModelProfile,
    weights: EccWeights | None = None,
    cfg: GdConfig = GdConfig(),
    method: str = "li_gd",
    rounding: str = "best",
) -> SplitPlan:
    """method: 'li_gd' (paper), 'gd' (cold-start baseline).
    rounding: 'best' (best-of argmax/greedy, beyond-paper), 'greedy',
    or 'paper' (0.5-rule with argmax repair)."""
    if weights is None:
        weights = make_weights(env.n_users)
    return li_gd.solve(env, prof, weights, cfg, method=method, rounding=rounding)


def plan_for_arch(env: NetworkEnv, arch_cfg, seq: int, batch: int = 1,
                  weights: EccWeights | None = None,
                  cfg: GdConfig = GdConfig()) -> SplitPlan:
    """Plan a split for one of the assigned LM architectures."""
    prof = profiles.from_arch_config(arch_cfg, seq=seq, batch=batch)
    return plan(env, prof, weights, cfg)


def plan_batch(envs: NetworkEnv, prof: ModelProfile,
               weights: EccWeights | None = None,
               cfg: GdConfig = GdConfig(), method: str = "li_gd") -> SplitPlan:
    """Batched Li-GD over stacked channel realizations (beyond-paper):
    `envs` is a NetworkEnv whose array leaves carry a leading Monte-Carlo
    dim (same radio/compute constants). One compiled program optimizes all
    draws in parallel -- this is the production shape for re-planning under
    fading (the paper re-runs the solver per draw)."""
    n_users = envs.g_up.shape[1]
    if weights is None:
        weights = make_weights(n_users)

    def one(env):
        return li_gd.solve(env, prof, weights, cfg, method=method)

    import jax
    return jax.vmap(one)(envs)


def stack_envs(envs: list[NetworkEnv]) -> NetworkEnv:
    """Stack same-shape environments along a leading Monte-Carlo dim."""
    import jax
    return jax.tree.map(lambda *xs: jax.numpy.stack(xs), *envs)


def compare_all(env: NetworkEnv, prof: ModelProfile,
                weights: EccWeights | None = None,
                cfg: GdConfig = GdConfig()) -> dict:
    """Run ECC-NOMA + every baseline; returns {name: Outcome}. Used by the
    paper-figure benchmarks."""
    if weights is None:
        weights = make_weights(env.n_users)
    p = plan(env, prof, weights, cfg)
    return {
        "ecc_noma": baselines.evaluate_plan(env, prof, p, weights),
        "ecc_oma": baselines.ecc_oma(env, prof, weights, cfg),
        "device_only": baselines.device_only(env, prof),
        "edge_only": baselines.edge_only(env, prof),
        "neurosurgeon": baselines.neurosurgeon(env, prof),
        "dnn_surgery": baselines.dnn_surgery(env, prof),
    }
