"""Back-compat planner facade: turns (network env, model profile, QoS
weights) into a discrete SplitPlan with a single call.

This module is a thin wrapper over repro.core.li_gd.solve. New code that
plans repeatedly -- Monte-Carlo batches or online re-planning across a
time-correlated scenario -- should use repro.planning.PlannerEngine, which
owns the compiled-solver cache and the warm-start state (the former
plan_batch/stack_envs helpers live there as PlannerEngine.plan_many and
planning.stack_envs).
"""
from __future__ import annotations

from repro.core import baselines, li_gd, profiles
from repro.core.types import (
    EccWeights,
    GdConfig,
    ModelProfile,
    NetworkEnv,
    SplitPlan,
    make_weights,
)


def plan(
    env: NetworkEnv,
    prof: ModelProfile,
    weights: EccWeights | None = None,
    cfg: GdConfig = GdConfig(),
    method: str = "li_gd",
    rounding: str = "best",
) -> SplitPlan:
    """method: 'li_gd' (paper), 'gd' (cold-start baseline).
    rounding: 'best' (best-of argmax/greedy, beyond-paper), 'greedy',
    or 'paper' (0.5-rule with argmax repair)."""
    if weights is None:
        weights = make_weights(env.n_users)
    return li_gd.solve(env, prof, weights, cfg, method=method, rounding=rounding)


def plan_for_arch(env: NetworkEnv, arch_cfg, seq: int, batch: int = 1,
                  weights: EccWeights | None = None,
                  cfg: GdConfig = GdConfig()) -> SplitPlan:
    """Plan a split for one of the assigned LM architectures."""
    prof = profiles.from_arch_config(arch_cfg, seq=seq, batch=batch)
    return plan(env, prof, weights, cfg)


def compare_all(env: NetworkEnv, prof: ModelProfile,
                weights: EccWeights | None = None,
                cfg: GdConfig = GdConfig()) -> dict:
    """Run ECC-NOMA + every baseline; returns {name: Outcome}. Used by the
    paper-figure benchmarks."""
    if weights is None:
        weights = make_weights(env.n_users)
    p = plan(env, prof, weights, cfg)
    return {
        "ecc_noma": baselines.evaluate_plan(env, prof, p, weights),
        "ecc_oma": baselines.ecc_oma(env, prof, weights, cfg),
        "device_only": baselines.device_only(env, prof),
        "edge_only": baselines.edge_only(env, prof),
        "neurosurgeon": baselines.neurosurgeon(env, prof),
        "dnn_surgery": baselines.dnn_surgery(env, prof),
    }
