"""Inference-delay + energy models and the weighted utility (paper eqs. 1-22).

All functions are differentiable in the continuous variables (beta, p, r) so
jax.grad drives the (Li-)GD optimizer; the split index enters through
precomputed per-split constants (f_l^i, f_e^i, w_s), exactly as the paper
prescribes ("f_l, f_e, w_s are calculated by mobile users in advance").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import channel
from repro.core.types import (
    Array,
    EccWeights,
    GdVars,
    ModelProfile,
    NetworkEnv,
    lam,
)


def split_constants(prof: ModelProfile, s: Array) -> tuple[Array, Array, Array, Array]:
    """(f_device, f_edge, w_up_bits, m_down_bits) for split index s in 0..F."""
    pre = prof.prefix_flops()
    suf = prof.suffix_flops()
    return pre[s], suf[s], prof.w[s], prof.m_down[s]


def delay_energy(
    env: NetworkEnv,
    prof: ModelProfile,
    s: Array,
    v: GdVars,
    rates: tuple[Array, Array] | None = None,
    backend: str | None = None,
    layout=None,
) -> tuple[Array, Array]:
    """Per-user (T_i, E_i): paper eqs. (12) and (17). backend selects the
    SINR path (channel.user_rates); every choice is differentiable. layout
    is the optional CellLayout forwarded to the kernel backends."""
    comp = env.comp
    f_dev, f_edge, w_up, m_dn = split_constants(prof, s)
    if rates is None:
        r_up, r_dn = channel.user_rates(env, v.beta_up, v.beta_dn, v.p_up,
                                        v.p_dn, backend=backend,
                                        layout=layout)
    else:
        r_up, r_dn = rates
    speed_edge = lam(v.r, comp) * comp.c_min_edge

    t_dev = f_dev / comp.c_device                       # eq. (1)
    t_edge = f_edge / speed_edge                        # eq. (3)
    t_up = w_up / r_up                                  # eq. (7)
    t_dn = m_dn / r_dn                                  # eq. (10)
    T = t_dev + t_edge + t_up + t_dn                    # eq. (12)

    e_dev = comp.xi_device * comp.c_device**2 * comp.phi_device * f_dev    # eq. (13)
    e_up = v.p_up * t_up                                                   # eq. (14)
    e_edge = comp.xi_edge * speed_edge**2 * comp.phi_edge * f_edge         # eq. (16)
    e_dn = v.p_dn * t_dn                                                   # eq. (15)
    E = e_dev + e_up + e_edge + e_dn                    # eq. (17)
    return T, E


def utility(
    env: NetworkEnv,
    prof: ModelProfile,
    s: Array,
    v: GdVars,
    w: EccWeights,
    backend: str | None = None,
    layout=None,
) -> Array:
    """Gamma_s = sum_i omega_T^i T_i + omega_E^i E_i  (paper eq. 22)."""
    T, E = delay_energy(env, prof, s, v, backend=backend, layout=layout)
    return jnp.sum(w.w_T * T + w.w_E * E)


def per_user_utility(
    env: NetworkEnv, prof: ModelProfile, s: Array, v: GdVars, w: EccWeights,
    backend: str | None = None, layout=None,
) -> Array:
    T, E = delay_energy(env, prof, s, v, backend=backend, layout=layout)
    return w.w_T * T + w.w_E * E
