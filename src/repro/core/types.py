"""Core datatypes for the ECC / Li-GD NOMA split-inference planner.

Everything is a registered pytree so it can flow through jit/vmap/scan.
Units:
  gains          -- linear power gains |h|^2 (dimensionless, includes path loss)
  powers         -- Watts
  bandwidth      -- Hz
  workloads f    -- FLOPs
  data sizes w,m -- bits
  compute c      -- FLOP/s
  energy coeff   -- xi * c^2 = Joules per FLOP (DVFS-style E ~ xi c^2 f)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

# ln(2), shared by every rate computation (bit/s = Hz * ln(1+SINR)/LOG2).
# Single definition: core.channel, kernels.ops and kernels.ref import it.
LOG2 = 0.6931471805599453


def _register(cls):
    fields = [f.name for f in dataclasses.fields(cls)]
    meta = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static", False)]
    data = [n for n in fields if n not in meta]
    jax.tree_util.register_dataclass(cls, data_fields=data, meta_fields=meta)
    return cls


def static_field(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


@_register
@dataclasses.dataclass(frozen=True)
class RadioConstants:
    """Paper Sec. VI.A constants (configurable)."""

    bandwidth_up_hz: float = 10e6
    bandwidth_dn_hz: float = 10e6
    noise_psd_w_per_hz: float = 10 ** ((-174.0 - 30.0) / 10.0)  # -174 dBm/Hz
    p_up_min_w: float = 1e-3          # 0 dBm
    p_up_max_w: float = 0.3162        # 25 dBm (paper)
    p_dn_min_w: float = 0.1
    p_dn_max_w: float = 10.0
    beta_min: float = 1e-3            # numerical floor for relaxed subchannel share
    path_loss_exp: float = 5.0        # paper
    cell_radius_m: float = 250.0


@_register
@dataclasses.dataclass(frozen=True)
class ComputeConstants:
    """Device / edge compute + energy model constants."""

    c_device: float = 2.5e10          # FLOP/s of the mobile device
    c_min_edge: float = 2.5e10        # FLOP/s of one minimum edge compute unit
    r_min: float = 1.0
    r_max: float = 16.0
    lam_exponent: float = 0.85        # lambda(r) = r^0.85 (multicore nonlinearity, [15])
    xi_device: float = 1.3e-31        # J/FLOP = xi * c^2  (~2 W mobile SoC)
    xi_edge: float = 4.0e-33          # quadratic in allocated speed (paper eq. 16)
    phi_device: float = 1.0           # paper's cycles/bit factor, folded to 1 (see DESIGN)
    phi_edge: float = 1.0


@_register
@dataclasses.dataclass(frozen=True)
class NetworkEnv:
    """A realization of the NOMA radio network.

    Shapes: U users, N APs, M subchannels.
      g_up[u, n, m]  uplink |h|^2 from user u to AP n on subchannel m
      g_dn[n, u, m]  downlink |h|^2 from AP n to user u on subchannel m
      ap[u]          nearest-AP association (int32)
    """

    g_up: Array
    g_dn: Array
    ap: Array
    radio: RadioConstants
    comp: ComputeConstants

    @property
    def n_users(self) -> int:
        return self.g_up.shape[0]

    @property
    def n_aps(self) -> int:
        return self.g_up.shape[1]

    @property
    def n_sub(self) -> int:
        return self.g_up.shape[2]

    @property
    def noise_up(self) -> float:
        return self.radio.noise_psd_w_per_hz * self.radio.bandwidth_up_hz / self.n_sub

    @property
    def noise_dn(self) -> float:
        return self.radio.noise_psd_w_per_hz * self.radio.bandwidth_dn_hz / self.n_sub

    def own_gain_up(self) -> Array:  # (U, M)
        return jnp.take_along_axis(
            self.g_up, self.ap[:, None, None], axis=1
        ).squeeze(1)

    def own_gain_dn(self) -> Array:  # (U, M)
        g = jnp.swapaxes(self.g_dn, 0, 1)  # (U, N, M)
        return jnp.take_along_axis(g, self.ap[:, None, None], axis=1).squeeze(1)

    def same_cell(self) -> Array:  # (U, U) bool
        return self.ap[:, None] == self.ap[None, :]


class ProfileShapeError(ValueError):
    """A measured (or otherwise substituted) profile does not match the
    static profile's layer structure; raised at loop start instead of
    failing opaquely inside a jitted planner trace."""


@_register
@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Per-layer profile of an inference model (device-side units).

    fl[d]       FLOPs of layer d (d = 0..F-1)
    w[s]        bits of the activation produced by layer s (s = 0 is the raw
                input, so splitting at s=0 means full offload; w[F] = 0)
    m_down[s]   bits of the final result sent back down when split at s
                (0 when s == F: nothing was offloaded)
    """

    fl: Array
    w: Array
    m_down: Array
    name: str = static_field(default="model")

    @property
    def n_layers(self) -> int:
        return self.fl.shape[0]

    def validate_like(self, other: "ModelProfile") -> "ModelProfile":
        """Check that ``other`` is drop-in compatible with this profile:
        same layer count, same array shapes/dtypes, and the same static
        name (the name is pytree *metadata*, so a renamed profile would
        silently recompile every planner program that takes it as an
        operand). Returns ``other`` on success; raises ProfileShapeError
        with the offending field named otherwise. Measured-profile loops
        call this once at loop start."""
        if other.n_layers != self.n_layers:
            raise ProfileShapeError(
                f"measured profile has {other.n_layers} layers but the "
                f"static profile '{self.name}' has {self.n_layers}; the "
                "telemetry accumulator must be built from the profile the "
                "planner was constructed with (ModelProfile.like)")
        for field in ("fl", "w", "m_down"):
            a, b = getattr(self, field), getattr(other, field)
            if tuple(a.shape) != tuple(b.shape) or a.dtype != b.dtype:
                raise ProfileShapeError(
                    f"measured profile field '{field}' is "
                    f"{tuple(b.shape)}/{b.dtype} but the static profile "
                    f"'{self.name}' expects {tuple(a.shape)}/{a.dtype}; "
                    "a mismatched operand would recompile (or fail inside) "
                    "every compiled planner program")
        if other.name != self.name:
            raise ProfileShapeError(
                f"measured profile is named {other.name!r} but the static "
                f"profile is {self.name!r}; the name is static pytree "
                "metadata, so a rename mints a new jit signature and "
                "recompiles every planner program -- build measured "
                "profiles with ModelProfile.like, which preserves it")
        return other

    def like(self, fl: Array, w: Array, m_down: Array) -> "ModelProfile":
        """A profile with this profile's name and layer structure but new
        per-layer tables (e.g. measured/EMA-smoothed ones). Values are cast
        to the static tables' dtypes (strong-typed: a weak-f32 leaf would
        re-trace the planner once per feedback epoch); shapes are validated
        so a mismatch fails here, not inside a jitted planner trace."""
        made = ModelProfile(
            fl=jnp.asarray(fl, self.fl.dtype),
            w=jnp.asarray(w, self.w.dtype),
            m_down=jnp.asarray(m_down, self.m_down.dtype),
            name=self.name,
        )
        return self.validate_like(made)

    def prefix_flops(self) -> Array:
        """device-side FLOPs for split s = 0..F  (shape F+1)."""
        return jnp.concatenate([jnp.zeros((1,), self.fl.dtype), jnp.cumsum(self.fl)])

    def suffix_flops(self) -> Array:
        """edge-side FLOPs for split s = 0..F  (shape F+1)."""
        total = jnp.sum(self.fl)
        return total - self.prefix_flops()


@_register
@dataclasses.dataclass(frozen=True)
class EccWeights:
    """Per-user tradeoff weights (omega_T + omega_E = 1)."""

    w_T: Array  # (U,)
    w_E: Array  # (U,)


@_register
@dataclasses.dataclass(frozen=True)
class GdConfig:
    step_size: float = static_field(default=5e-3)
    eps: float = static_field(default=1e-5)
    max_iters: int = static_field(default=400)
    # Adam-mode is the beyond-paper optimizer upgrade; "sgd" is paper-faithful.
    optimizer: str = static_field(default="sgd")
    adam_b1: float = static_field(default=0.9)
    adam_b2: float = static_field(default=0.999)
    # First stopping rule (Table I line 6). "pgd" tests the projected-gradient
    # residual ||x - P(x - step_size*g)|| / step_size < eps, which vanishes at
    # a constrained (simplex/box boundary) optimum; "raw" is the paper-parity
    # baseline ||g|| < eps, which never fires on the boundary and silently
    # defers to the looser Gamma/maxdiff rules.
    stop_rule: str = static_field(default="pgd")
    # SINR backend traced into the solver's gradient path ("einsum" |
    # "pallas" | "pallas_interpret"). The Pallas pairwise kernel carries a
    # custom_vjp, so the GD hot loop itself can run stream-tiled at paper
    # scale; "pallas" falls back to interpret mode off-TPU. Always passed
    # explicitly to utility (never the channel-module global), so compiled
    # solver programs are keyed on -- and immune to -- backend switches.
    sinr_backend: str = static_field(default="einsum")


@_register
@dataclasses.dataclass(frozen=True)
class GdVars:
    """The continuous relaxation optimized by (Li-)GD."""

    beta_up: Array  # (U, M) in simplex rows
    beta_dn: Array  # (U, M)
    p_up: Array     # (U,) Watts
    p_dn: Array     # (U,) Watts
    r: Array        # (U,) edge compute units


@_register
@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Final discrete plan produced by the planner."""

    s: Array            # () int32 chosen split layer in 0..F
    sub_up: Array       # (U,) int32 chosen uplink subchannel
    sub_dn: Array       # (U,) int32
    p_up: Array         # (U,)
    p_dn: Array         # (U,)
    r: Array            # (U,)
    utility: Array      # () utility at the chosen plan (relaxed)
    per_layer_utility: Array  # (F+1,)
    iters: Array        # (F+1,) GD iterations spent per split point
    rounding_violations: Array  # () count of users whose 0.5-rounding broke (18.e)


def make_weights(n_users: int, w_T: float = 0.5) -> EccWeights:
    t = jnp.full((n_users,), float(w_T))
    return EccWeights(w_T=t, w_E=1.0 - t)


def lam(r: Array, comp: ComputeConstants) -> Array:
    """Multicore speedup lambda(r): monotone, concave (paper Sec III.A.2)."""
    return jnp.power(r, comp.lam_exponent)
