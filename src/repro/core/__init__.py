"""ECC / Li-GD core: the paper's contribution as a composable JAX module."""
from repro.core.types import (  # noqa: F401
    ComputeConstants,
    EccWeights,
    GdConfig,
    GdVars,
    ModelProfile,
    NetworkEnv,
    ProfileShapeError,
    RadioConstants,
    SplitPlan,
    lam,
    make_weights,
)
from repro.core.channel import (  # noqa: F401
    downlink_rates,
    downlink_sinr,
    make_env,
    oma_rates,
    set_sinr_backend,
    uplink_rates,
    uplink_sinr,
    user_rates,
)
from repro.core.utility import delay_energy, per_user_utility, utility  # noqa: F401
from repro.core.li_gd import (  # noqa: F401
    GdResult,
    LoopResult,
    assemble_plan,
    cold_init,
    gd_loop,
    gd_solve,
    li_gd_loop,
    plain_gd_loop,
    project_simplex,
    project_simplex_floor,
    rho_estimate,
    greedy_round_dn,
    greedy_round_up,
    round_beta,
    solve,
    to_physical,
)
from repro.core import baselines, planner, profiles  # noqa: F401
