"""Logical-axis -> mesh-axis sharding rules (neutral module: imported by
both the model zoo and the runtime without circular imports)."""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axis (or tuple for joint sharding)
RULES: dict[str | None, str | tuple | None] = {
    "vocab": "model",
    "qkv": "model",          # flattened heads*hd projections
    "kv": "model",           # flattened kv_heads*hd
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",      # expert parallelism
    "experts_row": None,     # router output dim: small, replicate
    "lru": "model",
    "lru_out": None,         # second dim of the square lru mats: replicate
    "embed": None,           # residual stream replicated (TP gathers on it)
    "layers": None,
    "batch": ("pod", "data"),
    "seq": None,
    "kvseq": "model",        # decode KV-cache sequence sharding (flash-decode)
    "fleet": "fleet",        # planner fleet axis (one scenario batch per device)
    None: None,
}


FLEET_AXIS = "fleet"


def fleet_mesh(n_devices: int | None = None, axis: str = FLEET_AXIS) -> Mesh:
    """A 1-D mesh over the (first n) local devices for fleet planning:
    PlannerEngine.shard(fleet_mesh()) runs plan_many/replan_many via
    shard_map with the fleet dim split across devices."""
    devs = jax.devices() if n_devices is None else jax.devices()[:n_devices]
    return Mesh(np.asarray(devs), (axis,))


def fleet_axis(mesh: Mesh) -> str:
    """The mesh axis carrying the fleet dim: 'fleet' when present, else the
    first axis (so a plain 1-D ('data',) mesh also works)."""
    if FLEET_AXIS in mesh.shape:
        return FLEET_AXIS
    return mesh.axis_names[0]


def fleet_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding splitting the leading (fleet) dim over the mesh."""
    return NamedSharding(mesh, P(fleet_axis(mesh)))


def shard_fleet(tree, mesh: Mesh):
    """Explicitly place a fleet-batched pytree (stacked NetworkEnv, fleet
    ScenarioState, batched PlanState) with its leading dim split over the
    mesh's fleet axis. jit would insert the same transfer implicitly; doing
    it once up front keeps steady-state dispatch transfer-free (and clean
    under jax.transfer_guard('disallow'))."""
    return jax.device_put(tree, fleet_sharding(mesh))


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def spec_for(mesh: Mesh, logical_axes: tuple, shape: tuple,
             fsdp: bool = False) -> P:
    """Resolve logical axes to a PartitionSpec. A mesh axis is used at most
    once per tensor (first logical dim wins: e.g. MoE (experts, embed, mlp)
    shards experts over 'model' and leaves mlp replicated); non-divisible
    dims are dropped to replication (jit rejects uneven input shardings).

    fsdp=True (parameters only, Perf iteration E): a dim whose logical axis
    is 'embed' additionally shards over the data-parallel axes (ZeRO-3 /
    MaxText-fsdp style) -- GSPMD inserts per-layer weight all-gathers in
    fwd/bwd and reduce-scatters the gradients."""
    out = []
    used: set = set()

    def assign(mesh_ax, dim):
        if isinstance(mesh_ax, tuple):
            mesh_ax = tuple(a for a in mesh_ax if a in mesh.shape
                            and a not in used)
            if not mesh_ax:
                return None
        elif mesh_ax not in mesh.shape or mesh_ax in used:
            return None
        size = axis_size(mesh, mesh_ax)
        if dim % size == 0 and dim >= size:
            used.update(mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,))
            return mesh_ax
        return None

    for ax, dim in zip(logical_axes, shape):
        mesh_ax = RULES.get(ax, None)
        got = assign(mesh_ax, dim) if mesh_ax is not None else None
        if got is None and fsdp and ax == "embed":
            got = assign(tuple(a for a in ("pod", "data") if a in mesh.shape),
                         dim)
        out.append(got)
    return P(*out)


def ambient_mesh():
    """The physical mesh activated via `with mesh:` (trace-time), or None."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def constrain(x, logical_axes: tuple):
    """with_sharding_constraint resolved through the divisibility-aware
    rules against the ambient mesh; no-op outside a mesh context."""
    m = ambient_mesh()
    if m is None:
        return x
    spec = spec_for(m, logical_axes, x.shape)
    if all(a is None for a in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))
