from repro.runtime.train import TrainState, init_state, jit_train_step, make_train_step  # noqa: F401
from repro.runtime.serve import (  # noqa: F401
    OnlineSplitServer,
    jit_decode_step,
    jit_prefill,
    make_split_serve,
)
from repro.runtime import ft, sharding  # noqa: F401
