"""Runtime sharding: param/cache/batch sharding trees built on the
neutral rules in repro.pshard (re-exported here for back-compat)."""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.pshard import (  # noqa: F401
    FLEET_AXIS,
    RULES,
    ambient_mesh,
    axis_size,
    constrain,
    fleet_axis,
    fleet_mesh,
    fleet_sharding,
    shard_fleet,
    spec_for,
)


def tree_shardings(mesh: Mesh, specs_tree, shapes_tree, fsdp: bool = False):
    """specs_tree: pytree of logical-axes tuples; shapes_tree: matching pytree
    of jax.ShapeDtypeStruct/arrays. Returns pytree of NamedSharding."""
    def resolve(axes, arr):
        return NamedSharding(mesh, spec_for(mesh, axes, arr.shape, fsdp=fsdp))

    return jax.tree.map(
        resolve, specs_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )


# --------------------------------------------------------------------------
# activation / data shardings
# --------------------------------------------------------------------------
def batch_spec(mesh: Mesh, shape: tuple, batch_dim: int = 0,
               seq_dim: int | None = None, seq_axis: str | None = None) -> P:
    """Shard the batch dim over (pod, data); optionally sequence over an axis
    (sequence parallelism for batch-1 long-context)."""
    axes: list = [None] * len(shape)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_size = axis_size(mesh, dp)
    if dp and shape[batch_dim] % dp_size == 0 and shape[batch_dim] >= dp_size:
        axes[batch_dim] = dp
    elif "data" in mesh.shape and shape[batch_dim] % mesh.shape["data"] == 0:
        axes[batch_dim] = "data"
    elif seq_dim is not None and seq_axis is None:
        seq_axis = "data"  # batch unshardable -> spill onto sequence
    if (seq_dim is not None and seq_axis is not None
            and shape[seq_dim] % axis_size(mesh, seq_axis) == 0):
        axes[seq_dim] = seq_axis
    return P(*axes)


def cache_shardings(mesh: Mesh, caches_shapes, cfg):
    """Shard KV caches: batch over (pod,data) when divisible, else sequence
    over every free axis (long-context single-request decode); kv-heads over
    model when divisible, else the cache SEQUENCE shards over model and the
    single-pass decode attention runs flash-decoding style (scores and AV
    stay shard-local, only tiny softmax reductions cross shards; §Perf B)."""
    model = axis_size(mesh, "model")

    def _seq_axes(batch_sharded: bool, kv_on_model: bool, s_dim: int):
        """Choose the sequence-dim sharding for a cache of length s_dim."""
        free = []
        if not batch_sharded:
            free += [a for a in ("pod", "data") if a in mesh.shape]
        if not kv_on_model and "model" in mesh.shape:
            free.append("model")
        while free and s_dim % axis_size(mesh, tuple(free)) != 0:
            free.pop()
        return tuple(free) if free else None

    def resolve(path, arr):
        names = [getattr(p, "key", getattr(p, "name", None)) or str(p)
                 for p in path]
        shape = arr.shape
        key = names[-1] if names else ""
        # KV cache tensors: (L, B, S, KV, hd)
        if key in ("k", "v") and len(shape) == 5:
            axes: list = [None] * 5
            axes[1] = batch_spec(mesh, shape[1:2])[0]
            kv_ok = shape[3] % model == 0 and shape[3] >= model
            if kv_ok:
                axes[3] = "model"
            axes[2] = _seq_axes(axes[1] is not None, kv_ok, shape[2])
            return NamedSharding(mesh, P(*axes))
        if key == "pos" and len(shape) == 3:
            axes = [None, batch_spec(mesh, shape[1:2])[0], None]
            kv_ok = (cfg.n_kv_heads % model == 0
                     and cfg.n_kv_heads >= model)  # mirror the k/v choice
            axes[2] = _seq_axes(axes[1] is not None, kv_ok, shape[2])
            return NamedSharding(mesh, P(*axes))
        # recurrent states (L, B, ...) / enc_out (B, S, D) / pos (B,)
        if len(shape) >= 2 and key in ("h", "conv", "C", "n", "c", "m"):
            axes = [None] * len(shape)
            axes[1] = batch_spec(mesh, shape[1:2])[0]
            # last dim is a width dim: shard over model when divisible
            if shape[-1] % model == 0 and shape[-1] >= model:
                axes[-1] = "model"
            return NamedSharding(mesh, P(*axes))
        if key in ("enc_out", "frontend") and len(shape) == 3:
            return NamedSharding(mesh, batch_spec(mesh, shape))
        if len(shape) == 1:  # top-level pos counter
            return NamedSharding(mesh, batch_spec(mesh, shape))
        return NamedSharding(mesh, P(*[None] * len(shape)))

    return jax.tree_util.tree_map_with_path(resolve, caches_shapes)
