"""Training step: CE loss + MoE aux, microbatch accumulation (lax.scan),
grad clip, AdamW. Built once per (model, mesh) and jit'd with explicit
in/out shardings so the dry-run can .lower().compile() it directly.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import Model
from repro.optim import adamw_init, adamw_update, cosine_lr
from repro.optim.adamw import AdamWState, clip_by_global_norm
from repro.runtime import sharding as shlib


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jax.Array


def init_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def loss_fn(model: Model, params, batch, aux_weight=1e-2):
    logits, _, aux = model.train_logits(params, batch)
    tgt = batch["targets"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    mask = (tgt >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, (loss, aux)


def loss_fn_chunked(model: Model, params, batch, aux_weight=1e-2,
                    seq_chunk: int = 512):
    """Chunked cross-entropy (§Perf D): the (B, S, V) fp32 logits tensor --
    e.g. 421 GB global for phi3 train_4k -- is never materialized. The
    sequence is scanned in chunks; jax.checkpoint recomputes each chunk's
    logits in the backward pass."""
    hidden, aux = model.train_hidden(params, batch)
    tgt = batch["targets"]
    b, s, d = hidden.shape
    c = min(seq_chunk, s)
    n = s // c
    assert s % c == 0, (s, c)
    unembed = params["unembed"]
    vocab = model.cfg.vocab_size

    @jax.checkpoint
    def chunk_nll(h_c, t_c):
        from repro.models.layers import logits_out
        logits = logits_out(h_c, unembed, vocab)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, t_c[..., None], axis=-1)[..., 0]
        m = (t_c >= 0).astype(jnp.float32)
        return jnp.sum(nll * m), jnp.sum(m)

    def body(carry, xs):
        tot, cnt = carry
        h_c, t_c = xs
        nll, m = chunk_nll(h_c, t_c)
        return (tot + nll, cnt + m), None

    hs = jnp.moveaxis(hidden.reshape(b, n, c, d), 1, 0)
    ts = jnp.moveaxis(tgt.reshape(b, n, c), 1, 0)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ts))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux_weight * aux, (loss, aux)


def make_train_step(model: Model, n_microbatches: int = 1, base_lr=3e-4,
                    total_steps=10000, seq_chunk: int = 0):
    """Returns train_step(state, batch) -> (state, metrics). Microbatches
    split the global batch on axis 0 and accumulate grads via lax.scan
    (compute/comm overlap: XLA overlaps the psum of microbatch i with the
    backward of microbatch i+1). seq_chunk > 0 enables chunked CE."""

    def train_step(state: TrainState, batch):
        if seq_chunk:
            lfn = lambda p, b: loss_fn_chunked(model, p, b,
                                               seq_chunk=seq_chunk)
        else:
            lfn = lambda p, b: loss_fn(model, p, b)
        grad_fn = jax.value_and_grad(lfn, has_aux=True)

        if n_microbatches > 1:
            def micro(carry, mb):
                gsum, lsum, asum = carry
                (l, (nll, aux)), g = grad_fn(state.params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + nll, asum + aux), None

            mbs = jax.tree.map(
                lambda x: x.reshape(n_microbatches,
                                    x.shape[0] // n_microbatches, *x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (g, nll, aux), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros(()), jnp.zeros(())), mbs)
            g = jax.tree.map(lambda x: x / n_microbatches, g)
            nll, aux = nll / n_microbatches, aux / n_microbatches
        else:
            (_, (nll, aux)), g = grad_fn(state.params, batch)

        g, gnorm = clip_by_global_norm(g)
        lr = cosine_lr(state.step, base_lr=base_lr, total=total_steps)
        params, opt = adamw_update(state.params, g, state.opt, lr)
        metrics = {"loss": nll, "aux": aux, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    return train_step


def _zero1_shardings(mesh, p_shard, params_shapes, min_size=2**16):
    """ZeRO-1 (§Perf C): optimizer moments additionally shard their largest
    replicated dim over the data-parallel axes. Grads arrive param-sharded;
    GSPMD turns the AR + slice into reduce-scatter, and the param update
    all-gathers -- the classic ZeRO-1 collective schedule."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not dp:
        return p_shard

    def widen(ns, arr):
        if arr.size < min_size:
            return ns
        spec = list(ns.spec) + [None] * (arr.ndim - len(ns.spec))
        used = {a for s in spec if s for a in
                (s if isinstance(s, tuple) else (s,))}
        free = tuple(a for a in dp if a not in used)
        if not free:
            return ns
        size = shlib.axis_size(mesh, free)
        for i, (ax, dim) in enumerate(zip(spec, arr.shape)):
            if ax is None and dim % size == 0 and dim >= size:
                spec[i] = free if len(free) > 1 else free[0]
                return NamedSharding(mesh, P(*spec))
        return ns

    return jax.tree.map(widen, p_shard, params_shapes)


def jit_train_step(model: Model, mesh, n_microbatches: int = 1,
                   zero1: bool = False, seq_chunk: int = 0,
                   fsdp: bool = False):
    """jit with explicit state/batch shardings for the dry-run."""
    step_fn = make_train_step(model, n_microbatches, seq_chunk=seq_chunk)
    specs = model.specs()
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = shlib.tree_shardings(mesh, specs, params_shapes, fsdp=fsdp)
    m_shard = (_zero1_shardings(mesh, p_shard, params_shapes) if zero1
               else p_shard)
    opt_shard = AdamWState(
        step=NamedSharding(mesh, P()), m=m_shard,
        v=jax.tree.map(lambda s: s, m_shard),
    )
    state_shard = TrainState(params=p_shard, opt=opt_shard,
                             step=NamedSharding(mesh, P()))

    def batch_shard(shapes):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, shlib.batch_spec(mesh, s.shape)),
            shapes,
        )

    def make(batch_shapes):
        return jax.jit(
            step_fn,
            in_shardings=(state_shard, batch_shard(batch_shapes)),
            out_shardings=(state_shard, NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )

    return make, state_shard
