"""Fault tolerance: step watchdog, straggler detection, retrying train loop.

At 1000+ node scale the failure modes this addresses:
  * hung steps (network partition, device wedged) -> watchdog raises after
    `timeout_s`, the driver restores from the last checkpoint and retries;
  * stragglers (slow host) -> per-step timing vs a running median; offenders
    are counted and surfaced so the scheduler can evict the host. Mitigation
    within a step is XLA's (collectives don't proceed without every peer),
    so detection + requeue-from-checkpoint is the actionable layer;
  * crash-restart -> the loop is re-entrant: it reads the newest checkpoint
    and the data pipeline is stateless-resumable (batch = f(seed, step)).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field


class StepTimeout(Exception):
    pass


class Watchdog:
    """Context manager: raises StepTimeout in the main thread's next check if
    the step exceeds timeout_s (cooperative; XLA steps can't be interrupted
    preemptively from Python)."""

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._fired = threading.Event()
        self._timer: threading.Timer | None = None

    def __enter__(self):
        self._timer = threading.Timer(self.timeout_s, self._fired.set)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        assert self._timer is not None
        self._timer.cancel()
        return False

    @property
    def fired(self) -> bool:
        """Non-raising read of the timer state: the serving path
        (faults.degrade.EpochWatchdog) keeps the overrunning epoch's result
        and escalates a ladder instead of unwinding to a checkpoint."""
        return self._fired.is_set()

    def check(self):
        if self._fired.is_set():
            raise StepTimeout(f"step exceeded {self.timeout_s}s")


@dataclass
class StragglerDetector:
    threshold: float = 2.0        # x median
    window: int = 50
    times: list = field(default_factory=list)
    straggler_steps: int = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) >= 5:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.threshold * med:
                self.straggler_steps += 1
                return True
        return False


def run_with_retries(step_once, n_steps: int, restore_fn, max_retries: int = 3,
                     step_timeout_s: float = 600.0, on_straggler=None,
                     retryable: tuple[type[BaseException], ...] = ()):
    """Generic fault-tolerant loop. step_once(i) runs one step and must be
    idempotent-from-checkpoint; restore_fn() rewinds state after a failure.
    Returns (completed_steps, retries_used, straggler_steps).

    Only StepTimeout plus the caller's explicit ``retryable`` allowlist is
    retried. Anything else propagates immediately: a bare RuntimeError here
    is usually XLA reporting a compile/OOM/device error, and restoring a
    checkpoint to re-run into the same error ``max_retries`` times masks
    the real failure (and can silently burn the retry budget)."""
    det = StragglerDetector()
    retry_types: tuple[type[BaseException], ...] = (StepTimeout,
                                                    *tuple(retryable))
    retries = 0
    i = 0
    while i < n_steps:
        try:
            with Watchdog(step_timeout_s) as wd:
                t0 = time.monotonic()
                step_once(i)
                wd.check()
            dt = time.monotonic() - t0
            if det.record(dt) and on_straggler is not None:
                on_straggler(i, dt)
            i += 1
        except retry_types:
            retries += 1
            if retries > max_retries:
                raise
            i = restore_fn()
    return i, retries, det.straggler_steps
