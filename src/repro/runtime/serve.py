"""Serving runtime: prefill / decode steps, and ECC split-serve.

Split-serve is the paper's deployment shape: the model is cut at the
ECC-planned layer s*; layers [0, s) run on the *device* mesh, layers
[s, F) on the *edge* mesh. These are two separately-compiled programs (the
paper's device and edge are distinct systems joined by a NOMA radio link,
not one SPMD partition); the planner prices the activation transfer with
the NOMA rate model and `transfer_seconds` reports the simulated link time.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import Model
from repro.models.layers import COMPUTE_DTYPE, embed_lookup, logits_out
from repro.planning import WarmStateShapeError
from repro.runtime import sharding as shlib


def jit_prefill(model: Model, mesh, max_len: int):
    def fn(params, batch):
        return model.prefill(params, batch, max_len)

    specs = model.specs()
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = shlib.tree_shardings(mesh, specs, params_shapes)
    return jax.jit(fn, in_shardings=(p_shard, None)), p_shard


def jit_decode_step(model: Model, mesh, batch: int, max_len: int):
    """Returns (jitted step, params_sharding, cache_sharding)."""
    specs = model.specs()
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = shlib.tree_shardings(mesh, specs, params_shapes)
    cache_shapes = jax.eval_shape(lambda: model.make_caches(batch, max_len))
    c_shard = shlib.cache_shardings(mesh, cache_shapes, model.cfg)
    tok_shard = NamedSharding(mesh, shlib.batch_spec(mesh, (batch, 1)))

    step = jax.jit(
        model.decode_step,
        in_shardings=(p_shard, c_shard, tok_shard),
        out_shardings=(None, c_shard),
        donate_argnums=(1,),
    )
    return step, p_shard, c_shard


# --------------------------------------------------------------------------
# ECC split-serve
# --------------------------------------------------------------------------
class SplitPrograms(NamedTuple):
    device_fn: object     # (tokens, frontend=None) -> activation (B, S, D);
                          # closes over the device-side stage params
    edge_fn: object       # (activation, frontend=None) -> logits; closes over
                          # the edge-side stage params + unembed
    split_layer: int
    act_bytes_per_token: int


def _split_params(model: Model, params, s: int):
    """Split stacked stage params at global block index s."""
    a_stages, b_stages = [], []
    seen = 0
    for spec, p_st in zip(model.stages, params["stages"]):
        if seen + spec.n_layers <= s:
            a_stages.append((spec, p_st))
        elif seen >= s:
            b_stages.append((spec, p_st))
        else:
            cut = s - seen
            take = lambda t, sl: jax.tree.map(lambda x: x[sl], t)
            import dataclasses as dc
            a_stages.append((dc.replace(spec, n_layers=cut),
                             take(p_st, slice(0, cut))))
            b_stages.append((dc.replace(spec, n_layers=spec.n_layers - cut),
                             take(p_st, slice(cut, None))))
        seen += spec.n_layers
    return a_stages, b_stages


def make_split_serve(model: Model, params, s: int):
    """Build device/edge programs for split point s (decoder-only archs)."""
    cfg = model.cfg
    a_stages, b_stages = _split_params(model, params, s)

    def device_fn(tokens, frontend=None):
        b, sl = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(sl, dtype=jnp.int32)[None], (b, sl))
        x = embed_lookup(params["embed"], tokens)
        aux = {"pos": pos,
               "frontend": None if frontend is None else frontend.astype(COMPUTE_DTYPE),
               "moe_impl": model.moe_impl, "moe_capacity": model.moe_capacity}
        for spec, p_st in a_stages:
            x, _, _ = model._run_stage(spec, p_st, x, aux, None)
        return x.astype(COMPUTE_DTYPE)

    def edge_fn(x, frontend=None):
        b, sl, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(sl, dtype=jnp.int32)[None], (b, sl))
        aux = {"pos": pos,
               "frontend": None if frontend is None else frontend.astype(COMPUTE_DTYPE),
               "moe_impl": model.moe_impl, "moe_capacity": model.moe_capacity}
        for spec, p_st in b_stages:
            x, _, _ = model._run_stage(spec, p_st, x, aux, None)
        x = model._final_norm(params, x)
        return logits_out(x, params["unembed"], cfg.vocab_size)

    act_bytes = cfg.d_model * 2  # bf16 residual stream per token
    return SplitPrograms(device_fn=jax.jit(device_fn), edge_fn=jax.jit(edge_fn),
                         split_layer=s, act_bytes_per_token=act_bytes)


def transfer_seconds(n_tokens: int, d_model: int, rate_bps: float) -> float:
    """Simulated NOMA uplink time for the split activation."""
    bits = n_tokens * d_model * 16
    return bits / max(rate_bps, 1e-9)


def planned_transfer_seconds(env, prof, plan):
    """Per-user split-upload seconds under the *discrete* plan: the NOMA
    uplink rate each user actually gets on its assigned subchannel at its
    planned power, pricing prof.w[s] bits. This is the planner-side twin of
    `transfer_seconds` (which prices a raw token count at a given rate): for
    an LM profile built at batch=1, w[s] = seq * d_model * ACT_BITS, so the
    two agree exactly on the same rate. The online telemetry uses this as
    the modeled upload time an observation is compared against."""
    from repro.core import channel  # deferred: runtime must stay importable
                                    # without the solver stack in the loop
    beta_up = jax.nn.one_hot(plan.sub_up, env.n_sub, dtype=env.g_up.dtype)
    r_up = jnp.sum(channel.uplink_rates(env, beta_up, plan.p_up), axis=-1)
    bits = prof.w[plan.s]
    return bits / jnp.maximum(r_up, 1e-9)


def jit_masked_decode_step(model: Model, mesh, batch: int, max_len: int):
    """Slot-masked decode step for continuous batching: like
    jit_decode_step, but takes an `active` (B,) bool mask; inactive slots'
    caches (including pos) are frozen so a slot can idle between requests
    and be overwritten at its next admission. Returns (jitted step,
    params_sharding, cache_sharding); step(params, caches, token, active)
    -> (logits, new_caches)."""
    from repro.online.batcher import slot_where  # deferred: avoid cycle
                                                 # (online.loop imports serve)
    specs = model.specs()
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = shlib.tree_shardings(mesh, specs, params_shapes)
    cache_shapes = jax.eval_shape(lambda: model.make_caches(batch, max_len))
    c_shard = shlib.cache_shardings(mesh, cache_shapes, model.cfg)
    tok_shard = NamedSharding(mesh, shlib.batch_spec(mesh, (batch, 1)))

    def masked_step(params, caches, token, active):
        token = jnp.where(active[:, None], token, 0)
        logits, new_caches = model.decode_step(params, caches, token)
        return logits, slot_where(active, new_caches, caches)

    step = jax.jit(
        masked_step,
        in_shardings=(p_shard, c_shard, tok_shard, None),
        out_shardings=(None, c_shard),
        donate_argnums=(1,),
    )
    return step, p_shard, c_shard


# --------------------------------------------------------------------------
# online split-serve: re-plan as the scenario evolves, re-cut when s* moves
# --------------------------------------------------------------------------
class OnlineSplitServer:
    """Couples a PlannerEngine to split-serve across a time-evolving scenario.

    Every `replan_every` epochs the engine warm-start re-plans against the
    newly observed NetworkEnv; the (expensive) make_split_serve re-cut only
    happens when the planned split layer actually moves. `observe(env)`
    returns the current SplitPrograms.

    The epoch loop is device-resident: the engine's replan dispatches
    asynchronously (rho gate and warm payload are traced into the compiled
    program), GD-iteration accounting accumulates in a device scalar (read
    it lazily via the `total_iters` property), and the only host sync per
    replan is fetching the planned split layer s* -- the serve decision that
    chooses whether to re-cut the model is inherently a host branch.

    model/params may be None for planning-only runs (benchmarks, tests):
    the re-cut is then recorded but no programs are built.

    The PlanState threaded across epochs carries the full warm-start payload
    (normalized optima, Adam moments + step counts, and the epoch's gains for
    the engine's rho-adaptive gate). A network shape change (user count /
    subchannel count) invalidates that state: observe() catches the engine's
    shape-change ValueError, resets the warm state, and re-plans cold --
    `cold_resets` counts these events.

    With ``guard_plans=True`` (the default) the same one-scalar sync also
    traps *non-finite or infeasible* plans: the in-jit health check
    (faults.guards.plan_word) packs the plan's health bits above s* in the
    synced word, a bad plan is rejected and the last good PlanState held
    (`bad_plans` counts these, next to `cold_resets`), and the degradation
    ladder -- not the batcher -- decides what serves next. A NaN measured
    profile otherwise flows straight through replan into a served plan:
    utility goes NaN while the power vector can stay finite, so the guard
    checks the whole plan, not just the powers.
    """

    def __init__(self, engine, model: Model | None = None, params=None,
                 replan_every: int = 1, guard_plans: bool = True):
        if replan_every < 1:
            raise ValueError(f"replan_every must be >= 1, got {replan_every}")
        self.engine = engine
        self.model = model
        self.params = params
        self.replan_every = replan_every
        self.guard_plans = bool(guard_plans)
        self.state = None               # planning.PlanState of the last re-plan
        self.programs: SplitPrograms | None = None
        self.split_layer: int | None = None
        self.epoch = 0
        self.recuts = 0
        self.cold_resets = 0
        self.replans = 0                # scheduled + forced engine dispatches
        self.forced_replans = 0         # QoS-triggered (force=True) subset
        self.bad_plans = 0              # guarded replans rejected (held last good)
        self.last_plan_ok: bool | None = None   # outcome of the last dispatch
        self.last_replanned = False     # did the last observe() dispatch?
        self._iters_acc = jnp.zeros((), jnp.int32)  # device-side accumulator
        self._plan_word_fn = None       # jitted guard, built on first use

    @property
    def total_iters(self) -> int:
        """Total GD iterations across all re-plans. Reading it syncs the
        device accumulator; the serving loop itself never does."""
        return int(self._iters_acc)

    def metrics(self) -> dict:
        """Counters of the server's control-plane activity: epochs seen,
        replans dispatched (and how many were QoS-forced off-schedule),
        re-cuts of the served model, cold resets after network shape
        changes, and total GD iterations (this read syncs the device
        accumulator)."""
        return {
            "epoch": self.epoch,
            "replans": self.replans,
            "forced_replans": self.forced_replans,
            "recuts": self.recuts,
            "cold_resets": self.cold_resets,
            "bad_plans": self.bad_plans,
            "split_layer": self.split_layer,
            "total_iters": self.total_iters,
        }

    def export_host(self) -> dict:
        """The server's host-side control-plane state as JSON scalars, for
        the serving snapshot (repro.state). The device-resident pieces
        (PlanState and the GD-iteration accumulator) travel in the
        snapshot's device tree, not here."""
        return {
            "epoch": self.epoch,
            "recuts": self.recuts,
            "cold_resets": self.cold_resets,
            "replans": self.replans,
            "forced_replans": self.forced_replans,
            "bad_plans": self.bad_plans,
            "split_layer": self.split_layer,
            "last_plan_ok": self.last_plan_ok,
            "last_replanned": self.last_replanned,
        }

    def import_host(self, state: dict, iters_acc) -> None:
        """Inverse of export_host. ``iters_acc`` is the restored device
        scalar. When a served model is attached, the split programs are
        re-cut at the restored split layer (the compiled split programs
        themselves are not persisted -- they are pure functions of
        (model, params, s))."""
        self.epoch = int(state["epoch"])
        self.recuts = int(state["recuts"])
        self.cold_resets = int(state["cold_resets"])
        self.replans = int(state["replans"])
        self.forced_replans = int(state["forced_replans"])
        self.bad_plans = int(state["bad_plans"])
        sl = state["split_layer"]
        self.split_layer = None if sl is None else int(sl)
        ok = state["last_plan_ok"]
        self.last_plan_ok = None if ok is None else bool(ok)
        self.last_replanned = bool(state["last_replanned"])
        self._iters_acc = iters_acc
        if self.model is not None and self.split_layer is not None:
            self.programs = make_split_serve(self.model, self.params,
                                             self.split_layer)

    def reset_warm(self) -> None:
        """Drop the warm-start payload: the next replan goes cold. The
        degradation ladder calls this before a degraded-stage retry --
        after a run of rejected plans the carried moments/optima are
        themselves suspect."""
        self.state = None

    def _sync_plan(self, env, plan) -> tuple[int, int]:
        """The one host sync per replan: (health, s). Guarded servers pack
        both into a single scalar in-jit (faults.guards.plan_word); the
        guard program is jitted once per server (env consts are closures,
        the plan is an operand -- no cache growth across epochs)."""
        if not self.guard_plans:
            return 0, int(plan.s)
        if self._plan_word_fn is None:
            from repro.faults import guards
            from repro.planning.engine import _recorded
            self._plan_word_fn = jax.jit(_recorded(functools.partial(
                guards.plan_word, n_sub=env.n_sub,
                p_up_max=env.radio.p_up_max_w, p_dn_max=env.radio.p_dn_max_w,
                r_max=env.comp.r_max), "plan_guard"))
        from repro.faults.guards import split_plan_word
        return split_plan_word(int(self._plan_word_fn(plan)))

    def observe(self, env, prof=None, force: bool = False,
                hold: bool = False) -> SplitPrograms | None:
        """Advance one epoch: re-plan on schedule (or immediately when
        ``force`` is set -- the QoS monitor's trigger path), re-cut if s*
        moved. ``prof`` substitutes a measured profile (repro.online
        telemetry) as an operand of the engine's already-compiled programs;
        None plans against the engine's static profile. ``hold`` skips the
        replan outright (the ladder's backoff posture) while still
        advancing the epoch clock."""
        self.last_replanned = False
        if not hold and (force or self.epoch % self.replan_every == 0):
            prev_state = self.state
            try:
                new_state = self.engine.replan(self.state, env, prof=prof)
            except WarmStateShapeError:
                # Shape change: the warm-start state no longer fits this
                # network. Reset it and fall back to a cold plan. (Other
                # ValueErrors propagate -- swallowing them would silently
                # disable warm starts forever.)
                prev_state = self.state = None
                self.cold_resets += 1
                new_state = self.engine.plan(env, prof=prof)
            self.replans += 1
            self.last_replanned = True
            self.forced_replans += int(
                force and self.epoch % self.replan_every != 0)
            self._iters_acc = self._iters_acc + new_state.total_iters
            health, s = self._sync_plan(env, new_state.plan)
            if health:
                # Rung 1 of the ladder: never serve a corrupt plan. Keep
                # the last good state (warm payload included) and let the
                # ladder decide the follow-up posture.
                self.bad_plans += 1
                self.last_plan_ok = False
                self.state = prev_state
            else:
                self.last_plan_ok = True
                self.state = new_state
                if s != self.split_layer:
                    self.split_layer = s
                    self.recuts += 1
                    if self.model is not None:
                        self.programs = make_split_serve(self.model,
                                                         self.params, s)
        self.epoch += 1
        return self.programs
