"""Versioned serving snapshots: the durable half of the online loop.

A ``ServingSnapshot`` is one atomic directory ``snap_<epoch:08d>`` holding
the loop's complete episode state (OnlineLoop.serving_state):

  leaves.npz   every device-resident leaf -- base PRNG key, served plan,
               fault rates, scenario/stream/batch/QoS/telemetry/fault
               state, the server's PlanState (warm Adam payload included)
               and its GD-iteration accumulator
  meta.json    schema version, epoch, the loop's config fingerprint, the
               device treedef string, per-leaf dtype/shape/CRC-32, and the
               JSON host state (epoch clock, server counters, degradation-
               ladder state machine)

Write path: serialized into a tmp dir, then promoted with the checkpoint
manager's rename-aside dance -- a crash at any instant leaves either the
previous snapshot or the new one, never a torn directory. ``SnapshotStore``
adds a configurable epoch cadence, optional async writes (the state is
device_get on the caller's thread first, so donation can't mutate it
under the writer), and keep-n retention.

Restore path is *validating and retrace-free by construction*: the stored
treedef, per-leaf dtypes/shapes and checksums are checked against BOTH the
bytes read and the live loop's ``state_template`` avals (eval_shape of the
engine's plan/replan programs plus the live episode tree). Any leaf that
would have caused the already-compiled epoch/planner programs to retrace
is exactly a leaf that fails this validation, and raises
``SnapshotIntegrityError`` instead of restoring.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import (
    SnapshotIntegrityError,
    _promote,
    _recover,
    leaf_crc32,
)

SNAPSHOT_VERSION = 1
_SNAP_FMT = "snap_{:08d}"
_SNAP_RE = re.compile(r"snap_(\d{8})")


@dataclasses.dataclass(frozen=True)
class SnapshotConfig:
    """Durability knobs: snapshot every ``every`` epochs (the cadence), keep
    the ``keep_n`` newest on disk, write asynchronously unless
    ``asynchronous=False`` (sync writes are for tests and for callers that
    need the snapshot durable before the next epoch)."""

    every: int = 20
    keep_n: int = 3
    asynchronous: bool = True

    def __post_init__(self) -> None:
        if self.every < 1 or self.keep_n < 1:
            raise ValueError("every/keep_n must be >= 1")


def _capture(loop) -> tuple[list[np.ndarray], str, dict[str, Any], int]:
    """Snapshot the loop on the caller's thread: device_get host copies of
    every leaf (immune to donation by later epochs) + the host state."""
    device, host = loop.serving_state()
    flat, treedef = jax.tree_util.tree_flatten(device)
    leaves = [np.asarray(jax.device_get(x)) for x in flat]
    return leaves, str(treedef), host, int(host["host_epoch"])


def save_snapshot(directory: str, loop) -> str:
    """Write one snapshot of ``loop`` now (synchronous); returns its path."""
    leaves, treedef, host, epoch = _capture(loop)
    return _write(directory, leaves, treedef, host, epoch,
                  loop.config_fingerprint())


def _write(directory: str, leaves: list[np.ndarray], treedef: str,
           host: dict[str, Any], epoch: int, fingerprint: str) -> str:
    os.makedirs(directory, exist_ok=True)
    _recover(directory)
    final = os.path.join(directory, _SNAP_FMT.format(epoch))
    tmp = os.path.join(directory, f"tmp.{epoch}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    meta = {
        "version": SNAPSHOT_VERSION,
        "epoch": epoch,
        "fingerprint": fingerprint,
        "treedef": treedef,
        "n_leaves": len(leaves),
        "dtypes": [str(a.dtype) for a in leaves],
        "shapes": [list(a.shape) for a in leaves],
        "crc32s": [leaf_crc32(a) for a in leaves],
        "host": host,
    }
    np.savez(os.path.join(tmp, "leaves.npz"),
             **{f"a{i}": a for i, a in enumerate(leaves)})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    _promote(tmp, final)
    return final


def list_snapshots(directory: str) -> list[int]:
    """Epochs of complete snapshots under ``directory``, ascending."""
    _recover(directory)
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return []
    return sorted(int(m.group(1)) for n in names
                  if (m := _SNAP_RE.fullmatch(n)))


def _read_meta(path: str) -> dict[str, Any]:
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SnapshotIntegrityError(
            f"{path}: unreadable meta.json ({e})") from e
    for key in ("version", "epoch", "fingerprint", "treedef", "n_leaves",
                "dtypes", "shapes", "crc32s", "host"):
        if key not in meta:
            raise SnapshotIntegrityError(f"{path}: meta.json missing {key!r}")
    if meta["version"] != SNAPSHOT_VERSION:
        raise SnapshotIntegrityError(
            f"{path}: snapshot version {meta['version']}, this build reads "
            f"{SNAPSHOT_VERSION}")
    return meta


def load_snapshot(directory: str, loop, epoch: int) -> None:
    """Validate and restore ``snap_<epoch>`` into ``loop`` (which must be
    reset() already -- the snapshot supplies state, not programs).

    Validation order: meta.json well-formed -> config fingerprint matches
    the live loop -> treedef + per-leaf dtype/shape match the live epoch
    program's avals (``loop.state_template``) -> bytes read back match
    their recorded CRC-32s. Any failure raises SnapshotIntegrityError and
    leaves ``loop`` untouched."""
    path = os.path.join(directory, _SNAP_FMT.format(epoch))
    meta = _read_meta(path)
    live_fp = loop.config_fingerprint()
    if meta["fingerprint"] != live_fp:
        raise SnapshotIntegrityError(
            f"{path}: config fingerprint {meta['fingerprint']} does not "
            f"match the live loop ({live_fp}) -- the snapshot was taken "
            "under a different loop/engine configuration")
    kind = meta["host"].get("plan_state_kind")
    if kind not in ("cold", "warm", "none"):
        raise SnapshotIntegrityError(
            f"{path}: unknown plan_state_kind {kind!r}")
    template = loop.state_template(kind)
    tflat, tdef = jax.tree_util.tree_flatten(template)
    if meta["treedef"] != str(tdef):
        raise SnapshotIntegrityError(
            f"{path}: treedef mismatch\n  stored:   {meta['treedef']}\n"
            f"  expected: {str(tdef)}")
    if meta["n_leaves"] != len(tflat):
        raise SnapshotIntegrityError(
            f"{path}: {meta['n_leaves']} leaves stored, live template has "
            f"{len(tflat)}")
    for i, aval in enumerate(tflat):
        got_dt, got_sh = np.dtype(meta["dtypes"][i]), tuple(meta["shapes"][i])
        if got_dt != np.dtype(aval.dtype) or got_sh != tuple(aval.shape):
            raise SnapshotIntegrityError(
                f"{path}: leaf {i} stored as {got_dt}{list(got_sh)}, the "
                f"live program expects {np.dtype(aval.dtype)}"
                f"{list(aval.shape)} -- restoring it would retrace")
    try:
        with np.load(os.path.join(path, "leaves.npz")) as data:
            leaves = [data[f"a{i}"] for i in range(meta["n_leaves"])]
    except Exception as e:
        raise SnapshotIntegrityError(
            f"{path}: unreadable or truncated leaves.npz ({e})") from e
    for i, a in enumerate(leaves):
        if (str(a.dtype) != meta["dtypes"][i]
                or list(a.shape) != meta["shapes"][i]):
            raise SnapshotIntegrityError(
                f"{path}: leaf {i} bytes disagree with meta.json")
        if leaf_crc32(a) != meta["crc32s"][i]:
            raise SnapshotIntegrityError(
                f"{path}: leaf {i} failed its CRC-32 check")
    # Cast to committed device arrays with the template's exact avals --
    # jnp.asarray of a numpy array is strong-typed, so the restored leaves
    # are indistinguishable from the uninterrupted run's.
    device = jax.tree_util.tree_unflatten(
        tdef, [jnp.asarray(a) for a in leaves])
    loop.load_serving_state(device, meta["host"])


class SnapshotStore:
    """Cadenced, optionally-async snapshot writer + escalating restorer.

    ``maybe_save(loop)`` is the serving loop's per-epoch hook: it snapshots
    when the epoch clock hits the cadence. ``restore_newest_valid(loop)``
    is the crash supervisor's: it walks snapshots newest-first, skipping
    any that fail integrity validation, and reports what it skipped."""

    def __init__(self, directory: str,
                 cfg: SnapshotConfig = SnapshotConfig()):
        self.directory = directory
        self.cfg = cfg
        self.saves = 0
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def maybe_save(self, loop) -> str | None:
        """Snapshot iff the loop's epoch clock is on the cadence (and past
        epoch 0). Returns the final path (the *eventual* path for async
        writes), or None when off-cadence."""
        if loop.host_epoch <= 0 or loop.host_epoch % self.cfg.every != 0:
            return None
        return self.save(loop)

    def save(self, loop) -> str:
        """Snapshot now. The device state is captured (device_get) on the
        caller's thread either way; with ``asynchronous`` the serialization
        and the atomic promote happen on a background thread while the loop
        keeps stepping. Write errors surface on the next save/wait."""
        self.wait()
        leaves, treedef, host, epoch = _capture(loop)
        fingerprint = loop.config_fingerprint()
        final = os.path.join(self.directory, _SNAP_FMT.format(epoch))
        if not self.cfg.asynchronous:
            _write(self.directory, leaves, treedef, host, epoch, fingerprint)
            self._gc()
            self.saves += 1
            return final

        def work():
            try:
                _write(self.directory, leaves, treedef, host, epoch,
                       fingerprint)
                self._gc()
            except Exception as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self.saves += 1
        return final

    def wait(self) -> None:
        """Join any in-flight async write; re-raise its error, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def epochs(self) -> list[int]:
        return list_snapshots(self.directory)

    def restore(self, loop, epoch: int | None = None) -> int:
        """Restore the snapshot at ``epoch`` (default: newest) into
        ``loop``; returns the restored epoch. SnapshotIntegrityError on a
        corrupt snapshot, FileNotFoundError when there are none."""
        self.wait()
        epochs = self.epochs()
        if not epochs:
            raise FileNotFoundError(f"no snapshots under {self.directory}")
        epoch = epochs[-1] if epoch is None else epoch
        load_snapshot(self.directory, loop, epoch)
        return epoch

    def restore_newest_valid(self, loop) -> tuple[int, list[int]]:
        """Walk snapshots newest-first until one validates and restores;
        returns ``(restored_epoch, skipped_epochs)``. FileNotFoundError
        when every snapshot is corrupt or none exist -- the supervisor's
        cue to fall to the PR-9 ladder cold start."""
        self.wait()
        skipped: list[int] = []
        for epoch in reversed(self.epochs()):
            try:
                load_snapshot(self.directory, loop, epoch)
                return epoch, skipped
            except SnapshotIntegrityError:
                skipped.append(epoch)
        raise FileNotFoundError(
            f"no valid snapshot under {self.directory} "
            f"(skipped corrupt: {skipped})")

    def _gc(self) -> None:
        for e in list_snapshots(self.directory)[:-self.cfg.keep_n]:
            shutil.rmtree(os.path.join(self.directory, _SNAP_FMT.format(e)),
                          ignore_errors=True)
