"""Durable serving: versioned snapshots of the online loop's full state,
a deterministic-replay flight recorder, and a crash supervisor that
resumes bit-exactly from the newest valid snapshot. See
analysis.recovery_audit for the machine-checked guarantees."""
from repro.checkpoint.manager import SnapshotIntegrityError  # noqa: F401
from repro.state.journal import (  # noqa: F401
    FlightRecorder,
    effective_trajectory,
    pack_word,
    read_journal,
    replay,
    unpack_word,
)
from repro.state.snapshot import (  # noqa: F401
    SNAPSHOT_VERSION,
    SnapshotConfig,
    SnapshotStore,
    list_snapshots,
    load_snapshot,
    save_snapshot,
)
from repro.state.supervisor import CrashSupervisor, SimulatedCrash  # noqa: F401
