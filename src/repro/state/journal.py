"""Flight recorder: a deterministic replay journal for the online loop.

The loop's entire host-visible trace per epoch is tiny -- the packed
``(health << 16) | s`` plan word, the QoS trigger bit, the ladder stage --
and all of its device-side randomness is ``fold_in(base_key, epoch)``, so
an episode is fully determined by (seed, fault-rate swap schedule, epoch
count). The journal records exactly that: one JSONL line per event, each
line carrying a CRC-32 of its canonical payload so a torn tail (the crash
case) or a tampered record is detected rather than replayed.

Record kinds:

  start     {seed, fingerprint}                 episode begins (reset key)
  epoch     {t, word, trigger, stage}           one served epoch's trace
  rates     {t, rates}                          set_fault_rates swap
  snapshot  {t, path}                           a snapshot was cut
  restore   {t, from}                           supervisor resumed from
                                                ``from`` after a crash at t

``effective_trajectory`` collapses restore rewinds (epochs re-executed
after a resume supersede nothing -- bit-exact resume means they *equal*
the originals, which the divergence detector verifies). ``replay`` re-runs
the episode from the journal alone and reports the first epoch, if any,
whose served (s*, health, trigger) diverges from the recorded word --
the postmortem tool: a clean replay localizes a production anomaly to
recorded host input rather than loop nondeterminism.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Callable

import jax

from repro.faults.guards import PLAN_WORD_SHIFT
from repro.faults.injectors import FaultConfig


def _crc(payload: dict[str, Any]) -> int:
    return zlib.crc32(json.dumps(payload, sort_keys=True).encode())


def pack_word(health: int, s: int) -> int:
    """The journal's epoch word, identical to the in-jit packing the loop
    syncs (faults.guards.plan_word): ``(health << 16) | s``."""
    return (int(health) << PLAN_WORD_SHIFT) | int(s)


def unpack_word(word: int) -> tuple[int, int]:
    return word >> PLAN_WORD_SHIFT, word & ((1 << PLAN_WORD_SHIFT) - 1)


class FlightRecorder:
    """Append-only JSONL journal writer. Every record is flushed on write
    (a crash loses at most the line being written, which the reader's CRC
    check drops); the file handle is opened lazily and appends, so a
    restarted supervisor keeps journaling into the same flight record."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None

    def _emit(self, payload: dict[str, Any]) -> None:
        if self._fh is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "a")
        rec = dict(payload)
        rec["crc"] = _crc(payload)
        self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
        self._fh.flush()

    def record_start(self, seed: int, fingerprint: str) -> None:
        self._emit({"kind": "start", "seed": int(seed),
                    "fingerprint": fingerprint})

    def record_epoch(self, t: int, s: int, health: int, trigger: bool,
                     stage: str) -> None:
        self._emit({"kind": "epoch", "t": int(t),
                    "word": pack_word(health, s),
                    "trigger": bool(trigger), "stage": stage})

    def record_rates(self, t: int, rates: dict[str, float]) -> None:
        self._emit({"kind": "rates", "t": int(t), "rates": rates})

    def record_snapshot(self, t: int, path: str) -> None:
        self._emit({"kind": "snapshot", "t": int(t), "path": path})

    def record_restore(self, t: int, from_epoch: int) -> None:
        self._emit({"kind": "restore", "t": int(t),
                    "from": int(from_epoch)})

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def read_journal(path: str) -> tuple[list[dict[str, Any]], bool]:
    """Parse a journal; returns ``(records, clean)``. Reading stops at the
    first unparseable or CRC-failing line: a torn tail (crash mid-write) is
    expected and simply truncates, so ``clean=False`` + every record up to
    the tear. A mid-file tamper truncates the same way -- everything after
    an untrusted line is untrusted."""
    records: list[dict[str, Any]] = []
    clean = True
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    crc = rec.pop("crc")
                except (json.JSONDecodeError, KeyError, TypeError):
                    clean = False
                    break
                if _crc(rec) != crc:
                    clean = False
                    break
                records.append(rec)
    except FileNotFoundError:
        return [], False
    return records, clean


def effective_trajectory(records: list[dict[str, Any]]) -> dict[str, Any]:
    """Collapse the journal into the episode's effective host trace:

      epochs  {t: epoch-record}  last write wins (a resume re-executes
              epochs k+1.. after a restore record; bit-exact resume means
              re-executions equal the originals -- ``replay`` checks that)
      rates   [(t, FaultConfig kwargs)]  swap schedule, restore-rewound
      seed    from the first start record (None when the journal starts
              mid-episode)
    """
    epochs: dict[int, dict[str, Any]] = {}
    rates: list[tuple[int, dict[str, float]]] = []
    seed = None
    fingerprint = None
    for rec in records:
        kind = rec.get("kind")
        if kind == "start":
            if seed is None:
                seed = rec["seed"]
                fingerprint = rec["fingerprint"]
        elif kind == "epoch":
            epochs[rec["t"]] = rec
        elif kind == "rates":
            rates.append((rec["t"], rec["rates"]))
        elif kind == "restore":
            # epochs t > from were lost to the crash and will re-execute;
            # rate swaps journaled after the restore point re-apply too.
            k = rec["from"]
            rates = [(t, r) for t, r in rates if t <= k]
    return {"seed": seed, "fingerprint": fingerprint, "epochs": epochs,
            "rates": rates}


def replay(records: list[dict[str, Any]], factory: Callable[[], Any],
           n_epochs: int | None = None) -> dict[str, Any]:
    """Deterministically re-run a journaled episode and diff it.

    ``factory`` builds a fresh OnlineLoop configured exactly as the
    recorded one (the start record's fingerprint is checked against it).
    The journal supplies the seed and the fault-rate swap schedule -- the
    only host inputs; everything else is fold_in-derived on device. Returns

      {"epochs": n, "divergence": None | {"t", "expected", "got"}}

    where divergence reports the FIRST epoch whose served plan word,
    trigger, or ladder stage differs from the journal. None means the
    journal reproduces the s*/health trajectory exactly."""
    traj = effective_trajectory(records)
    if traj["seed"] is None:
        raise ValueError("journal has no start record; cannot replay")
    loop = factory()
    fp = loop.config_fingerprint()
    if traj["fingerprint"] != fp:
        raise ValueError(
            f"journal fingerprint {traj['fingerprint']} does not match the "
            f"factory's loop ({fp})")
    epochs = traj["epochs"]
    last_t = max(epochs) if epochs else 0
    n = last_t if n_epochs is None else min(n_epochs, last_t)
    swaps = dict(traj["rates"])  # t -> rates kwargs (post-epoch-t swap)
    loop.reset(jax.random.PRNGKey(traj["seed"]))
    if 0 in swaps:
        loop.set_fault_rates(FaultConfig(**swaps[0]))
    divergence = None
    for _ in range(n):
        out, trigger = loop.step_epoch()
        t = loop.host_epoch
        rec = epochs.get(t)
        if rec is not None:
            got = {"word": pack_word(int(out.health), int(loop._plan.s)),
                   "trigger": bool(trigger),
                   "stage": loop.ladder.stage if loop.ladder is not None
                   else "normal"}
            exp = {"word": rec["word"], "trigger": rec["trigger"],
                   "stage": rec["stage"]}
            if got != exp:
                divergence = {"t": t, "expected": exp, "got": got}
                break
        if t in swaps:
            loop.set_fault_rates(FaultConfig(**swaps[t]))
    return {"epochs": n, "divergence": divergence}
