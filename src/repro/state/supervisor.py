"""Crash supervisor: restart a killed serving loop from durable state.

The supervisor owns the epoch loop that ``OnlineLoop.run`` would otherwise
drive, adding the durability hooks around each step:

  * cut snapshots on the store's cadence (``SnapshotStore.maybe_save``)
  * journal every epoch/snapshot/restore into the flight recorder
  * catch a crash (any exception out of the epoch -- a raised-mid-epoch
    fault, or the test/benchmark chaos hook's ``SimulatedCrash``) and
    rebuild: fresh loop from the factory, reset with the episode key,
    then restore, escalating exactly as the ISSUE's ladder names it --

      newest snapshot -> (checksum fail) -> previous snapshot -> ...
      -> (none valid) -> PR-9 ladder cold start from epoch 0

Because restore is bit-exact (repro.state.snapshot) and all host decisions
are deterministic functions of restored counters, the epochs re-executed
after a resume equal the uninterrupted run's leaf-for-leaf -- recovery
costs wall-clock (``recovery_epochs`` counts the re-executed epochs), not
correctness.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.state.journal import FlightRecorder
from repro.state.snapshot import SnapshotStore


class SimulatedCrash(RuntimeError):
    """Raised by chaos hooks to kill the loop mid-flight in tests and the
    recovery benchmark -- stands in for a process kill."""


class CrashSupervisor:
    """Drives an OnlineLoop to ``n_epochs`` across crashes.

    factory    () -> OnlineLoop, the *same* configuration every call (the
               snapshot fingerprint enforces this).
    store      SnapshotStore for durability; None disables snapshots (the
               benchmark's no-checkpoint arm: every crash is a cold start).
    recorder   FlightRecorder journaling the run; optional.
    max_restarts  crash budget before the supervisor re-raises.
    """

    def __init__(self, factory: Callable[[], Any],
                 store: SnapshotStore | None = None,
                 recorder: FlightRecorder | None = None,
                 max_restarts: int = 5):
        self.factory = factory
        self.store = store
        self.recorder = recorder
        self.max_restarts = max_restarts
        self.loop = None
        # recovery accounting (surfaced via metrics())
        self.restarts = 0
        self.cold_restarts = 0
        self.corrupt_snapshots = 0
        self.recovery_epochs = 0       # epochs re-executed after restores
        self.restored_from: list[int] = []

    def _boot(self, key: jax.Array, seed: int | None):
        loop = self.factory()
        if self.recorder is not None:
            loop.attach_recorder(self.recorder)
            if seed is not None:
                self.recorder.record_start(seed, loop.config_fingerprint())
        loop.reset(key)
        return loop

    def _recover(self, key: jax.Array, crash_epoch: int):
        """Rebuild after a crash: fresh loop, newest valid snapshot, the
        escalation ladder on integrity failures, cold start at the end."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"crash budget exhausted ({self.max_restarts} restarts)")
        loop = self._boot(key, None)    # reset == the ladder cold start
        restored = 0
        if self.store is not None:
            try:
                restored, skipped = self.store.restore_newest_valid(loop)
                self.corrupt_snapshots += len(skipped)
            except FileNotFoundError:
                # every listed snapshot was tried and failed validation
                self.corrupt_snapshots += len(self.store.epochs())
                self.cold_restarts += 1
        else:
            self.cold_restarts += 1
        self.recovery_epochs += max(crash_epoch - restored, 0)
        self.restored_from.append(restored)
        if self.recorder is not None:
            self.recorder.record_restore(crash_epoch, restored)
        return loop

    def run(self, key: jax.Array, n_epochs: int, seed: int | None = None,
            record: bool = False,
            chaos: Callable[[int], None] | None = None) -> dict:
        """Run to ``n_epochs`` completed epochs, surviving crashes.

        ``chaos(next_epoch)`` is called before each epoch and may raise to
        simulate a crash (SimulatedCrash or anything else non-exiting).
        ``seed`` labels the journal's start record for replay; pass the
        integer that made ``key``. With record=True the returned metrics
        carry the run()-compatible per-epoch history -- rewound on restore,
        so re-executed epochs appear once."""
        self.loop = loop = self._boot(key, seed)
        hist = loop.history_init()
        while loop.host_epoch < n_epochs:
            try:
                if chaos is not None:
                    chaos(loop.host_epoch + 1)
                out, trigger = loop.step_epoch()
                if record:
                    loop.record_history(hist, out, trigger)
                if self.store is not None:
                    path = self.store.maybe_save(loop)
                    if path is not None and self.recorder is not None:
                        self.recorder.record_snapshot(loop.host_epoch, path)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                crash_epoch = loop.host_epoch
                if self.store is not None:
                    # A real kill would also lose the writer thread; join it
                    # so the restart sees a quiesced directory either way.
                    try:
                        self.store.wait()
                    except Exception:
                        pass
                self.loop = loop = self._recover(key, crash_epoch)
                if record:
                    for col in hist.values():
                        del col[loop.host_epoch:]
        m = loop.metrics()
        m.update(self.metrics())
        if record:
            m["history"] = hist
        return m

    def metrics(self) -> dict:
        return {
            "restarts": self.restarts,
            "cold_restarts": self.cold_restarts,
            "corrupt_snapshots": self.corrupt_snapshots,
            "supervisor_recovery_epochs": self.recovery_epochs,
            "restored_from": list(self.restored_from),
            "snapshots_saved": self.store.saves if self.store else 0,
        }
