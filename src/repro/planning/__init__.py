"""Unified planning stack: PlannerEngine over static, batched, and
time-correlated (online warm-start) environments -- vmapped on one device
or shard_map-sharded over a fleet mesh (see repro.pshard.fleet_mesh)."""
from repro.planning.engine import (  # noqa: F401
    PlannerEngine,
    PlanState,
    WarmStateShapeError,
    compile_log,
    member,
    stack_envs,
)
from repro.pshard import (  # noqa: F401
    fleet_axis,
    fleet_mesh,
    fleet_sharding,
    shard_fleet,
)
