"""Unified planning stack: PlannerEngine over static, batched, and
time-correlated (online warm-start) environments."""
from repro.planning.engine import (  # noqa: F401
    PlannerEngine,
    PlanState,
    WarmStateShapeError,
    member,
    stack_envs,
)
