"""PlannerEngine: the unified entry point for single-shot, batched, and
online warm-started ECC planning -- single scenarios, vmapped fleets, and
mesh-sharded fleets.

The engine owns a cache of compiled solver programs keyed on
(entry kind, env shape, GdConfig, method, rounding), so a serving loop that
re-plans every epoch pays tracing/compilation once per network shape. The
entry points share the cache:

  plan(env)             -- one-shot solve (the paper's Table I).
  plan_many(envs)       -- vmapped Monte-Carlo over stacked realizations
                           (one compiled program optimizes all draws). With a
                           mesh attached (mesh=... or engine.shard(mesh)) the
                           fleet dim is split across devices via shard_map.
  replan(prev, env)     -- online Li-GD: every split point warm-starts from
                           the previous epoch's normalized optimum at the
                           same split *and resumes its Adam moments*, so the
                           optimizer continues its trajectory instead of
                           re-biasing from zero. Under time-correlated fading
                           the previous optimum is near-optimal, so this is
                           the paper's warm-start argument (Corollary 4)
                           applied across *time* instead of across split
                           points.
  replan_many(prev, envs) -- the fleet replan: scenarios evolving in
                           parallel, one compiled program; sharded over the
                           mesh when one is attached (the carried PlanState
                           payload is donated to XLA on that path).

All entry points return a PlanState carrying the discrete SplitPlan plus the
solver state needed to warm-start the next epoch: the stacked normalized
optima, the per-split Adam moments and step counts, and the epoch's uplink
gains.

Everything in the replan dispatch path is device-resident: the rho-adaptive
warm gate -- estimate the epoch-to-epoch channel correlation between the
stored and observed gains, and run the exact cold Li-GD chain instead of the
temporal warm starts for any scenario whose estimate drops below
`warm_rho_min` -- is computed *inside* the compiled program
(li_gd.rho_estimate + a traced use_warm select), as is the Adam-moment
decay. replan/replan_many therefore enqueue asynchronously with zero host
syncs; the estimate itself is returned as PlanState.warm_rho. At low
correlation the previous optimum is stale and warm-starting from it costs
iterations instead of saving them. Independently of the gate, each split
point only adopts the temporal start when one utility probe says it beats
the fresh chain carry, so replan is never structurally worse than a cold
sweep.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 promoted shard_map out of experimental
    from jax import shard_map as _shard_map_impl  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

from repro.core import channel, li_gd
from repro.core.types import (
    Array,
    EccWeights,
    GdConfig,
    ModelProfile,
    NetworkEnv,
    SplitPlan,
    make_weights,
)
from repro.pshard import axis_size, fleet_axis


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """shard_map with replication checking off: the solver's lax.while_loop
    has no replication rule on older jax, and every output here is fully
    fleet-sharded anyway. Newer jax renamed/dropped the kwarg."""
    try:
        return _shard_map_impl(fn, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)
    except TypeError:
        return _shard_map_impl(fn, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs)


# -- compile observability --------------------------------------------------
# Every solver program the engine jits is wrapped so that each TRACE (which
# is exactly each compilation: jax.jit re-runs the python body only when the
# signature cache misses) appends its entry-point kind to the active logs.
# This is what makes "replan compiled exactly once across cold->warm->cold"
# machine-checkable (repro.analysis probes + the recompile regression test)
# instead of an assumption about the PR 3 weak-type fix.
_COMPILE_LOGS: list[list[str]] = []


@contextlib.contextmanager
def compile_log():
    """Record the kind of every engine program traced inside the block:

        with compile_log() as log:
            eng.plan(env); eng.replan(state, env)
        assert log == ["plan", "replan"]

    Entries appear at trace time, so a steady-state loop that appends
    nothing proves zero recompiles. Nesting is fine (each context gets its
    own list); tracing-only inspection (engine.program + jax.make_jaxpr /
    jax.eval_shape) also records, so keep audit traffic outside the block
    when counting execution compiles."""
    sink: list[str] = []
    _COMPILE_LOGS.append(sink)
    try:
        yield sink
    finally:
        _COMPILE_LOGS.remove(sink)


def _recorded(fn, kind: str):
    """Wrap a to-be-jitted solver program so each trace logs its kind."""
    @functools.wraps(fn)
    def wrapped(*args):
        for sink in _COMPILE_LOGS:
            sink.append(kind)
        return fn(*args)
    return wrapped


class WarmStateShapeError(ValueError):
    """A warm-start PlanState does not fit the observed network shape
    (user/AP/subchannel count changed, or a fleet state was handed to the
    single-scenario entry point and vice versa); re-plan cold instead."""


class PlanState(NamedTuple):
    """A plan plus the solver state needed to warm-start the next epoch.
    All leaves are device arrays: the state round-trips through
    replan/replan_many without ever being pulled to host."""

    plan: SplitPlan
    norms: dict          # per-split normalized optima, leaves lead with (F+1, ...)
    total_iters: Array   # () total GD iterations spent producing this plan
    moms: tuple | None = None      # per-split Adam moments (m1, m2), leaves (F+1, ...)
    opt_steps: Array | None = None # (F+1,) int32 optimizer steps behind `moms`
    gains: Array | None = None     # g_up of the planned epoch (rho estimation)
    warm_rho: Array | None = None  # () in-jit rho estimate behind the warm gate
                                   # (None when the state came from a cold plan)


def stack_envs(envs: Sequence[NetworkEnv]) -> NetworkEnv:
    """Stack same-shape environments along a leading Monte-Carlo dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *envs)


def member(tree, i: int):
    """Slice fleet member i out of a batched pytree (stacked NetworkEnv or
    batched PlanState). Scalar leaves -- e.g. radio/comp constants that
    Scenario.env_many broadcast or that stayed unbatched -- pass through."""
    return jax.tree.map(lambda x: x[i] if getattr(x, "ndim", 0) > 0 else x,
                        tree)


def _strong_typed(tree):
    """Strip weak types from every leaf. The cold and warm solver programs
    must emit byte-identical PlanState avals: a weak-f32 leaf from the cold
    program would re-trace the warm program once on the first replan (and
    again on the second, when the warm output feeds back)."""
    return jax.tree.map(
        lambda x: jax.lax.convert_element_type(x, x.dtype)
        if getattr(x, "weak_type", False) else x, tree)


def _solve_state(env, prof, w, cfg, method, rounding) -> PlanState:
    loop = li_gd.gd_loop(env, prof, w, cfg, chain=(method == "li_gd"))
    plan = li_gd.assemble_plan(env, loop, prof, rounding=rounding, w=w,
                               backend=cfg.sinr_backend)
    return _strong_typed(
        PlanState(plan=plan, norms=loop.norms, total_iters=loop.total_iters,
                  moms=loop.moms, opt_steps=loop.opt_steps, gains=env.g_up))


def _resolve_state(env, prof, w, warm, warm_mom, warm_steps, prev_gains,
                   cfg, method, rounding, warm_rho_min,
                   warm_moment_decay) -> PlanState:
    """The fully traced replan program: rho gate, moment decay, warm solve,
    and plan assembly all happen on device inside one compiled call."""
    del method  # warm mode supersedes the chain-vs-cold distinction
    rho = li_gd.rho_estimate(prev_gains, env.g_up)
    # warm_rho_min is a trace-time constant per engine; rho is in [0, 1], so
    # warm_rho_min <= 0 means the gate is always open (fallback disabled).
    use_warm = rho >= warm_rho_min
    if warm_moment_decay != 1.0:
        warm_mom = jax.tree.map(lambda x: warm_moment_decay * x, warm_mom)
    loop = li_gd.gd_loop(env, prof, w, cfg, warm=warm, warm_mom=warm_mom,
                         warm_steps=warm_steps, use_warm=use_warm)
    plan = li_gd.assemble_plan(env, loop, prof, rounding=rounding, w=w,
                               backend=cfg.sinr_backend)
    return _strong_typed(
        PlanState(plan=plan, norms=loop.norms, total_iters=loop.total_iters,
                  moms=loop.moms, opt_steps=loop.opt_steps, gains=env.g_up,
                  warm_rho=rho))


class PlannerEngine:
    """Compiled-solver cache + unified planning API for one model profile.

    method: 'li_gd' (paper warm-start chain) or 'gd' (cold-start baseline).
    rounding: 'best' | 'greedy' | 'paper' (see li_gd.assemble_plan).
    mesh: optional jax.sharding.Mesh. When set, plan_many/replan_many run
        via shard_map with the fleet dim split over the mesh's fleet axis
        ('fleet' when present, else the first axis); the fleet size must be
        divisible by that axis. The carried warm-start payload is donated to
        XLA on the sharded replan path (the engine returns the next epoch's
        state, so the previous one is dead weight). engine.shard(mesh) is
        the fluent variant: a sharded twin of an existing engine.
    warm_rho_min: replan's rho-adaptive gate -- a scenario whose estimated
        epoch-to-epoch correlation falls below this threshold has its
        temporal warm starts disabled (the compiled warm program then runs
        the exact cold Li-GD chain), because a stale optimum is a worse
        start than no prior at all. The estimate and the gate are traced
        into the compiled program (no host sync); 0.0 disables the fallback.
    sinr_backend: SINR path traced into every compiled solver program
        ('einsum' | 'pallas' | 'pallas_interpret'; None keeps cfg's value).
        The Pallas pairwise kernel is differentiable (custom_vjp with a
        transposed-streaming backward kernel), so 'pallas' makes the GD hot
        loop itself stream-tiled -- end-to-end, including the vmapped and
        mesh-sharded fleet paths. The choice is folded into GdConfig and
        therefore into the compiled-program cache key: already-compiled
        programs keep the backend they were traced with, and an engine with
        a different backend mints new cache entries instead of mutating
        live ones (channel.set_sinr_backend's global never reaches engine
        programs).
    warm_moment_decay: factor applied to the carried Adam moments on resume
        (inside the compiled program). The sweet spot is a *softened*
        restart: carrying the moments verbatim steers the new epoch with a
        stale direction and over-remembered scale (slightly worse optima),
        while zeroing them re-biases Adam from t=0 and its sign-like opening
        steps walk away from the near-optimal start (many extra iterations).
        Decaying both moments -- with the step count carried so bias
        correction does not re-amplify them -- keeps per-coordinate scale
        memory but lets fresh gradients dominate within a few steps.
        1.0 resumes verbatim, 0.0 zeroes.
    """

    def __init__(
        self,
        prof: ModelProfile,
        weights: EccWeights | None = None,
        cfg: GdConfig = GdConfig(),
        method: str = "li_gd",
        rounding: str = "best",
        warm_rho_min: float = 0.5,
        warm_moment_decay: float = 0.1,
        mesh: Mesh | None = None,
        sinr_backend: str | None = None,
    ):
        if method not in ("li_gd", "gd"):
            raise KeyError(method)
        if sinr_backend is not None:
            cfg = dataclasses.replace(cfg, sinr_backend=sinr_backend)
        # Validate the *effective* backend, whichever route supplied it
        # (the kwarg or GdConfig(sinr_backend=...)), so a bad value fails
        # here instead of deep inside the first plan() trace.
        if cfg.sinr_backend not in channel.SINR_BACKENDS:
            raise ValueError(
                f"sinr_backend must be one of {channel.SINR_BACKENDS}, "
                f"got {cfg.sinr_backend!r}")
        if not 0.0 <= warm_rho_min <= 1.0:
            raise ValueError(f"warm_rho_min must be in [0, 1], got {warm_rho_min}")
        if not 0.0 <= warm_moment_decay <= 1.0:
            raise ValueError(
                f"warm_moment_decay must be in [0, 1], got {warm_moment_decay}")
        if mesh is not None and not mesh.axis_names:
            raise ValueError("mesh must have at least one axis")
        self._prof = prof
        self._weights = weights
        if mesh is None:
            self._prof_rep = self._weights_rep = None
        else:
            # Pre-place replicated copies of the engine constants over the
            # mesh once, so steady-state *sharded* dispatch needs no implicit
            # transfers (fleet-batched inputs are the caller's:
            # pshard.shard_fleet). The originals stay unplaced: the
            # single-scenario plan/replan programs are not mesh programs and
            # would reject mixed device commitments.
            rep = NamedSharding(mesh, P())
            self._prof_rep = jax.device_put(prof, rep)
            self._weights_rep = (None if weights is None
                                 else jax.device_put(weights, rep))
        self.cfg = cfg
        self.method = method
        self.rounding = rounding
        self.warm_rho_min = warm_rho_min
        self.warm_moment_decay = warm_moment_decay
        self._mesh = mesh
        self._cache: dict[tuple, object] = {}

    @property
    def mesh(self) -> Mesh | None:
        """Read-only: the replicated constants and the compiled fleet
        programs are lowered per mesh, so swap meshes via shard(), not by
        assigning the attribute."""
        return self._mesh

    @property
    def prof(self) -> ModelProfile:
        """Read-only: mesh engines hold a replicated copy baked at
        construction; build a new engine for a different profile."""
        return self._prof

    @property
    def weights(self) -> EccWeights | None:
        """Read-only: mesh engines hold a replicated copy baked at
        construction; pass per-call weights or build a new engine."""
        return self._weights

    @property
    def sinr_backend(self) -> str:
        """The SINR backend traced into this engine's compiled programs
        (folded into cfg, hence into every cache key)."""
        return self.cfg.sinr_backend

    def _prof_arg(self, prof: ModelProfile | None,
                  sharded: bool = False) -> ModelProfile:
        """The profile operand for one dispatch. ``prof`` overrides the
        static profile with a *measured* one (repro.online telemetry): it is
        validated against the static profile's layer structure, dtypes and
        name here -- host metadata only, so a mismatch raises a clear
        ProfileShapeError instead of recompiling (or failing inside) the
        jitted solver. A compatible override hits the same compiled program:
        the profile is an operand, never a trace constant."""
        if prof is None:
            return (self._prof_rep if sharded else self._prof)
        self._prof.validate_like(prof)
        if sharded:
            # Replicate the override explicitly, as _w does for weights:
            # sharded dispatch must not pay an implicit per-call reshard.
            return jax.device_put(prof, NamedSharding(self.mesh, P()))
        return prof

    def shard(self, mesh: Mesh | None) -> "PlannerEngine":
        """A twin of this engine whose fleet entry points run shard_map over
        `mesh` (None returns a plain vmapped twin). The compiled-program
        cache is not shared: sharded programs are lowered per mesh."""
        return PlannerEngine(
            self.prof, weights=self.weights, cfg=self.cfg, method=self.method,
            rounding=self.rounding, warm_rho_min=self.warm_rho_min,
            warm_moment_decay=self.warm_moment_decay, mesh=mesh,
        )

    # -- compiled-program cache ------------------------------------------
    def _env_shape(self, env: NetworkEnv) -> tuple:
        return tuple(env.g_up.shape)

    def _fleet_axis_size(self) -> int:
        return axis_size(self.mesh, fleet_axis(self.mesh))

    def _check_fleet_divisible(self, b: int):
        nd = self._fleet_axis_size()
        if b % nd != 0:
            raise ValueError(
                f"fleet size {b} is not divisible by the mesh fleet axis "
                f"'{fleet_axis(self.mesh)}' ({nd} devices); pad the fleet or "
                "use a divisor-sized mesh (repro.pshard.fleet_mesh(n))")

    def _compiled(self, kind: str, env: NetworkEnv):
        # warm_rho_min / warm_moment_decay are trace-time constants of the
        # compiled replan programs, so they belong in the key: retuning them
        # on a live engine must recompile, not silently keep the old gate.
        key = (kind, self._env_shape(env), self.cfg, self.method, self.rounding,
               self.warm_rho_min, self.warm_moment_decay)
        fn = self._cache.get(key)
        if fn is None:
            solve = functools.partial(_solve_state, cfg=self.cfg,
                                      method=self.method, rounding=self.rounding)
            resolve = functools.partial(
                _resolve_state, cfg=self.cfg, method=self.method,
                rounding=self.rounding, warm_rho_min=self.warm_rho_min,
                warm_moment_decay=self.warm_moment_decay)
            if kind == "plan":
                fn = jax.jit(_recorded(solve, kind))
            elif kind == "plan_many":
                fn = jax.jit(_recorded(
                    jax.vmap(solve, in_axes=(0, None, None)), kind))
            elif kind == "replan":
                fn = jax.jit(_recorded(resolve, kind))
            elif kind == "replan_many":
                fn = jax.jit(_recorded(
                    jax.vmap(resolve, in_axes=(0, None, None, 0, 0, 0, 0)),
                    kind))
            elif kind == "plan_many_sharded":
                ax = fleet_axis(self.mesh)
                fn = jax.jit(_recorded(_shard_map(
                    jax.vmap(solve, in_axes=(0, None, None)), mesh=self.mesh,
                    in_specs=(P(ax), P(), P()), out_specs=P(ax)), kind))
            elif kind == "replan_many_sharded":
                ax = fleet_axis(self.mesh)
                # The carried payload (norms, moms, steps) is donated: the
                # caller threads the *returned* PlanState to the next epoch,
                # so XLA may reuse the previous epoch's buffers in place.
                fn = jax.jit(
                    _recorded(_shard_map(
                        jax.vmap(resolve, in_axes=(0, None, None, 0, 0, 0, 0)),
                        mesh=self.mesh,
                        in_specs=(P(ax), P(), P(), P(ax), P(ax), P(ax), P(ax)),
                        out_specs=P(ax)), kind),
                    donate_argnums=(3, 4, 5))
            else:
                raise KeyError(kind)
            self._cache[key] = fn
        return fn

    def cache_size(self) -> int:
        return len(self._cache)

    def cache_keys(self) -> list[tuple]:
        """The compiled-program cache keys, for cache-discipline audits:
        (kind, env shape, GdConfig, method, rounding, warm_rho_min,
        warm_moment_decay). Read-only snapshot."""
        return list(self._cache)

    # -- program introspection (repro.analysis hooks) --------------------
    def program(self, kind: str, env: NetworkEnv):
        """The jitted program this engine dispatches for (kind, env) --
        built and cached on first access exactly as the entry points do.
        Pair with program_args() to trace it (jax.make_jaxpr / eval_shape)
        without executing: the repro.analysis auditor's entry point."""
        return self._compiled(kind, env)

    def program_args(self, kind: str, env: NetworkEnv,
                     prev: PlanState | None = None,
                     weights: EccWeights | None = None,
                     prof: ModelProfile | None = None) -> tuple:
        """The positional argument tuple program(kind, env) is called with.

        ``env`` is a single environment for plan/replan and a stacked fleet
        for the *_many kinds; replan kinds need ``prev`` (a PlanState of
        arrays, or of ShapeDtypeStructs from jax.eval_shape for trace-only
        audits -- the warm payload assembly is pure metadata in that case).
        ``prof`` substitutes a measured profile, exactly as the entry points
        do (validated, same compiled program)."""
        many = "many" in kind
        nu = env.g_up.shape[1] if many else env.n_users
        w = self._w(env, weights, n_users=nu)
        prof = self._prof_arg(prof)
        if kind.startswith("plan"):
            return (env, prof, w)
        if prev is None:
            raise ValueError(
                f"program_args({kind!r}) needs prev= (a PlanState or its "
                "jax.eval_shape avals) to assemble the warm payload")
        norms, moms, steps, prev_gains = self._warm_args(prev, env.g_up)
        return (env, prof, w, norms, moms, steps, prev_gains)

    def _w(self, env: NetworkEnv, weights, n_users: int | None = None,
           sharded: bool = False) -> EccWeights:
        if weights is None:
            if self.weights is not None:
                return self._weights_rep if sharded else self.weights
            weights = make_weights(env.n_users if n_users is None else n_users)
        if sharded:
            # Caller-supplied (or freshly derived) weights: replicate them
            # over the mesh explicitly, or every sharded dispatch pays an
            # implicit reshard (and trips jax.transfer_guard('disallow')).
            return jax.device_put(weights, NamedSharding(self.mesh, P()))
        return weights

    # -- warm-state shape validation (host metadata only, no device sync) --
    @staticmethod
    def _warm_dims(prev: PlanState) -> tuple[int | None, tuple[int, int]]:
        """(fleet size | None, (U, M)) read off a PlanState's norms. Leaves
        are (F+1, U, M) for a single scenario and (B, F+1, U, M) for a
        fleet; the trailing two dims are the network shape in both cases."""
        beta = prev.norms["beta_up"]
        nd = getattr(beta, "ndim", 0)
        if nd == 3:
            return None, tuple(beta.shape[-2:])
        if nd == 4:
            return int(beta.shape[0]), tuple(beta.shape[-2:])
        raise WarmStateShapeError(
            f"warm-start norms have rank-{nd} leaves {tuple(beta.shape)}; "
            "expected (F+1, U, M) for a single scenario or (B, F+1, U, M) "
            "for a fleet")

    # -- entry points ----------------------------------------------------
    def plan(self, env: NetworkEnv, weights: EccWeights | None = None,
             prof: ModelProfile | None = None) -> PlanState:
        """One-shot solve of a static environment. ``prof`` substitutes a
        measured profile (repro.online) for this dispatch: validated against
        the static one, then passed as an operand to the *same* compiled
        program -- closed-loop feedback never recompiles."""
        return self._compiled("plan", env)(
            env, self._prof_arg(prof), self._w(env, weights))

    def plan_many(
        self,
        envs: NetworkEnv | Sequence[NetworkEnv],
        weights: EccWeights | None = None,
        prof: ModelProfile | None = None,
    ) -> PlanState:
        """Batched Monte-Carlo solve: `envs` is either a list of same-shape
        environments or a NetworkEnv whose array leaves carry a leading
        batch dim. Returns a PlanState with the same leading dim. With a
        mesh attached, the batch is split over the fleet axis (shard_map);
        otherwise it is vmapped on one device."""
        if not isinstance(envs, NetworkEnv):
            envs = list(envs)
            if not envs:
                raise ValueError("plan_many needs at least one environment")
            envs = stack_envs(envs)
        if getattr(envs.g_up, "ndim", 0) != 4:
            raise ValueError(
                f"plan_many expects stacked envs with g_up (B, U, N, M); got "
                f"{tuple(envs.g_up.shape)} -- use plan() for a single "
                "scenario")
        if self.mesh is not None:
            self._check_fleet_divisible(envs.g_up.shape[0])
            w = self._w(envs, weights, n_users=envs.g_up.shape[1], sharded=True)
            return self._compiled("plan_many_sharded", envs)(
                envs, self._prof_arg(prof, sharded=True), w)
        w = self._w(envs, weights, n_users=envs.g_up.shape[1])
        return self._compiled("plan_many", envs)(envs, self._prof_arg(prof), w)

    # -- warm-start payload assembly (pure device ops, dispatches async) --
    def _warm_args(self, prev: PlanState, gains: Array):
        """(norms, moms, steps, prev_gains) handed to the compiled replan.
        Everything stays on device: missing moments/steps are zero-filled
        with device ops, and a missing gains record falls back to the new
        epoch's gains (rho estimate 1 -> gate open), matching the legacy
        'no history, trust the warm start' behavior."""
        norms, moms, steps = prev.norms, prev.moms, prev.opt_steps
        if moms is None:
            moms = (jax.tree.map(jnp.zeros_like, norms),
                    jax.tree.map(jnp.zeros_like, norms))
        if steps is None:
            steps = jnp.zeros(norms["beta_up"].shape[:-2], jnp.int32)
        prev_gains = gains if prev.gains is None else prev.gains
        return norms, moms, steps, prev_gains

    def replan(
        self,
        prev: PlanState | None,
        env: NetworkEnv,
        weights: EccWeights | None = None,
        prof: ModelProfile | None = None,
    ) -> PlanState:
        """Online re-plan for the next epoch of a time-correlated scenario:
        every split point starts from the better of `prev.norms[s]` (resuming
        its Adam moments/step counts, so early stopping fires as soon as the
        tracked optimum is re-attained) and the fresh Li-GD chain carry.
        Falls back to a cold plan() when there is no previous state. The
        rho-adaptive gate runs inside the compiled program: when the
        estimated epoch-to-epoch correlation is below `warm_rho_min` the
        temporal starts are disabled on device (use_warm=False -> exact cold
        Li-GD chain, same program). The call dispatches asynchronously --
        shape validation below reads array metadata only. ``prof``
        substitutes a measured profile (repro.online feedback) as an operand
        of the same compiled program."""
        if prev is None:
            return self.plan(env, weights, prof=prof)
        fleet, warm_um = self._warm_dims(prev)
        if fleet is not None:
            raise WarmStateShapeError(
                f"fleet-batched PlanState (B={fleet}) passed to replan(); "
                "use replan_many() for fleets, or planning.member(state, i) "
                "to re-plan one member")
        if warm_um != (env.n_users, env.n_sub) or (
                prev.gains is not None
                and tuple(prev.gains.shape) != tuple(env.g_up.shape)):
            raise WarmStateShapeError(
                f"warm-start state is for a (U, M)={warm_um} network but the "
                f"new env has {tuple(env.g_up.shape)}; scenario shapes (users, "
                "APs, subchannels) must stay static across epochs (use plan() "
                "after a shape change)")
        norms, moms, steps, prev_gains = self._warm_args(prev, env.g_up)
        return self._compiled("replan", env)(
            env, self._prof_arg(prof), self._w(env, weights), norms, moms,
            steps, prev_gains
        )

    def replan_many(
        self,
        prev: PlanState | None,
        envs: NetworkEnv | Sequence[NetworkEnv],
        weights: EccWeights | None = None,
        prof: ModelProfile | None = None,
    ) -> PlanState:
        """Fleet replan: scenarios evolving in parallel, all warm-started in
        one compiled program -- vmapped on one device, or shard_map over the
        mesh's fleet axis when one is attached (the carried payload is
        donated on that path; do not reuse `prev` afterwards). `prev` is the
        batched PlanState from the previous epoch's plan_many/replan_many
        (leaves lead with the fleet dim); `envs` is a stacked NetworkEnv or
        a list of same-shape environments. The rho-adaptive gate applies per
        fleet member inside the program: stale members run the exact cold
        Li-GD chain, fresh members resume their Adam trajectory."""
        if not isinstance(envs, NetworkEnv):
            envs = list(envs)
            if not envs:
                raise ValueError("replan_many needs at least one environment")
            envs = stack_envs(envs)
        if getattr(envs.g_up, "ndim", 0) != 4:
            raise WarmStateShapeError(
                f"replan_many expects stacked envs with g_up (B, U, N, M); "
                f"got {tuple(envs.g_up.shape)} -- use replan() for a single "
                "scenario")
        if prev is None:
            return self.plan_many(envs, weights, prof=prof)
        b, u, m = envs.g_up.shape[0], envs.g_up.shape[1], envs.g_up.shape[3]
        fleet, warm_um = self._warm_dims(prev)
        if fleet is None:
            raise WarmStateShapeError(
                f"single-scenario PlanState (norms leaves "
                f"{tuple(prev.norms['beta_up'].shape)}) passed to "
                "replan_many(); fleet states carry a leading fleet dim -- "
                "start from plan_many(), or use replan() for one scenario")
        if (fleet, *warm_um) != (b, u, m) or (
                prev.gains is not None
                and tuple(prev.gains.shape) != tuple(envs.g_up.shape)):
            raise WarmStateShapeError(
                f"warm-start state is for a fleet of {fleet} (U, M)={warm_um} "
                f"networks but the stacked envs have g_up "
                f"{tuple(envs.g_up.shape)}; fleet and scenario shapes must "
                "stay static across epochs (use plan_many() after a shape "
                "change)")
        norms, moms, steps, prev_gains = self._warm_args(prev, envs.g_up)
        if self.mesh is not None:
            self._check_fleet_divisible(b)
            w = self._w(envs, weights, n_users=u, sharded=True)
            return self._compiled("replan_many_sharded", envs)(
                envs, self._prof_arg(prof, sharded=True), w, norms, moms,
                steps, prev_gains
            )
        w = self._w(envs, weights, n_users=u)
        return self._compiled("replan_many", envs)(
            envs, self._prof_arg(prof), w, norms, moms, steps, prev_gains
        )
