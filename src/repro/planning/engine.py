"""PlannerEngine: the unified entry point for single-shot, batched, and
online warm-started ECC planning.

The engine owns a cache of compiled solver programs keyed on
(entry kind, env shape, GdConfig, method, rounding), so a serving loop that
re-plans every epoch pays tracing/compilation once per network shape. Three
entry points share the cache:

  plan(env)             -- one-shot solve (the paper's Table I).
  plan_many(envs)       -- vmapped Monte-Carlo over stacked realizations
                           (one compiled program optimizes all draws).
  replan(prev, env)     -- online Li-GD: every split point warm-starts from
                           the previous epoch's normalized optimum at the
                           same split. Under time-correlated fading the
                           previous optimum is near-optimal, so this is the
                           paper's warm-start argument (Corollary 4) applied
                           across *time* instead of across split points.

plan/replan return a PlanState carrying both the discrete SplitPlan and the
stacked normalized optima needed to warm-start the next epoch.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import li_gd
from repro.core.types import (
    Array,
    EccWeights,
    GdConfig,
    ModelProfile,
    NetworkEnv,
    SplitPlan,
    make_weights,
)


class PlanState(NamedTuple):
    """A plan plus the solver state needed to warm-start the next epoch."""

    plan: SplitPlan
    norms: dict          # per-split normalized optima, leaves lead with (F+1, ...)
    total_iters: Array   # () total GD iterations spent producing this plan


def stack_envs(envs: Sequence[NetworkEnv]) -> NetworkEnv:
    """Stack same-shape environments along a leading Monte-Carlo dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *envs)


def _solve_state(env, prof, w, cfg, method, rounding) -> PlanState:
    loop = li_gd.gd_loop(env, prof, w, cfg, chain=(method == "li_gd"))
    plan = li_gd.assemble_plan(env, loop, prof, rounding=rounding, w=w)
    return PlanState(plan=plan, norms=loop.norms, total_iters=loop.total_iters)


def _resolve_state(env, prof, w, warm, cfg, method, rounding) -> PlanState:
    del method  # warm mode supersedes the chain-vs-cold distinction
    loop = li_gd.gd_loop(env, prof, w, cfg, warm=warm)
    plan = li_gd.assemble_plan(env, loop, prof, rounding=rounding, w=w)
    return PlanState(plan=plan, norms=loop.norms, total_iters=loop.total_iters)


class PlannerEngine:
    """Compiled-solver cache + unified planning API for one model profile.

    method: 'li_gd' (paper warm-start chain) or 'gd' (cold-start baseline).
    rounding: 'best' | 'greedy' | 'paper' (see li_gd.assemble_plan).
    """

    def __init__(
        self,
        prof: ModelProfile,
        weights: EccWeights | None = None,
        cfg: GdConfig = GdConfig(),
        method: str = "li_gd",
        rounding: str = "best",
    ):
        if method not in ("li_gd", "gd"):
            raise KeyError(method)
        self.prof = prof
        self.weights = weights
        self.cfg = cfg
        self.method = method
        self.rounding = rounding
        self._cache: dict[tuple, object] = {}

    # -- compiled-program cache ------------------------------------------
    def _env_shape(self, env: NetworkEnv) -> tuple:
        return tuple(env.g_up.shape)

    def _compiled(self, kind: str, env: NetworkEnv):
        key = (kind, self._env_shape(env), self.cfg, self.method, self.rounding)
        fn = self._cache.get(key)
        if fn is None:
            if kind == "plan":
                base = functools.partial(_solve_state, cfg=self.cfg,
                                         method=self.method, rounding=self.rounding)
                fn = jax.jit(base)
            elif kind == "plan_many":
                base = functools.partial(_solve_state, cfg=self.cfg,
                                         method=self.method, rounding=self.rounding)
                fn = jax.jit(jax.vmap(base, in_axes=(0, None, None)))
            elif kind == "replan":
                base = functools.partial(_resolve_state, cfg=self.cfg,
                                         method=self.method, rounding=self.rounding)
                fn = jax.jit(base)
            else:
                raise KeyError(kind)
            self._cache[key] = fn
        return fn

    def cache_size(self) -> int:
        return len(self._cache)

    def _w(self, env: NetworkEnv, weights, n_users: int | None = None) -> EccWeights:
        if weights is not None:
            return weights
        if self.weights is not None:
            return self.weights
        return make_weights(env.n_users if n_users is None else n_users)

    # -- entry points ----------------------------------------------------
    def plan(self, env: NetworkEnv, weights: EccWeights | None = None) -> PlanState:
        """One-shot solve of a static environment."""
        return self._compiled("plan", env)(env, self.prof, self._w(env, weights))

    def plan_many(
        self,
        envs: NetworkEnv | Sequence[NetworkEnv],
        weights: EccWeights | None = None,
    ) -> PlanState:
        """Batched Monte-Carlo solve: `envs` is either a list of same-shape
        environments or a NetworkEnv whose array leaves carry a leading
        batch dim. Returns a PlanState with the same leading dim."""
        if not isinstance(envs, NetworkEnv):
            envs = list(envs)
            if not envs:
                raise ValueError("plan_many needs at least one environment")
            envs = stack_envs(envs)
        w = self._w(envs, weights, n_users=envs.g_up.shape[1])
        return self._compiled("plan_many", envs)(envs, self.prof, w)

    def replan(
        self,
        prev: PlanState | None,
        env: NetworkEnv,
        weights: EccWeights | None = None,
    ) -> PlanState:
        """Online re-plan for the next epoch of a time-correlated scenario,
        warm-starting each split point from `prev.norms`. Falls back to a
        cold plan() when there is no previous state."""
        if prev is None:
            return self.plan(env, weights)
        warm_shape = tuple(prev.norms["beta_up"].shape[1:])
        if warm_shape != (env.n_users, env.n_sub):
            raise ValueError(
                f"warm-start state is for a (U, M)={warm_shape} network but the "
                f"new env has ({env.n_users}, {env.n_sub}); scenario shapes must "
                "stay static across epochs (use plan() after a shape change)")
        return self._compiled("replan", env)(
            env, self.prof, self._w(env, weights), prev.norms
        )
