"""PlannerEngine: the unified entry point for single-shot, batched, and
online warm-started ECC planning.

The engine owns a cache of compiled solver programs keyed on
(entry kind, env shape, GdConfig, method, rounding), so a serving loop that
re-plans every epoch pays tracing/compilation once per network shape. Three
entry points share the cache:

  plan(env)             -- one-shot solve (the paper's Table I).
  plan_many(envs)       -- vmapped Monte-Carlo over stacked realizations
                           (one compiled program optimizes all draws).
  replan(prev, env)     -- online Li-GD: every split point warm-starts from
                           the previous epoch's normalized optimum at the
                           same split *and resumes its Adam moments*, so the
                           optimizer continues its trajectory instead of
                           re-biasing from zero. Under time-correlated fading
                           the previous optimum is near-optimal, so this is
                           the paper's warm-start argument (Corollary 4)
                           applied across *time* instead of across split
                           points.
  replan_many(prev, envs) -- the vmapped replan: a fleet of scenarios
                           evolving in parallel, one compiled program.

All entry points return a PlanState carrying the discrete SplitPlan plus the
solver state needed to warm-start the next epoch: the stacked normalized
optima, the per-split Adam moments and step counts, and the epoch's uplink
gains. The gains feed a rho-adaptive selector: replan estimates the
epoch-to-epoch channel correlation between the stored and observed gains and
disables the temporal warm starts (use_warm=False -> the compiled warm
program runs an exact cold Li-GD chain) for any scenario whose estimate
drops below `warm_rho_min` -- at low correlation the previous optimum is
stale and warm-starting from it costs iterations instead of saving them.
Independently of the selector, each split point only adopts the temporal
start when one utility probe says it beats the fresh chain carry, so replan
is never structurally worse than a cold sweep.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import li_gd
from repro.core.types import (
    Array,
    EccWeights,
    GdConfig,
    ModelProfile,
    NetworkEnv,
    SplitPlan,
    make_weights,
)


class WarmStateShapeError(ValueError):
    """A warm-start PlanState does not fit the observed network shape
    (user/AP/subchannel count changed); re-plan cold instead."""


class PlanState(NamedTuple):
    """A plan plus the solver state needed to warm-start the next epoch."""

    plan: SplitPlan
    norms: dict          # per-split normalized optima, leaves lead with (F+1, ...)
    total_iters: Array   # () total GD iterations spent producing this plan
    moms: tuple | None = None      # per-split Adam moments (m1, m2), leaves (F+1, ...)
    opt_steps: Array | None = None # (F+1,) int32 optimizer steps behind `moms`
    gains: Array | None = None     # g_up of the planned epoch (rho estimation)


def stack_envs(envs: Sequence[NetworkEnv]) -> NetworkEnv:
    """Stack same-shape environments along a leading Monte-Carlo dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *envs)


def member(tree, i: int):
    """Slice fleet member i out of a batched pytree (stacked NetworkEnv or
    batched PlanState). Scalar leaves -- e.g. radio/comp constants that
    Scenario.env_many broadcast or that stayed unbatched -- pass through."""
    return jax.tree.map(lambda x: x[i] if getattr(x, "ndim", 0) > 0 else x,
                        tree)


def _solve_state(env, prof, w, cfg, method, rounding) -> PlanState:
    loop = li_gd.gd_loop(env, prof, w, cfg, chain=(method == "li_gd"))
    plan = li_gd.assemble_plan(env, loop, prof, rounding=rounding, w=w)
    return PlanState(plan=plan, norms=loop.norms, total_iters=loop.total_iters,
                     moms=loop.moms, opt_steps=loop.opt_steps, gains=env.g_up)


def _resolve_state(env, prof, w, warm, warm_mom, warm_steps, use_warm,
                   cfg, method, rounding) -> PlanState:
    del method  # warm mode supersedes the chain-vs-cold distinction
    loop = li_gd.gd_loop(env, prof, w, cfg, warm=warm, warm_mom=warm_mom,
                         warm_steps=warm_steps, use_warm=use_warm)
    plan = li_gd.assemble_plan(env, loop, prof, rounding=rounding, w=w)
    return PlanState(plan=plan, norms=loop.norms, total_iters=loop.total_iters,
                     moms=loop.moms, opt_steps=loop.opt_steps, gains=env.g_up)


def _rho_estimate(prev_gains: Array, gains: Array) -> np.ndarray:
    """Estimate the epoch-to-epoch fading correlation rho from two gain
    tensors (per fleet member when batched). For the Gauss-Markov process
    corr(|h_t|^2, |h_{t+1}|^2) = rho^2, so rho_hat = sqrt(clip(corr, 0, 1))."""
    a = np.asarray(prev_gains, dtype=np.float64)
    b = np.asarray(gains, dtype=np.float64)
    batched = a.ndim > 3
    a = a.reshape(a.shape[0] if batched else 1, -1)
    b = b.reshape(b.shape[0] if batched else 1, -1)
    a = a - a.mean(axis=1, keepdims=True)
    b = b - b.mean(axis=1, keepdims=True)
    denom = np.sqrt((a * a).sum(axis=1) * (b * b).sum(axis=1))
    corr = (a * b).sum(axis=1) / np.maximum(denom, 1e-30)
    rho = np.sqrt(np.clip(corr, 0.0, 1.0))
    return rho if batched else rho[0]


class PlannerEngine:
    """Compiled-solver cache + unified planning API for one model profile.

    method: 'li_gd' (paper warm-start chain) or 'gd' (cold-start baseline).
    rounding: 'best' | 'greedy' | 'paper' (see li_gd.assemble_plan).
    warm_rho_min: replan's rho-adaptive selector -- a scenario whose
        estimated epoch-to-epoch correlation falls below this threshold has
        its temporal warm starts disabled (the compiled warm program then
        runs the exact cold Li-GD chain), because a stale optimum is a worse
        start than no prior at all. 0.0 disables the fallback.
    warm_moment_decay: factor applied to the carried Adam moments on resume.
        The sweet spot is a *softened* restart: carrying the moments verbatim
        steers the new epoch with a stale direction and over-remembered
        scale (slightly worse optima), while zeroing them re-biases Adam
        from t=0 and its sign-like opening steps walk away from the
        near-optimal start (many extra iterations). Decaying both moments --
        with the step count carried so bias correction does not re-amplify
        them -- keeps per-coordinate scale memory but lets fresh gradients
        dominate within a few steps. 1.0 resumes verbatim, 0.0 zeroes.
    """

    def __init__(
        self,
        prof: ModelProfile,
        weights: EccWeights | None = None,
        cfg: GdConfig = GdConfig(),
        method: str = "li_gd",
        rounding: str = "best",
        warm_rho_min: float = 0.5,
        warm_moment_decay: float = 0.1,
    ):
        if method not in ("li_gd", "gd"):
            raise KeyError(method)
        if not 0.0 <= warm_rho_min <= 1.0:
            raise ValueError(f"warm_rho_min must be in [0, 1], got {warm_rho_min}")
        if not 0.0 <= warm_moment_decay <= 1.0:
            raise ValueError(
                f"warm_moment_decay must be in [0, 1], got {warm_moment_decay}")
        self.prof = prof
        self.weights = weights
        self.cfg = cfg
        self.method = method
        self.rounding = rounding
        self.warm_rho_min = warm_rho_min
        self.warm_moment_decay = warm_moment_decay
        self._cache: dict[tuple, object] = {}

    # -- compiled-program cache ------------------------------------------
    def _env_shape(self, env: NetworkEnv) -> tuple:
        return tuple(env.g_up.shape)

    def _compiled(self, kind: str, env: NetworkEnv):
        key = (kind, self._env_shape(env), self.cfg, self.method, self.rounding)
        fn = self._cache.get(key)
        if fn is None:
            if kind == "plan":
                base = functools.partial(_solve_state, cfg=self.cfg,
                                         method=self.method, rounding=self.rounding)
                fn = jax.jit(base)
            elif kind == "plan_many":
                base = functools.partial(_solve_state, cfg=self.cfg,
                                         method=self.method, rounding=self.rounding)
                fn = jax.jit(jax.vmap(base, in_axes=(0, None, None)))
            elif kind == "replan":
                base = functools.partial(_resolve_state, cfg=self.cfg,
                                         method=self.method, rounding=self.rounding)
                fn = jax.jit(base)
            elif kind == "replan_many":
                base = functools.partial(_resolve_state, cfg=self.cfg,
                                         method=self.method, rounding=self.rounding)
                fn = jax.jit(jax.vmap(base, in_axes=(0, None, None, 0, 0, 0, 0)))
            else:
                raise KeyError(kind)
            self._cache[key] = fn
        return fn

    def cache_size(self) -> int:
        return len(self._cache)

    def _w(self, env: NetworkEnv, weights, n_users: int | None = None) -> EccWeights:
        if weights is not None:
            return weights
        if self.weights is not None:
            return self.weights
        return make_weights(env.n_users if n_users is None else n_users)

    # -- entry points ----------------------------------------------------
    def plan(self, env: NetworkEnv, weights: EccWeights | None = None) -> PlanState:
        """One-shot solve of a static environment."""
        return self._compiled("plan", env)(env, self.prof, self._w(env, weights))

    def plan_many(
        self,
        envs: NetworkEnv | Sequence[NetworkEnv],
        weights: EccWeights | None = None,
    ) -> PlanState:
        """Batched Monte-Carlo solve: `envs` is either a list of same-shape
        environments or a NetworkEnv whose array leaves carry a leading
        batch dim. Returns a PlanState with the same leading dim."""
        if not isinstance(envs, NetworkEnv):
            envs = list(envs)
            if not envs:
                raise ValueError("plan_many needs at least one environment")
            envs = stack_envs(envs)
        w = self._w(envs, weights, n_users=envs.g_up.shape[1])
        return self._compiled("plan_many", envs)(envs, self.prof, w)

    # -- warm-start payload assembly -------------------------------------
    def _warm_payload(self, prev: PlanState, gains: Array):
        """(norms, moms, steps, use_warm) from a previous PlanState. `gains`
        is the new epoch's g_up -- (U, N, M) for a single scenario,
        (B, U, N, M) for a fleet -- compared against prev.gains to estimate
        the epoch-to-epoch correlation; use_warm (scalar / per-member (B,))
        disables the temporal warm starts for scenarios whose estimate fell
        below warm_rho_min (the compiled warm program then degrades to an
        exact cold Li-GD chain for them)."""
        norms, moms, steps = prev.norms, prev.moms, prev.opt_steps
        if moms is None:
            moms = (jax.tree.map(jnp.zeros_like, norms),
                    jax.tree.map(jnp.zeros_like, norms))
        elif self.warm_moment_decay != 1.0:
            moms = jax.tree.map(lambda x: self.warm_moment_decay * x, moms)
        if steps is None:
            steps = jnp.zeros(norms["beta_up"].shape[:-2], jnp.int32)
        batched = gains.ndim > 3
        if self.warm_rho_min <= 0.0 or prev.gains is None:
            use_warm = np.ones((gains.shape[0],), bool) if batched else True
        else:
            rho = _rho_estimate(prev.gains, gains)
            use_warm = rho >= self.warm_rho_min
        return norms, moms, steps, jnp.asarray(use_warm)

    def replan(
        self,
        prev: PlanState | None,
        env: NetworkEnv,
        weights: EccWeights | None = None,
    ) -> PlanState:
        """Online re-plan for the next epoch of a time-correlated scenario:
        every split point starts from the better of `prev.norms[s]` (resuming
        its Adam moments/step counts, so early stopping fires as soon as the
        tracked optimum is re-attained) and the fresh Li-GD chain carry.
        Falls back to a cold plan() when there is no previous state, and
        disables the temporal starts entirely (use_warm=False -> exact cold
        Li-GD chain, same compiled program) when the estimated epoch-to-epoch
        correlation is below `warm_rho_min`."""
        if prev is None:
            return self.plan(env, weights)
        warm_shape = tuple(prev.norms["beta_up"].shape[1:])
        if warm_shape != (env.n_users, env.n_sub) or (
                prev.gains is not None
                and tuple(prev.gains.shape) != tuple(env.g_up.shape)):
            raise WarmStateShapeError(
                f"warm-start state is for a (U, M)={warm_shape} network but the "
                f"new env has {tuple(env.g_up.shape)}; scenario shapes (users, "
                "APs, subchannels) must stay static across epochs (use plan() "
                "after a shape change)")
        norms, moms, steps, use_warm = self._warm_payload(prev, env.g_up)
        return self._compiled("replan", env)(
            env, self.prof, self._w(env, weights), norms, moms, steps, use_warm
        )

    def replan_many(
        self,
        prev: PlanState | None,
        envs: NetworkEnv | Sequence[NetworkEnv],
        weights: EccWeights | None = None,
    ) -> PlanState:
        """Batched replan: a fleet of scenarios evolving in parallel, all
        warm-started in one compiled vmapped program. `prev` is the batched
        PlanState from the previous epoch's plan_many/replan_many (leaves lead
        with the fleet dim); `envs` is a stacked NetworkEnv or a list of
        same-shape environments. The rho-adaptive fallback applies per fleet
        member: stale members run the exact cold Li-GD chain, fresh members
        resume their Adam trajectory."""
        if not isinstance(envs, NetworkEnv):
            envs = list(envs)
            if not envs:
                raise ValueError("replan_many needs at least one environment")
            envs = stack_envs(envs)
        if prev is None:
            return self.plan_many(envs, weights)
        b, u, m = envs.g_up.shape[0], envs.g_up.shape[1], envs.g_up.shape[3]
        warm_shape = tuple(prev.norms["beta_up"].shape)
        if warm_shape[:1] + warm_shape[2:] != (b, u, m) or (
                prev.gains is not None
                and tuple(prev.gains.shape) != tuple(envs.g_up.shape)):
            raise WarmStateShapeError(
                f"warm-start state with leaves {warm_shape} does not match the "
                f"stacked envs {tuple(envs.g_up.shape)}; fleet and scenario "
                "shapes must stay static across epochs (use plan_many() after "
                "a shape change)")
        w = self._w(envs, weights, n_users=u)
        norms, moms, steps, use_warm = self._warm_payload(prev, envs.g_up)
        return self._compiled("replan_many", envs)(
            envs, self.prof, w, norms, moms, steps, use_warm
        )
