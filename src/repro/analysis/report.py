"""Findings and reports for the program auditor.

A Finding is one rule violation anchored to one audited program; an
AuditReport aggregates the findings of one or many audit() calls together
with the programs and rules that were checked (so a green report says
*what* was proven, not just that nothing failed). Reports serialize to
plain dicts for the CLI's machine-readable JSON artifact and for the
BENCH rows' ``audit`` meta field.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable


class AuditError(AssertionError):
    """Raised by AuditReport.raise_if_failed(); the message lists every
    finding with its actionable remediation text."""


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation in one audited program.

    rule      the catalog rule's name (e.g. "no_gather_above").
    program   label of the audited program (e.g. "dense_urban/pallas:replan").
    message   what was found and what to do about it.
    detail    optional machine-readable payload (shapes, grids, byte counts).
    """

    rule: str
    program: str
    message: str
    detail: dict = dataclasses.field(default_factory=dict)

    def __str__(self) -> str:
        return f"[{self.rule}] {self.program}: {self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "program": self.program,
                "message": self.message, "detail": dict(self.detail)}


@dataclasses.dataclass
class AuditReport:
    """The outcome of auditing one or more programs against a rule set."""

    programs: list[str] = dataclasses.field(default_factory=list)
    rules: list[str] = dataclasses.field(default_factory=list)
    findings: list[Finding] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def merge(self, other: "AuditReport") -> "AuditReport":
        """Fold another report in place (CLI aggregation); returns self."""
        for p in other.programs:
            if p not in self.programs:
                self.programs.append(p)
        for r in other.rules:
            if r not in self.rules:
                self.rules.append(r)
        self.findings.extend(other.findings)
        return self

    def raise_if_failed(self) -> None:
        if self.findings:
            lines = "\n".join(str(f) for f in self.findings)
            raise AuditError(
                f"{len(self.findings)} audit finding(s):\n{lines}")

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "programs": list(self.programs),
            "rules": list(self.rules),
            "findings": [f.to_dict() for f in self.findings],
        }


def merge_reports(reports: Iterable[AuditReport]) -> AuditReport:
    out = AuditReport()
    for r in reports:
        out.merge(r)
    return out
