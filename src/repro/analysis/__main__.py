"""CLI: audit the engine's compiled programs across presets and backends.

    python -m repro.analysis [--presets dense_urban hotspot]
                             [--backends einsum pallas_interpret]
                             [--out report.json] [--no-runtime]

Per (preset, backend) the plan/replan/replan_many programs are traced and
audited against the rule catalog (trace-only, nothing executes -- cheap at
any scale). Unless --no-runtime, a small-env engine additionally runs the
live probes: exact compile counts across cold->warm->warm (the weak-type
recompile gate), zero-host-transfer dispatch under jax.transfer_guard, and
the cache-key discipline sweep. Exit status 1 on any finding; the JSON
report is machine-readable (CI artifact).
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.analysis.engine_audit import (
    CacheKeyDiscipline,
    audit_engine,
    runtime_probe,
)
from repro.analysis.fault_audit import audit_faults
from repro.analysis.online_audit import (
    audit_online_replan,
    online_feedback_probe,
    online_loop_probe,
)
from repro.analysis.recovery_audit import audit_recovery
from repro.analysis.report import AuditReport
from repro.core import make_env, make_weights, profiles
from repro.core.types import GdConfig
from repro.planning import PlannerEngine
from repro.scenarios import presets

DEFAULT_PRESETS = ("dense_urban", "hotspot")
DEFAULT_BACKENDS = ("einsum", "pallas_interpret")


def preset_env(name: str, seed: int = 0):
    cfg = presets.get(name)
    return make_env(jax.random.PRNGKey(seed), n_users=cfg.n_users,
                    n_aps=cfg.n_aps, n_sub=cfg.n_sub)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__.split("\n\n")[0])
    ap.add_argument("--presets", nargs="+", default=list(DEFAULT_PRESETS),
                    choices=presets.names(), metavar="PRESET",
                    help=f"scenario presets to audit (default: "
                         f"{' '.join(DEFAULT_PRESETS)}; "
                         f"available: {' '.join(presets.names())})")
    ap.add_argument("--backends", nargs="+", default=list(DEFAULT_BACKENDS),
                    metavar="BACKEND",
                    help="SINR backends to audit (default: "
                         f"{' '.join(DEFAULT_BACKENDS)})")
    ap.add_argument("--fleet", type=int, default=2,
                    help="fleet size for the replan_many audit (default 2)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the JSON report here (default: stdout only "
                         "prints the summary)")
    ap.add_argument("--no-runtime", action="store_true",
                    help="skip the executing probes (compile counts, "
                         "transfer guard, cache discipline)")
    args = ap.parse_args(argv)

    prof = profiles.nin()
    report = AuditReport()

    for preset in args.presets:
        env = preset_env(preset)
        weights = make_weights(env.n_users)
        for backend in args.backends:
            engine = PlannerEngine(prof, weights=weights,
                                   sinr_backend=backend)
            label = f"{preset}/{backend}"
            report.merge(audit_engine(engine, env, fleet=args.fleet,
                                      label=label))
            # the closed-loop feedback path: replan with a measured-profile
            # operand must satisfy the same rules with the same signature
            report.merge(audit_online_replan(engine, env, label=label))
            print(f"audited {label}: plan/replan/replan_many/"
                  f"replan_measured ({len(report.findings)} finding(s) "
                  "so far)")

    if not args.no_runtime:
        # Live probes run on a small env (they execute the solver); the
        # invariants they check are shape-independent engine properties.
        env_a = make_env(jax.random.PRNGKey(1), n_users=8, n_aps=2, n_sub=4)
        env_b = make_env(jax.random.PRNGKey(2), n_users=8, n_aps=2, n_sub=4)
        env_c = make_env(jax.random.PRNGKey(3), n_users=6, n_aps=2, n_sub=4)
        cfg = GdConfig(max_iters=40)
        probe_eng = PlannerEngine(prof, weights=make_weights(8), cfg=cfg)
        report.merge(runtime_probe(probe_eng, env_a, env_b, label="runtime"))
        cache_eng = PlannerEngine(prof, weights=make_weights(8), cfg=cfg)
        report.merge(CacheKeyDiscipline().probe(cache_eng, env_a, env_c,
                                                label="runtime"))
        online_eng = PlannerEngine(prof, weights=make_weights(8), cfg=cfg)
        report.merge(online_feedback_probe(online_eng, env_a,
                                           label="runtime"))
        report.merge(online_loop_probe(label="runtime"))
        # chaos hardening: fault injection must ride the same compiled
        # epoch program (rates are operands) and the guard chain must keep
        # every served plan finite without host-side checks
        report.merge(audit_faults(label="runtime"))
        # durable serving: crash + restore must be bit-exact, mint zero
        # steady-state compiles, and replay cleanly from the journal
        report.merge(audit_recovery(label="runtime"))
        print("ran runtime probes (compile log, transfer guard, cache "
              "keys, online feedback, online loop, chaos loop, recovery)")

    payload = report.to_dict()
    payload["presets"] = list(args.presets)
    payload["backends"] = list(args.backends)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")

    print(f"programs audited: {len(report.programs)}; "
          f"rules: {', '.join(report.rules)}")
    if report.ok:
        print("AUDIT OK: no findings")
        return 0
    print(f"AUDIT FAILED: {len(report.findings)} finding(s)")
    for f in report.findings:
        print(f"  {f}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
