"""Closed-loop feedback audits: measured-profile replans and the online
serving loop's steady state.

Three probes, mirroring engine_audit's layering:

* audit_online_replan -- trace-only. The engine's replan program called
  with a *measured* profile operand (ModelProfile.like of the static one)
  must satisfy the base rules (no host transfers inside the jaxpr, stable
  signature), and its output avals must be byte-identical whether the next
  dispatch uses the measured or the static profile: the profile is an
  operand, never part of the signature.

* online_feedback_probe -- executing. plan -> replan(static) ->
  replan(measured) -> replan(measured') must compile exactly one plan and
  one replan program with zero cache growth across the profile swaps, and
  the steady-state feedback path -- telemetry update, measured-profile
  rebuild, replan dispatch -- must move nothing to host under
  jax.transfer_guard('disallow').

* online_loop_probe -- executing. A small OnlineLoop (scenario + streams +
  batching + QoS + telemetry + scheduled replans) warmed up and then run
  for several epochs under planning.compile_log() must trace nothing: the
  whole closed loop is one reused epoch program plus reused planner
  programs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.audit import audit
from repro.analysis.report import AuditReport, Finding, merge_reports
from repro.analysis.rules import StableSignature, base_rules
from repro.core.types import GdConfig, NetworkEnv
from repro.planning.engine import PlannerEngine, compile_log


def _measured_like(engine: PlannerEngine, scale: float):
    """A synthetic measured profile: same structure, perturbed tables."""
    p = engine.prof
    return p.like(p.fl * scale, p.w * scale, p.m_down)


def audit_online_replan(engine: PlannerEngine, env: NetworkEnv,
                        label: str = "online") -> AuditReport:
    """Trace-only audit of the measured-profile replan path."""
    measured = _measured_like(engine, 1.5)
    rules = base_rules()
    plan_fn = engine.program("plan", env)
    cold = jax.eval_shape(plan_fn,
                          *engine.program_args("plan", env, prof=measured))
    replan_fn = engine.program("replan", env)
    args = engine.program_args("replan", env, prev=cold, prof=measured)
    rep = audit(replan_fn, *args, rules=rules,
                label=f"{label}:replan_measured")
    # Swapping back to the static profile must leave the signature alone:
    # measured feedback is an operand substitution, not a new program.
    warm_measured = jax.eval_shape(replan_fn, *args)
    warm_static = jax.eval_shape(
        replan_fn, *engine.program_args("replan", env, prev=warm_measured))
    rep.findings.extend(StableSignature.compare(
        f"{label}:replan_measured", warm_measured, warm_static))
    return rep


def online_feedback_probe(engine: PlannerEngine, env: NetworkEnv,
                          label: str = "online") -> AuditReport:
    """Execute the measured-profile feedback chain and check the dynamic
    invariants: one plan + one replan compile across static and measured
    dispatches, zero compiled-program cache growth from profile swaps, and
    a steady-state telemetry-update -> profile -> replan chain that moves
    no host data under jax.transfer_guard('disallow'). Probe a FRESH
    engine constructed with explicit weights."""
    from repro.online.telemetry import Observation, Telemetry

    report = AuditReport(programs=[f"{label}:feedback"],
                         rules=["stable_signature", "no_host_transfer",
                                "cache_key_discipline"])
    with compile_log() as log:
        state = engine.plan(env)
        state = engine.replan(state, env)            # static profile
        cache_n = engine.cache_size()
        for scale in (2.0, 3.0):
            state = engine.replan(state, env,
                                  prof=_measured_like(engine, scale))
    jax.block_until_ready(state.plan.utility)
    if log != ["plan", "replan"]:
        report.findings.append(Finding(
            rule="stable_signature", program=f"{label}:feedback",
            message=(
                f"static->measured->measured replan chain traced {log}, "
                "expected ['plan', 'replan']: a measured profile must hit "
                "the already-compiled replan program as a plain operand"),
            detail={"compile_log": list(log)}))
    if engine.cache_size() != cache_n:
        report.findings.append(Finding(
            rule="cache_key_discipline", program=f"{label}:feedback",
            message=(
                f"profile swaps grew the compiled-program cache from "
                f"{cache_n} to {engine.cache_size()} entries; the profile "
                "must not be part of the cache key"),
            detail={"before": cache_n, "after": engine.cache_size()}))

    # Steady-state feedback under the transfer guard. The telemetry update
    # and profile rebuild are warmed first (compilation may stage host
    # constants); the guarded region is the per-epoch feedback path.
    tel = Telemetry(engine.prof, env.comp, decay=0.5)
    ts = tel.init()
    f = engine.prof.n_layers
    obs = Observation(
        t_layer=jnp.full((f,), 1e-4, jnp.float32),
        t_up=jnp.float32(1e-3), rate_up=jnp.float32(1e6),
        rate_dn=jnp.float32(1e6), r_units=jnp.float32(2.0))
    s_dev = jnp.int32(max(f // 2, 1))
    ts = tel.update(ts, s_dev, obs)                  # warm the update
    state = engine.replan(state, env, prof=tel.profile(ts))
    env_dev = jax.device_put(env)
    try:
        with jax.transfer_guard("disallow"):
            ts = tel.update(ts, s_dev, obs)
            state = engine.replan(state, env_dev, prof=tel.profile(ts))
        jax.block_until_ready(state.plan.utility)
    except Exception as e:  # noqa: BLE001 -- the guard raises RuntimeError
        report.findings.append(Finding(
            rule="no_host_transfer", program=f"{label}:feedback",
            message=(
                "steady-state profile feedback (telemetry update -> "
                "measured profile -> replan) transferred data to/from host "
                f"under jax.transfer_guard('disallow'): {e}"),
            detail={"error": str(e)}))
    return report


def online_loop_probe(label: str = "online") -> AuditReport:
    """Run a small closed loop end to end: after warmup, further epochs of
    scenario + streams + batching + QoS + telemetry + scheduled replans
    must trace nothing (the epoch program logs as kind 'online_epoch')."""
    from repro.core import profiles
    from repro.online import OnlineLoop, ServiceConfig, StreamConfig
    from repro.scenarios import Scenario, ScenarioConfig

    report = AuditReport(programs=[f"{label}:loop"],
                         rules=["stable_signature"])
    eng = PlannerEngine(profiles.nin(),
                        cfg=GdConfig(step_size=3e-2, max_iters=30,
                                     optimizer="adam"))
    scen = Scenario(ScenarioConfig(n_users=6, n_aps=2, n_sub=3,
                                   fading_rho=0.95))
    loop = OnlineLoop(
        scen, eng,
        StreamConfig(arrival_rate_hz=20.0, epoch_dt_s=0.02),
        ServiceConfig(edge_capacity=4, queue_depth=8, load_gain=4.0,
                      replan_every=3))
    loop.reset(jax.random.PRNGKey(0))
    for _ in range(8):                               # warmup traces
        loop.step_epoch()
    with compile_log() as log:
        for _ in range(6):
            loop.step_epoch()
    if log:
        report.findings.append(Finding(
            rule="stable_signature", program=f"{label}:loop",
            message=(
                f"steady-state online loop traced {log}; expected no "
                "compiles: the epoch program (kind 'online_epoch') and the "
                "planner programs must be reused every epoch"),
            detail={"compile_log": list(log)}))
    return report


def audit_online(engine: PlannerEngine, env: NetworkEnv,
                 label: str = "online", runtime: bool = True) -> AuditReport:
    """The full closed-loop audit: trace-only measured-replan rules, plus
    (unless runtime=False) the executing feedback and loop probes."""
    reports = [audit_online_replan(engine, env, label=label)]
    if runtime:
        reports.append(online_feedback_probe(engine, env, label=label))
        reports.append(online_loop_probe(label=label))
    return merge_reports(reports)
