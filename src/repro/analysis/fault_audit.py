"""Chaos-hardening audits: fault injection must cost zero recompiles and
the guards zero host traffic.

Three probes, mirroring online_audit's layering:

* guard_trace_audit -- trace-only. The hardened epoch program (faults
  injected, guards packed, quarantine gate traced in) and the standalone
  plan-word guard must satisfy NoHostTransfer: every check stays on
  device; the host learns about faults only through the packed health
  word it was going to sync anyway.

* chaos_loop_probe -- executing. A hardened OnlineLoop under an ACTIVE
  fault mix (deep fades, AP blackouts, telemetry corruption, service
  spikes) warmed up and then run under planning.compile_log() must trace
  nothing -- the epoch program compiles exactly once even while the
  ladder escalates, quarantines, and recovers. Swapping the fault mix
  mid-episode (set_fault_rates) must also trace nothing and grow no
  engine cache entries: fault rates are operands, never cache keys.

* plans stay finite -- the same probe asserts the served plan's utility
  is finite after the chaotic episode: the guard chain's end-to-end
  contract (no NaN plan is ever on the air).
"""
from __future__ import annotations

import jax

from repro.analysis.audit import audit
from repro.analysis.report import AuditReport, Finding, merge_reports
from repro.analysis.rules import NoHostTransfer
from repro.core.types import GdConfig

# The chaos mix the probes run under: every injector class active, at the
# acceptance criterion's 20% link-outage operating point.
CHAOS = dict(link_outage_rate=0.2, fade_depth=1e-6, ap_outage_rate=0.05,
             telemetry_drop_rate=0.1, telemetry_spike_rate=0.05,
             service_spike_rate=0.02)


def _small_loop(faults, degrade):
    from repro.core import profiles
    from repro.online import OnlineLoop, ServiceConfig, StreamConfig
    from repro.planning import PlannerEngine
    from repro.scenarios import Scenario, ScenarioConfig

    eng = PlannerEngine(profiles.nin(),
                        cfg=GdConfig(step_size=3e-2, max_iters=30,
                                     optimizer="adam"))
    scen = Scenario(ScenarioConfig(n_users=6, n_aps=2, n_sub=3,
                                   fading_rho=0.95))
    return OnlineLoop(
        scen, eng,
        StreamConfig(arrival_rate_hz=20.0, epoch_dt_s=0.02, deadline_s=0.2),
        ServiceConfig(edge_capacity=4, queue_depth=8, load_gain=4.0,
                      replan_every=3, max_work_epochs=200),
        faults=faults, degrade=degrade)


def guard_trace_audit(label: str = "faults") -> AuditReport:
    """Trace-only: the hardened epoch program and the plan-word guard move
    nothing to host inside their jaxprs."""
    import functools

    from repro.faults import FaultConfig, LadderConfig, guards

    loop = _small_loop(FaultConfig(**CHAOS), LadderConfig())
    loop.reset(jax.random.PRNGKey(0))
    rep = audit(loop._epoch, *loop.epoch_args(), rules=[NoHostTransfer()],
                label=f"{label}:epoch_injected")
    env = loop.scenario.env(loop._sc)
    word_fn = functools.partial(
        guards.plan_word, n_sub=env.n_sub, p_up_max=env.radio.p_up_max_w,
        p_dn_max=env.radio.p_dn_max_w, r_max=env.comp.r_max)
    rep2 = audit(word_fn, loop._plan, rules=[NoHostTransfer()],
                 label=f"{label}:plan_word")
    return merge_reports([rep, rep2])


def chaos_loop_probe(label: str = "faults") -> AuditReport:
    """Executing: under active injection the steady-state hardened loop
    traces nothing, a fault-mix swap mints no cache keys, and the served
    plan ends the episode finite."""
    from repro.faults import FaultConfig, LadderConfig
    from repro.planning.engine import compile_log

    report = AuditReport(programs=[f"{label}:chaos_loop"],
                         rules=["stable_signature", "cache_key_discipline"])
    loop = _small_loop(FaultConfig(**CHAOS),
                       LadderConfig(quarantine_epochs=10, baseline_after=2))
    loop.reset(jax.random.PRNGKey(0))
    for _ in range(12):                              # warmup traces
        loop.step_epoch()
    cache_n = loop.engine.cache_size()
    with compile_log() as log:
        for _ in range(8):
            loop.step_epoch()
        # The operand-swap discipline, fault edition: a new mix re-enters
        # the same compiled epoch program.
        loop.set_fault_rates(FaultConfig(link_outage_rate=0.5,
                                         fade_depth=1e-6,
                                         telemetry_drop_rate=0.3))
        for _ in range(8):
            loop.step_epoch()
    if log:
        report.findings.append(Finding(
            rule="stable_signature", program=f"{label}:chaos_loop",
            message=(
                f"steady-state hardened loop under active fault injection "
                f"traced {log}; expected no compiles: fault draws, guards, "
                "quarantine gating and the rate swap must all reuse the "
                "one epoch program"),
            detail={"compile_log": list(log)}))
    if loop.engine.cache_size() != cache_n:
        report.findings.append(Finding(
            rule="cache_key_discipline", program=f"{label}:chaos_loop",
            message=(
                f"fault injection grew the engine's compiled-program cache "
                f"from {cache_n} to {loop.engine.cache_size()} entries; "
                "fault operands must not be cache keys"),
            detail={"before": cache_n, "after": loop.engine.cache_size()}))
    if not bool(jax.numpy.isfinite(loop._plan.utility)):
        report.findings.append(Finding(
            rule="stable_signature", program=f"{label}:chaos_loop",
            message=("the served plan ended a chaotic episode non-finite; "
                     "the guard chain let a corrupt plan on the air"),
            detail={"utility": float(loop._plan.utility)}))
    return report


def audit_faults(label: str = "faults",
                 runtime: bool = True) -> AuditReport:
    """The full chaos audit: trace-only guard rules, plus (unless
    runtime=False) the executing chaos-loop probe."""
    reports = [guard_trace_audit(label=label)]
    if runtime:
        reports.append(chaos_loop_probe(label=label))
    return merge_reports(reports)
