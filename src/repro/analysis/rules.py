"""The rule catalog: each kernel/engine invariant as a small checkable class.

These are the program invariants PRs 3-6 established (and that the tests
previously asserted with ad-hoc per-file jaxpr walkers):

  NoHostTransfer          replan-path programs contain no host callbacks.
  NoPairwiseIntermediate  no (U, V, M) arithmetic intermediate outside the
                          Pallas kernels (the pairwise tensor only streams
                          through them block by block).
  NoGatherAbove           no (>=U, >=U, M) gather -- the gather-free kernels
                          select the serving AP in-kernel from raw state.
  NoPad3D                 no rank-3 pad -- kernel operands enter unpadded,
                          boundary blocks are masked in-kernel.
  VmemCeiling             every pallas_call's per-block working set fits the
                          VMEM budget (derived from the kernel body's refs).
  SparseGrid              the tile-driven intra/SIC kernel launches exactly
                          the expected tile count (sum-of-cell-blocks^2 with
                          a CellLayout, the dense grid without).
  StableSignature         program outputs carry no weak types (the PR 3
                          recompile bug), and cold/warm signatures agree.

Engine-level discipline (CacheKeyDiscipline, compile counting) lives in
analysis/engine_audit.py -- it probes a live PlannerEngine rather than one
jaxpr. Rules are stateless and reusable: construct once, run against any
number of ProgramRecords via ``rule.check(record)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax

from repro.analysis.report import Finding
from repro.analysis.visitor import (
    ClosedJaxpr,
    iter_eqns,
    out_shapes,
    pallas_calls,
)
from repro.kernels.noma_rates import VMEM_CEILING_BYTES

# The arithmetic primitives whose (U, V, M) outputs would mean the pairwise
# tensor was materialized (moved here from tests/test_grad_kernels.py).
PAIRWISE_ARITH = frozenset({
    "mul", "add", "sub", "div", "select_n", "lt", "gt", "le", "ge",
    "and", "or", "max", "min", "log1p", "exp", "integer_pow", "pow",
})

# Primitives that force a host round-trip inside a compiled program.
HOST_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed",
})


@dataclasses.dataclass(frozen=True)
class ProgramRecord:
    """One traced program under audit: a label plus its ClosedJaxpr.
    closed is None only for synthetic label-carrier records (e.g.
    StableSignature.compare, which compares avals, not a program)."""

    label: str
    closed: ClosedJaxpr | None

    @property
    def jaxpr(self):
        assert self.closed is not None, "record has no traced program"
        return self.closed.jaxpr


class Rule:
    """Base class: a named, stateless check over one ProgramRecord."""

    name = "rule"

    def check(self, record: ProgramRecord) -> list[Finding]:
        return list(self.findings(record))

    def findings(self, record: ProgramRecord) -> Iterator[Finding]:
        raise NotImplementedError

    def describe(self) -> str:
        doc = (type(self).__doc__ or "").strip().splitlines()
        return doc[0] if doc else self.name

    def _finding(self, record: ProgramRecord, message: str,
                 **detail: Any) -> Finding:
        return Finding(rule=self.name, program=record.label,
                       message=message, detail=detail)


class NoHostTransfer(Rule):
    """No host callbacks: the replan path must dispatch asynchronously."""

    name = "no_host_transfer"

    def findings(self, record: ProgramRecord) -> Iterator[Finding]:
        for eqn in iter_eqns(record.jaxpr):
            if eqn.primitive.name in HOST_CALLBACK_PRIMS:
                yield self._finding(
                    record,
                    f"'{eqn.primitive.name}' forces a host round-trip inside "
                    "the compiled program; keep the replan path "
                    "device-resident (trace the decision with lax ops, or "
                    "move the host work outside the jitted program)",
                    primitive=eqn.primitive.name)


class _PairwiseShapeRule(Rule):
    """Shared shape predicate: a (>=U, >=U, M) trailing-3 output with equal
    receiver/interferer axes is the materialized pairwise tensor; leading
    batch dims (vmapped fleet programs) are ignored. The squareness check
    keeps per-split solver stacks like (2, S, U, M) from false-flagging
    when the split count happens to exceed U at toy scale."""

    def __init__(self, n_users: int):
        self.n_users = int(n_users)

    def _pairwise(self, shape: tuple[int, ...]) -> bool:
        return (len(shape) >= 3 and shape[-3] == shape[-2]
                and shape[-3] >= self.n_users)


class NoPairwiseIntermediate(_PairwiseShapeRule):
    """No (U, V, M) arithmetic outside the kernels: the pairwise tensor
    must only stream through pallas_call block by block."""

    name = "no_pairwise_intermediate"

    def findings(self, record: ProgramRecord) -> Iterator[Finding]:
        for eqn in iter_eqns(record.jaxpr, enter_pallas=False):
            if eqn.primitive.name not in PAIRWISE_ARITH:
                continue
            for shape in out_shapes(eqn):
                if self._pairwise(shape):
                    yield self._finding(
                        record,
                        f"'{eqn.primitive.name}' materializes a pairwise "
                        f"{shape} intermediate (O(U^2 M) memory at paper "
                        "scale); route the SINR reduction through the "
                        "Pallas kernels (backend='pallas'), which stream "
                        "it in (BU, BV, BM) blocks",
                        primitive=eqn.primitive.name, shape=list(shape))


class NoGatherAbove(_PairwiseShapeRule):
    """No (>=U, >=U, M) gather: AP-indexed gain selection happens in-kernel
    from the raw (U, N, M) state, never as a materialized g[:, ap, :]."""

    name = "no_gather_above"

    def findings(self, record: ProgramRecord) -> Iterator[Finding]:
        for eqn in iter_eqns(record.jaxpr, enter_pallas=False):
            if eqn.primitive.name != "gather":
                continue
            for shape in out_shapes(eqn):
                if self._pairwise(shape):
                    yield self._finding(
                        record,
                        f"gather materializes an AP-indexed {shape} gain "
                        "tensor; the gather-free kernels select the serving "
                        "AP in-kernel from the raw (U, N, M) state -- index "
                        "per scan step or move the selection into the "
                        "kernel (see li_gd.greedy_round_up)",
                        shape=list(shape))


class NoPad3D(Rule):
    """No rank-3 pad: kernel operands enter pallas_call unpadded; boundary
    blocks are masked in-kernel (cdiv over-coverage + iota masks)."""

    name = "no_pad_3d"

    def findings(self, record: ProgramRecord) -> Iterator[Finding]:
        for eqn in iter_eqns(record.jaxpr, enter_pallas=False):
            if eqn.primitive.name != "pad":
                continue
            for shape in out_shapes(eqn):
                if len(shape) >= 3:
                    yield self._finding(
                        record,
                        f"pad copies a rank-{len(shape)} tensor {shape} "
                        "(a _pad_to of a kernel operand); pass operands "
                        "unpadded and mask the boundary block in-kernel "
                        "against the true extent",
                        shape=list(shape))


class VmemCeiling(Rule):
    """Every pallas_call's per-block VMEM working set (inputs + outputs +
    scratch, derived from the kernel body's memory refs) fits the budget."""

    name = "vmem_ceiling"

    def __init__(self, budget_bytes: int = VMEM_CEILING_BYTES):
        self.budget_bytes = int(budget_bytes)

    def findings(self, record: ProgramRecord) -> Iterator[Finding]:
        for pc in pallas_calls(record.jaxpr):
            if pc.vmem_bytes >= self.budget_bytes:
                yield self._finding(
                    record,
                    f"kernel '{pc.name}' needs {pc.vmem_bytes} bytes of "
                    f"VMEM per block, over the {self.budget_bytes}-byte "
                    "budget; shrink the (BU, BV, BM, BN) block sizes "
                    "(see noma_rates.AUTOTUNE_BLOCKS for vetted candidates)",
                    kernel=pc.name, vmem_bytes=pc.vmem_bytes,
                    budget_bytes=self.budget_bytes)


class SparseGrid(Rule):
    """The tile-driven intra/SIC kernels (the programs' only scalar-prefetch
    pallas_calls) launch exactly the expected tile count."""

    name = "sparse_grid"

    def __init__(self, expected_tiles: int, require: bool = True):
        # expected_tiles: CellLayout.n_tiles when a layout is threaded, or
        # noma_rates.dense_tile_count(...) for the dense fallback schedule.
        self.expected_tiles = int(expected_tiles)
        self.require = require

    def findings(self, record: ProgramRecord) -> Iterator[Finding]:
        intra = [pc for pc in pallas_calls(record.jaxpr)
                 if pc.num_scalar_prefetch == 2]
        if not intra and self.require:
            yield self._finding(
                record,
                "no tile-driven intra/SIC kernel (a pallas_call with 2 "
                "scalar-prefetch operands) found; the program does not run "
                "the cell-block SIC path at all",
            )
            return
        for pc in intra:
            # The tile axis is the innermost grid dim; vmapped fleet
            # programs prepend the batch dim, leaving it in place.
            if pc.grid[-1] != self.expected_tiles:
                yield self._finding(
                    record,
                    f"intra kernel '{pc.name}' launches grid {pc.grid} "
                    f"({pc.grid[-1]} tiles) but the schedule expects "
                    f"{self.expected_tiles}; the tile list does not match "
                    "the CellLayout (rebuild the layout for this env/blocks, "
                    "or expect dense_tile_count for the no-layout path)",
                    kernel=pc.name, grid=list(pc.grid),
                    expected_tiles=self.expected_tiles)


class StableSignature(Rule):
    """Program outputs carry no weak types -- a weak-f32 leaf in a cold
    PlanState re-traces the warm program on the first replan (the PR 3
    recompile bug). compare() checks full cold/warm aval agreement."""

    name = "stable_signature"

    def findings(self, record: ProgramRecord) -> Iterator[Finding]:
        for i, aval in enumerate(record.closed.out_avals):
            if getattr(aval, "weak_type", False):
                yield self._finding(
                    record,
                    f"output {i} ({aval}) is weak-typed; feeding it back as "
                    "a warm-start operand re-traces the program (route "
                    "outputs through planning.engine._strong_typed)",
                    output_index=i, aval=str(aval))

    @classmethod
    def compare(cls, label: str, a: Any, b: Any) -> list[Finding]:
        """Signature agreement between two aval pytrees (jax.eval_shape
        outputs): identical treedefs and per-leaf shape/dtype/weak_type.
        Used to prove warm(warm(state)) traces identically to warm(state)."""
        rule = cls()
        findings: list[Finding] = []
        la, ta = jax.tree.flatten(a)
        lb, tb = jax.tree.flatten(b)
        record = ProgramRecord(label=label, closed=None)  # label carrier only
        if ta != tb:
            findings.append(rule._finding(
                record, f"signature tree structure changed: {ta} != {tb}"))
            return findings
        for i, (xa, xb) in enumerate(zip(la, lb)):
            sig_a = (tuple(xa.shape), str(xa.dtype),
                     bool(getattr(xa, "weak_type", False)))
            sig_b = (tuple(xb.shape), str(xb.dtype),
                     bool(getattr(xb, "weak_type", False)))
            if sig_a != sig_b:
                findings.append(rule._finding(
                    record,
                    f"leaf {i} signature changed across epochs: "
                    f"{sig_a} != {sig_b} (shape, dtype, weak_type); the "
                    "warm program would recompile every epoch",
                    leaf=i, before=list(map(str, sig_a)),
                    after=list(map(str, sig_b))))
        return findings


# The memory-model rules that only make sense for Pallas-backed programs
# (the einsum reference legitimately materializes the pairwise tensor).
def kernel_rules(n_users: int,
                 expected_tiles: int,
                 budget_bytes: int = VMEM_CEILING_BYTES) -> list[Rule]:
    return [
        NoPairwiseIntermediate(n_users),
        NoGatherAbove(n_users),
        NoPad3D(),
        VmemCeiling(budget_bytes),
        SparseGrid(expected_tiles),
    ]


# Backend-independent program discipline.
def base_rules() -> list[Rule]:
    return [NoHostTransfer(), StableSignature()]
