"""Generic jaxpr visitor for the program auditor.

One traversal implementation serves every rule: it recurses through any
equation parameter that holds a sub-jaxpr (closed calls / pjit, scan and
while bodies, cond branches, custom_vjp/custom_jvp call jaxprs) and knows
how to present ``pallas_call`` equations structurally -- the launch grid,
the scalar-prefetch operand count, and the kernel body's VMEM working set
derived from the body's memory-ref avals (which matches the analytic
``noma_rates.vmem_block_bytes`` exactly for the NOMA kernels; asserted in
tests/test_analysis_rules.py).

The previous per-test walkers in tests/test_grad_kernels.py and
tests/test_cell_layout.py are re-expressed on top of this module via the
rule catalog (analysis/rules.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import jax
import jax.core
import numpy as np

Jaxpr = jax.core.Jaxpr
ClosedJaxpr = jax.core.ClosedJaxpr


def subjaxprs(param: Any) -> Iterator[Jaxpr]:
    """Yield every (open) jaxpr held by one equation parameter value."""
    vals = param if isinstance(param, (tuple, list)) else [param]
    for p in vals:
        if isinstance(p, ClosedJaxpr):
            yield p.jaxpr
        elif isinstance(p, Jaxpr):
            yield p


def iter_eqns(jaxpr: Jaxpr, enter_pallas: bool = False) -> Iterator[Any]:
    """Every equation of ``jaxpr`` and its sub-jaxprs, depth-first.

    enter_pallas=False (the default, and what the memory-model rules want)
    yields ``pallas_call`` equations themselves but does NOT descend into
    their kernel bodies: the body works on (block,) VMEM refs that at toy
    scale can numerically look like full-tensor shapes but are streamed,
    not materialized.
    """
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not enter_pallas:
            continue
        for param in eqn.params.values():
            for sub in subjaxprs(param):
                yield from iter_eqns(sub, enter_pallas=enter_pallas)


def out_shapes(eqn: Any) -> list[tuple[int, ...]]:
    """Output aval shapes of one equation (missing avals -> ())."""
    return [tuple(getattr(v.aval, "shape", ())) for v in eqn.outvars]


def _is_smem(aval: Any) -> bool:
    ms = getattr(aval, "memory_space", None)
    return ms is not None and "smem" in str(ms).lower()


def _ref_bytes(aval: Any) -> int:
    shape = tuple(getattr(aval, "shape", ()))
    dtype = getattr(aval, "dtype", None)
    itemsize = np.dtype(dtype).itemsize if dtype is not None else 4
    return int(np.prod(shape, dtype=np.int64)) * itemsize


@dataclasses.dataclass(frozen=True)
class PallasCallInfo:
    """Structural summary of one ``pallas_call`` equation.

    grid                 launch grid (vmapped calls carry the batch dim
                         prepended; the trailing dims are the kernel's own).
    num_scalar_prefetch  SMEM scalar-prefetch operand count (the tile-driven
                         intra/SIC kernel is the only NOMA kernel with 2:
                         its (tile_r, tile_s) lists).
    vmem_bytes           working set of one kernel invocation: the summed
                         byte sizes of every non-SMEM memory ref the body
                         binds (inputs + outputs + scratch) -- block-shaped,
                         so independent of vmap batching.
    name                 kernel name when the jaxpr records one.
    """

    grid: tuple[int, ...]
    num_scalar_prefetch: int
    vmem_bytes: int
    name: str = "pallas_call"


def pallas_call_info(eqn: Any) -> PallasCallInfo:
    gm = eqn.params["grid_mapping"]
    body = eqn.params["jaxpr"]
    if isinstance(body, ClosedJaxpr):
        body = body.jaxpr
    vmem = sum(_ref_bytes(v.aval) for v in body.invars
               if not _is_smem(v.aval))
    name = str(eqn.params.get("name_and_src_info",
                              eqn.params.get("name", "pallas_call")))
    # name_and_src_info stringifies as "<name> at <file>:<line>"; keep the name
    name = name.split(" at ")[0] or "pallas_call"
    return PallasCallInfo(
        grid=tuple(int(g) for g in gm.grid),
        num_scalar_prefetch=int(getattr(gm, "num_index_operands", 0)),
        vmem_bytes=int(vmem),
        name=name,
    )


def pallas_calls(jaxpr: Jaxpr) -> list[PallasCallInfo]:
    """Every pallas_call in the program, in traversal order."""
    return [pallas_call_info(e) for e in iter_eqns(jaxpr, enter_pallas=False)
            if e.primitive.name == "pallas_call"]


def trace(fn: Callable, *args: Any, **kwargs: Any) -> ClosedJaxpr:
    """The program under audit: jax.make_jaxpr of ``fn`` at these avals.

    Tracing only -- nothing executes, so auditing an interpret-mode Pallas
    program at paper scale is cheap. Arguments may be concrete arrays or
    jax.ShapeDtypeStruct avals (e.g. a PlanState from jax.eval_shape fed
    back into a replan program)."""
    return jax.make_jaxpr(fn)(*args, **kwargs)
