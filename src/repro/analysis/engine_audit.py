"""Engine-level audits: PlannerEngine programs and cache discipline.

Two layers:

* audit_engine -- trace-only. Pulls the engine's compiled plan/replan/
  replan_many programs via engine.program()/program_args() (jax.make_jaxpr,
  nothing executes) and runs the rule catalog over each, plus the
  cold->warm->warm signature chain via jax.eval_shape: replan fed its own
  output must trace to byte-identical avals, or every epoch recompiles
  (the PR 3 weak-type bug, now machine-checked).

* CacheKeyDiscipline / runtime_probe -- probe a LIVE engine. The former
  perturbs the engine (same shape, new kind, new shape, gate retune, cfg
  change) and asserts the compiled-program cache grows exactly when it
  should; the latter executes the replan path on a small env under
  planning.compile_log() and jax.transfer_guard to prove the exact compile
  count and zero-host-transfer dispatch dynamically.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.analysis.audit import audit
from repro.analysis.report import AuditReport, Finding, merge_reports
from repro.analysis.rules import (
    Rule,
    StableSignature,
    base_rules,
    kernel_rules,
)
from repro.core.types import NetworkEnv
from repro.kernels.noma_rates import dense_tile_count
from repro.planning.engine import PlannerEngine, compile_log, stack_envs


def engine_rules(engine: PlannerEngine, env: NetworkEnv) -> list[Rule]:
    """The catalog subset an engine program must satisfy. Memory-model rules
    apply only to Pallas-backed programs: the einsum reference legitimately
    materializes the pairwise tensor (that is what it is for). The engine
    traces the dense tile schedule today (layout=None -- see the ROADMAP
    engine-threading item, whose acceptance gate is this expectation moving
    to CellLayout.n_tiles)."""
    u = int(env.g_up.shape[-3])
    rules = base_rules()
    if engine.sinr_backend != "einsum":
        rules += kernel_rules(u, expected_tiles=dense_tile_count(u, u))
    return rules


def audit_engine(
    engine: PlannerEngine,
    env: NetworkEnv,
    fleet: int = 2,
    label: str | None = None,
    rules: list[Rule] | None = None,
) -> AuditReport:
    """Audit the engine's plan, replan and replan_many programs for ``env``
    (trace-only; cheap even for paper-scale interpret-mode programs)."""
    label = label or engine.sinr_backend
    rules = engine_rules(engine, env) if rules is None else rules
    reports = []

    plan_fn = engine.program("plan", env)
    plan_args = engine.program_args("plan", env)
    reports.append(audit(plan_fn, *plan_args, rules=rules,
                         label=f"{label}:plan"))

    # replan, traced at the avals a cold plan would hand it
    cold = jax.eval_shape(plan_fn, *plan_args)
    replan_fn = engine.program("replan", env)
    replan_args = engine.program_args("replan", env, prev=cold)
    rep = audit(replan_fn, *replan_args, rules=rules,
                label=f"{label}:replan")
    # the signature chain: replan fed its own output must agree with itself
    warm = jax.eval_shape(replan_fn, *replan_args)
    warm2 = jax.eval_shape(
        replan_fn, *engine.program_args("replan", env, prev=warm))
    rep.findings.extend(
        StableSignature.compare(f"{label}:replan", warm, warm2))
    reports.append(rep)

    # the fleet path: vmapped pallas_calls prepend the batch dim to the
    # grid; the rules read the trailing dims, so the same set applies
    envs = stack_envs([env] * fleet)
    many_fn = engine.program("replan_many", envs)
    cold_many = jax.eval_shape(engine.program("plan_many", envs),
                               *engine.program_args("plan_many", envs))
    many_args = engine.program_args("replan_many", envs, prev=cold_many)
    reports.append(audit(many_fn, *many_args, rules=rules,
                         label=f"{label}:replan_many"))
    return merge_reports(reports)


class CacheKeyDiscipline:
    """Probes a live engine with config perturbations and asserts the
    compiled-program cache grows exactly when it should: reuse on identical
    dispatch, a new entry per kind / env shape / gate retune / cfg change.
    Trace-only (engine.program builds cache entries without executing).

    Probe a FRESH engine: pre-existing cache entries shift the expected
    counts. The engine's warm_rho_min and cfg are restored on exit."""

    name = "cache_key_discipline"

    def probe(self, engine: PlannerEngine, env: NetworkEnv,
              env_other_shape: NetworkEnv | None = None,
              label: str = "engine") -> AuditReport:
        report = AuditReport(programs=[f"{label}:cache"], rules=[self.name])

        def expect(step: str, want: int):
            got = engine.cache_size()
            if got != want:
                report.findings.append(Finding(
                    rule=self.name, program=f"{label}:cache",
                    message=(
                        f"after {step} the compiled-program cache holds "
                        f"{got} entries, expected {want}; the cache key "
                        "(kind, env shape, cfg, method, rounding, "
                        "warm_rho_min, warm_moment_decay) is not minting "
                        "entries exactly when dispatch semantics change"),
                    detail={"step": step, "got": got, "want": want}))

        base = engine.cache_size()
        engine.program("plan", env)
        expect("first plan program", base + 1)
        engine.program("plan", env)
        expect("repeat plan program (must reuse)", base + 1)
        engine.program("replan", env)
        expect("new kind (replan)", base + 2)
        if env_other_shape is not None:
            engine.program("plan", env_other_shape)
            expect("new env shape", base + 3)
            base += 1
        old_gate = engine.warm_rho_min
        old_cfg = engine.cfg
        try:
            engine.warm_rho_min = 0.25 if old_gate != 0.25 else 0.75
            engine.program("replan", env)
            expect("warm_rho_min retune (must recompile)", base + 3)
            engine.cfg = dataclasses.replace(
                old_cfg, max_iters=old_cfg.max_iters + 1)
            engine.program("plan", env)
            expect("cfg change (must recompile)", base + 4)
        finally:
            engine.warm_rho_min = old_gate
            engine.cfg = old_cfg
        return report


def runtime_probe(engine: PlannerEngine, env: NetworkEnv,
                  env_second: NetworkEnv | None = None,
                  label: str = "engine") -> AuditReport:
    """Execute the plan->replan->replan chain on a (small) env and check the
    dynamic invariants a trace can't: the chain compiles exactly one plan
    and one replan program -- a second env of the same shape, and the warm
    state fed back, reuse them -- and steady-state replan dispatch moves no
    host data (jax.transfer_guard). Probe a FRESH engine constructed with
    explicit weights (deriving weights per call allocates on host and would
    trip the guard by design)."""
    report = AuditReport(programs=[f"{label}:runtime"],
                         rules=["stable_signature", "no_host_transfer"])
    with compile_log() as log:
        state = engine.plan(env)
        state = engine.replan(state, env)
        state = engine.replan(state, env)
        if env_second is not None:
            s2 = engine.plan(env_second)
            s2 = engine.replan(s2, env_second)
            jax.block_until_ready(s2.plan.utility)
    jax.block_until_ready(state.plan.utility)
    if log != ["plan", "replan"]:
        report.findings.append(Finding(
            rule="stable_signature", program=f"{label}:runtime",
            message=(
                f"cold->warm->warm{'->second-env' if env_second is not None else ''} "
                f"chain traced {log}, expected ['plan', 'replan']: the warm "
                "output's avals differ from the cold ones (weak types?) or "
                "the cache key churns -- every epoch would recompile"),
            detail={"compile_log": list(log)}))
    # make_env leaves the radio/comp constants as python floats; a device-
    # resident pipeline (Scenario.env_many is jitted) has them on device
    # already, so place them once before the guarded dispatch.
    env_dev = jax.device_put(env)
    try:
        with jax.transfer_guard("disallow"):
            state = engine.replan(state, env_dev)
        jax.block_until_ready(state.plan.utility)
    except Exception as e:  # noqa: BLE001 -- the guard raises RuntimeError
        report.findings.append(Finding(
            rule="no_host_transfer", program=f"{label}:runtime",
            message=(
                "steady-state replan dispatch transferred data to/from host "
                f"under jax.transfer_guard('disallow'): {e}; keep the gate, "
                "moment decay and warm payload on device"),
            detail={"error": str(e)}))
    return report
