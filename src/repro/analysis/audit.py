"""ProgramAudit: trace a function once and run a rule set over its jaxpr.

    from repro import analysis

    report = analysis.audit(fn, *args, rules=[analysis.NoPad3D(), ...])
    report.raise_if_failed()

Auditing is trace-only (jax.make_jaxpr): nothing executes, so a
paper-scale interpret-mode Pallas program audits in milliseconds.
Arguments may be concrete arrays or jax.ShapeDtypeStruct avals.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

from repro.analysis.report import AuditReport
from repro.analysis.rules import ProgramRecord, Rule
from repro.analysis.visitor import ClosedJaxpr, trace


def audit_jaxpr(closed: ClosedJaxpr, rules: Sequence[Rule],
                label: str = "program") -> AuditReport:
    """Run ``rules`` over an already-traced program."""
    record = ProgramRecord(label=label, closed=closed)
    report = AuditReport(programs=[label], rules=[r.name for r in rules])
    for rule in rules:
        report.findings.extend(rule.check(record))
    return report


def audit(fn: Callable, *args: Any, rules: Sequence[Rule],
          label: str | None = None, **kwargs: Any) -> AuditReport:
    """Trace ``fn(*args, **kwargs)`` and audit its jaxpr against ``rules``."""
    if label is None:
        label = getattr(fn, "__name__", None) or "program"
    return audit_jaxpr(trace(fn, *args, **kwargs), rules, label=label)
