"""Durable-serving audits: crash recovery must be bit-exact, retrace-free,
and deterministically replayable.

Two executing probes over a chaos-hardened OnlineLoop (same small
configuration as fault_audit), machine-checking the ISSUE-10 acceptance
criteria:

* resume_probe -- run T epochs uninterrupted (arm A) and T epochs with a
  mid-episode crash + snapshot restore (arm B, driven by CrashSupervisor
  over a SnapshotStore). The two final serving states must agree
  leaf-for-leaf (device tree: plans, warm Adam payload, QoS rings,
  telemetry EMA, fault Markov state, PRNG key) and counter-for-counter
  (host: server + degradation-ladder state machines). Arm B's flight
  recorder is then replayed from the journal alone: the served
  (s*, health) trajectory must reproduce with no divergence.

* retrace_probe -- snapshot a warmed loop, restore it into a *fresh*
  process stand-in (new loop + engine from the same factory), warm the
  fresh programs, then run steady-state epochs (including a snapshot
  export) under planning.compile_log: nothing may trace, and the fresh
  engine's compiled-program cache must be no larger than the
  uninterrupted loop's -- restored leaves hit the exact avals the live
  programs were compiled for (StableSignature, restore edition).
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np

from repro.analysis.report import AuditReport, Finding, merge_reports
from repro.core.types import GdConfig

# Active but moderate chaos: the ladder gets exercised across the crash
# while most epochs still serve planner output.
CHAOS = dict(link_outage_rate=0.1, fade_depth=1e-6, ap_outage_rate=0.02,
             telemetry_drop_rate=0.05, service_spike_rate=0.02)

T_EPOCHS = 18
CADENCE = 6
CRASH_AT = 14          # between cadences: restore rewinds to epoch 12


def _factory():
    from repro.core import profiles
    from repro.faults import FaultConfig, LadderConfig
    from repro.online import OnlineLoop, ServiceConfig, StreamConfig
    from repro.planning import PlannerEngine
    from repro.scenarios import Scenario, ScenarioConfig

    eng = PlannerEngine(profiles.nin(),
                        cfg=GdConfig(step_size=3e-2, max_iters=30,
                                     optimizer="adam"))
    scen = Scenario(ScenarioConfig(n_users=6, n_aps=2, n_sub=3,
                                   fading_rho=0.95))
    return OnlineLoop(
        scen, eng,
        StreamConfig(arrival_rate_hz=20.0, epoch_dt_s=0.02, deadline_s=0.2),
        ServiceConfig(edge_capacity=4, queue_depth=8, load_gain=4.0,
                      replan_every=3, max_work_epochs=200),
        faults=FaultConfig(**CHAOS),
        degrade=LadderConfig(quarantine_epochs=10, baseline_after=2))


def _diff_leaves(tree_a, tree_b) -> list[str]:
    """Key-paths of leaves that differ in value, dtype, or shape."""
    flat_a = jax.tree_util.tree_flatten_with_path(tree_a)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(tree_b)[0]
    bad = []
    for (path, a), (_, b) in zip(flat_a, flat_b):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype != b.dtype or a.shape != b.shape or not np.array_equal(
                a, b, equal_nan=True):
            bad.append(jax.tree_util.keystr(path))
    return bad


def resume_probe(label: str = "recovery") -> AuditReport:
    """Crash + restore vs uninterrupted: final state equal leaf-for-leaf;
    journal replay reproduces the served trajectory exactly."""
    from repro.state import (
        FlightRecorder,
        SimulatedCrash,
        SnapshotConfig,
        SnapshotStore,
        read_journal,
        replay,
    )
    from repro.state.supervisor import CrashSupervisor

    report = AuditReport(
        programs=[f"{label}:resume", f"{label}:replay"],
        rules=["bit_exact_resume", "replay_divergence"])
    key = jax.random.PRNGKey(0)
    with tempfile.TemporaryDirectory() as td:
        sup_a = CrashSupervisor(_factory)
        sup_a.run(key, T_EPOCHS)
        dev_a, host_a = sup_a.loop.serving_state()

        rec = FlightRecorder(os.path.join(td, "flight.jsonl"))
        store = SnapshotStore(
            os.path.join(td, "snaps"),
            SnapshotConfig(every=CADENCE, keep_n=2, asynchronous=False))
        armed = [True]

        def chaos(next_epoch: int) -> None:
            if next_epoch == CRASH_AT and armed[0]:
                armed[0] = False
                raise SimulatedCrash("injected mid-episode kill")

        sup_b = CrashSupervisor(_factory, store=store, recorder=rec)
        sup_b.run(key, T_EPOCHS, seed=0, chaos=chaos)
        dev_b, host_b = sup_b.loop.serving_state()
        rec.close()

        if not sup_b.restored_from or sup_b.restored_from[0] <= 0:
            report.findings.append(Finding(
                rule="bit_exact_resume", program=f"{label}:resume",
                message=("the crash arm never restored from a snapshot "
                         "(cold start instead) -- the probe is vacuous"),
                detail={"restored_from": sup_b.restored_from,
                        "cold_restarts": sup_b.cold_restarts}))
        bad = _diff_leaves(dev_a, dev_b)
        if bad:
            report.findings.append(Finding(
                rule="bit_exact_resume", program=f"{label}:resume",
                message=(f"{len(bad)} device leaves differ between the "
                         f"uninterrupted run and the crashed-and-restored "
                         f"run after {T_EPOCHS} epochs: {bad[:6]}"),
                detail={"leaves": bad}))
        if json.dumps(host_a, sort_keys=True) != json.dumps(
                host_b, sort_keys=True):
            report.findings.append(Finding(
                rule="bit_exact_resume", program=f"{label}:resume",
                message=("host control-plane state (server/ladder counters) "
                         "differs across the restore"),
                detail={"uninterrupted": host_a, "restored": host_b}))

        records, clean = read_journal(os.path.join(td, "flight.jsonl"))
        if not clean or not records:
            report.findings.append(Finding(
                rule="replay_divergence", program=f"{label}:replay",
                message="flight journal unreadable or empty",
                detail={"records": len(records), "clean": clean}))
        else:
            res = replay(records, _factory)
            if res["divergence"] is not None:
                report.findings.append(Finding(
                    rule="replay_divergence", program=f"{label}:replay",
                    message=(
                        "journal replay diverged from the recorded served "
                        f"trajectory at epoch {res['divergence']['t']}"),
                    detail=res["divergence"]))
    return report


def retrace_probe(label: str = "recovery") -> AuditReport:
    """Restore into a fresh loop must mint zero steady-state compiles and
    no extra engine cache entries beyond the uninterrupted run's."""
    from repro.planning.engine import compile_log
    from repro.state import load_snapshot, save_snapshot

    report = AuditReport(
        programs=[f"{label}:retrace"],
        rules=["stable_signature", "cache_key_discipline"])
    key = jax.random.PRNGKey(0)
    with tempfile.TemporaryDirectory() as td:
        loop = _factory()
        loop.reset(key)
        for _ in range(2 * CADENCE):
            loop.step_epoch()
        save_snapshot(td, loop)
        cache_ref = loop.engine.cache_size()

        fresh = _factory()                 # new engine: a process restart
        fresh.reset(key)
        load_snapshot(td, fresh, 2 * CADENCE)
        for _ in range(2 * fresh.service_cfg.replan_every):  # warm programs
            fresh.step_epoch()
        with compile_log() as log:
            for _ in range(CADENCE):
                fresh.step_epoch()
            fresh.serving_state()          # the snapshot export path too
        if log:
            report.findings.append(Finding(
                rule="stable_signature", program=f"{label}:retrace",
                message=(
                    f"steady state after a snapshot restore traced {log}; "
                    "restored leaves must have the live programs' exact "
                    "avals so resume mints zero compiles"),
                detail={"compile_log": list(log)}))
        if fresh.engine.cache_size() > cache_ref:
            report.findings.append(Finding(
                rule="cache_key_discipline", program=f"{label}:retrace",
                message=(
                    f"restore grew the engine cache to "
                    f"{fresh.engine.cache_size()} entries vs {cache_ref} "
                    "uninterrupted; restored state must not mint new "
                    "compiled programs"),
                detail={"restored": fresh.engine.cache_size(),
                        "uninterrupted": cache_ref}))
    return report


def audit_recovery(label: str = "recovery") -> AuditReport:
    """The full durable-serving audit (both probes execute the loop)."""
    return merge_reports([resume_probe(label=label),
                          retrace_probe(label=label)])
