"""repro.analysis: static program audits for the kernel/engine invariants.

The PRs 3-6 performance claims rest on structural properties of the
compiled programs (no host transfers, no materialized pairwise tensors,
block-sparse SIC grids, VMEM-bounded kernels, recompile-free warm starts).
This package makes them machine-checked: a generic jaxpr visitor, a rule
catalog, an ``audit(fn, *args, rules=[...])`` entry point, engine-level
probes, and a CLI (``python -m repro.analysis``) that audits the engine's
plan/replan/replan_many programs across presets and SINR backends and
emits a JSON report. See README "Program invariants".
"""
from repro.analysis.audit import audit, audit_jaxpr  # noqa: F401
from repro.analysis.engine_audit import (  # noqa: F401
    CacheKeyDiscipline,
    audit_engine,
    engine_rules,
    runtime_probe,
)
from repro.analysis.fault_audit import (  # noqa: F401
    audit_faults,
    chaos_loop_probe,
    guard_trace_audit,
)
from repro.analysis.online_audit import (  # noqa: F401
    audit_online,
    audit_online_replan,
    online_feedback_probe,
    online_loop_probe,
)
from repro.analysis.recovery_audit import (  # noqa: F401
    audit_recovery,
    resume_probe,
    retrace_probe,
)
from repro.analysis.report import (  # noqa: F401
    AuditError,
    AuditReport,
    Finding,
    merge_reports,
)
from repro.analysis.rules import (  # noqa: F401
    HOST_CALLBACK_PRIMS,
    PAIRWISE_ARITH,
    NoGatherAbove,
    NoHostTransfer,
    NoPad3D,
    NoPairwiseIntermediate,
    ProgramRecord,
    Rule,
    SparseGrid,
    StableSignature,
    VmemCeiling,
    base_rules,
    kernel_rules,
)
from repro.analysis.visitor import (  # noqa: F401
    PallasCallInfo,
    iter_eqns,
    pallas_calls,
    trace,
)

CATALOG: tuple[type, ...] = (
    NoHostTransfer, NoPairwiseIntermediate, NoGatherAbove, NoPad3D,
    VmemCeiling, SparseGrid, StableSignature, CacheKeyDiscipline,
)
