"""Fault-tolerant checkpointing (numpy-based; orbax is not available offline).

Properties:
  * atomic: writes go to <dir>/tmp.<step> then os.replace -> step_<N>; a
    crash mid-write never corrupts the latest checkpoint.
  * async: save() returns immediately, a background thread serializes; the
    train loop keeps stepping (snapshot is taken on the caller's thread via
    jax.device_get so the arrays are immutable).
  * elastic: files store *global* arrays per host-shard; restore re-shards
    onto whatever mesh/device-count the new job uses (device count changes
    between save and restore are fine -- shardings are recomputed from the
    logical specs, not persisted).
  * bounded retention: keep_n newest checkpoints are retained.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree, process_index: int = 0):
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}.{process_index}")
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    flat, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(x)) for i, x in enumerate(flat)}
    np.savez(os.path.join(tmp, f"shard_{process_index}.npz"), **arrays)
    meta = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(flat),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_checkpoint(directory: str, tree_like, step: int | None = None,
                    shardings=None):
    """Restore into the structure of `tree_like`; device_put with `shardings`
    (pytree of NamedSharding) re-shards for the current mesh (elastic)."""
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_")
    )
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "shard_0.npz"))
    flat, treedef = _flatten(tree_like)
    assert len(flat) == len(data.files), (
        f"checkpoint has {len(data.files)} leaves, model expects {len(flat)}")
    loaded = [data[f"a{i}"] for i in range(len(flat))]
    if shardings is not None:
        sflat, _ = _flatten(shardings)
        loaded = [jax.device_put(a, s) for a, s in zip(loaded, sflat)]
    else:
        loaded = [jax.numpy.asarray(a) for a in loaded]
    return jax.tree_util.tree_unflatten(treedef, loaded), step


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree):
        self.wait()
        # snapshot on caller thread: device_get makes host copies now
        flat, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in flat]
        snap = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            try:
                save_checkpoint(self.directory, step, snap)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self):
        try:
            steps = sorted(
                int(d.split("_")[1]) for d in os.listdir(self.directory)
                if d.startswith("step_"))
            return steps[-1] if steps else None
        except FileNotFoundError:
            return None

    def restore(self, tree_like, shardings=None, step=None):
        return load_checkpoint(self.directory, tree_like, step, shardings)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_"))
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
