"""Fault-tolerant checkpointing (numpy-based; orbax is not available offline).

Properties:
  * atomic: writes go to <dir>/tmp.<step>, then are *promoted* into
    step_<N>. Promotion never opens a lost-update window: an existing
    step_<N> is renamed aside (atomic), the tmp dir os.replace's into
    place (atomic), and only then is the aside removed. A crash at any
    instant leaves either the old copy (possibly under the aside name --
    repaired by the next reader/writer) or the new one, never neither.
  * validated: meta.json records the treedef string, per-leaf dtypes,
    shapes and CRC-32s; load_checkpoint verifies all of them against the
    caller's `tree_like` and the bytes actually read, raising
    SnapshotIntegrityError instead of silently mis-unflattening.
  * async: save() returns immediately, a background thread serializes; the
    train loop keeps stepping (snapshot is taken on the caller's thread via
    jax.device_get so the arrays are immutable).
  * elastic: files store *global* arrays per host-shard; restore re-shards
    onto whatever mesh/device-count the new job uses (device count changes
    between save and restore are fine -- shardings are recomputed from the
    logical specs, not persisted).
  * bounded retention: keep_n newest checkpoints are retained.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"step_(\d{8})")
_ASIDE_SUFFIX = ".aside"


class SnapshotIntegrityError(RuntimeError):
    """On-disk checkpoint/snapshot data does not match what the caller
    expects (treedef / dtype / shape mismatch, checksum failure, missing or
    unreadable shards). Raised instead of silently mis-unflattening; the
    crash supervisor treats it as "this snapshot is corrupt, fall back to
    an older one"."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _promote(tmp: str, final: str) -> None:
    """Atomically promote ``tmp`` over ``final`` even when ``final`` exists.

    ``os.replace`` cannot replace a non-empty directory, and the obvious
    rmtree-then-replace opens a crash window in which the only copy is
    gone. Rename-aside closes it: the old final moves to ``<final>.aside``
    (atomic), tmp replaces final (atomic), then the aside is deleted.
    ``_recover`` repairs a crash between the renames."""
    aside = final + _ASIDE_SUFFIX
    if os.path.exists(aside):            # stale aside from an old crash
        shutil.rmtree(aside)
    had_old = os.path.exists(final)
    if had_old:
        os.rename(final, aside)
    os.replace(tmp, final)
    if had_old:
        shutil.rmtree(aside, ignore_errors=True)


def _recover(directory: str) -> None:
    """Repair interrupted promotions: a stranded ``<final>.aside`` whose
    final is missing is renamed back into place (the crash hit between the
    two renames); one whose final exists is a superseded copy and is
    removed. Idempotent; called by every reader and writer."""
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return
    for name in names:
        if not name.endswith(_ASIDE_SUFFIX):
            continue
        final = os.path.join(directory, name[: -len(_ASIDE_SUFFIX)])
        aside = os.path.join(directory, name)
        if os.path.exists(final):
            shutil.rmtree(aside, ignore_errors=True)
        else:
            os.rename(aside, final)


def list_steps(directory: str) -> list[int]:
    """Step numbers of complete checkpoints under ``directory``, ascending.
    Only exact ``step_<8 digits>`` names count -- tmp dirs and asides are
    never mistaken for checkpoints."""
    _recover(directory)
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return []
    return sorted(int(m.group(1)) for n in names
                  if (m := _STEP_RE.fullmatch(n)))


def leaf_crc32(a: np.ndarray) -> int:
    """Content checksum of one leaf (dtype/shape are recorded separately)."""
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def save_checkpoint(directory: str, step: int, tree, process_index: int = 0):
    os.makedirs(directory, exist_ok=True)
    _recover(directory)
    tmp = os.path.join(directory, f"tmp.{step}.{process_index}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(x)) for i, x in enumerate(flat)}
    meta = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(flat),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
        "crc32s": [leaf_crc32(a) for a in arrays.values()],
    }
    np.savez(os.path.join(tmp, f"shard_{process_index}.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    _promote(tmp, final)
    return final


def _read_meta(path: str) -> dict[str, Any]:
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SnapshotIntegrityError(
            f"{path}: unreadable meta.json ({e})") from e
    for key in ("treedef", "n_leaves", "dtypes", "shapes"):
        if key not in meta:
            raise SnapshotIntegrityError(f"{path}: meta.json missing {key!r}")
    return meta


def _validate_meta(meta: dict[str, Any], flat: list, treedef, path: str) -> None:
    """Stored structure must match the caller's ``tree_like`` exactly --
    a mismatch means the caller would mis-unflatten (or retrace)."""
    if meta["n_leaves"] != len(flat):
        raise SnapshotIntegrityError(
            f"{path}: checkpoint has {meta['n_leaves']} leaves, "
            f"caller expects {len(flat)}")
    if meta["treedef"] != str(treedef):
        raise SnapshotIntegrityError(
            f"{path}: treedef mismatch\n  stored:   {meta['treedef']}\n"
            f"  expected: {str(treedef)}")
    for i, leaf in enumerate(flat):
        want_dt = np.dtype(jax.numpy.result_type(leaf))
        want_sh = tuple(jax.numpy.shape(leaf))
        got_dt = np.dtype(meta["dtypes"][i])
        got_sh = tuple(meta["shapes"][i])
        if got_dt != want_dt or got_sh != want_sh:
            raise SnapshotIntegrityError(
                f"{path}: leaf {i} is {got_dt}{list(got_sh)}, caller "
                f"expects {want_dt}{list(want_sh)}")


def _load_arrays(path: str, meta: dict[str, Any],
                 process_index: int = 0) -> list[np.ndarray]:
    shard = os.path.join(path, f"shard_{process_index}.npz")
    try:
        with np.load(shard) as data:
            loaded = [data[f"a{i}"] for i in range(meta["n_leaves"])]
    except Exception as e:  # truncated zip, missing member, missing file
        raise SnapshotIntegrityError(
            f"{shard}: unreadable or truncated shard ({e})") from e
    crcs = meta.get("crc32s")
    for i, a in enumerate(loaded):
        if (str(a.dtype) != meta["dtypes"][i]
                or list(a.shape) != meta["shapes"][i]):
            raise SnapshotIntegrityError(
                f"{shard}: leaf {i} is {a.dtype}{list(a.shape)}, meta.json "
                f"says {meta['dtypes'][i]}{meta['shapes'][i]}")
        if crcs is not None and leaf_crc32(a) != crcs[i]:
            raise SnapshotIntegrityError(
                f"{shard}: leaf {i} failed its CRC-32 check")
    return loaded


def load_checkpoint(directory: str, tree_like, step: int | None = None,
                    shardings=None):
    """Restore into the structure of `tree_like`; device_put with `shardings`
    (pytree of NamedSharding) re-shards for the current mesh (elastic).

    The stored meta.json (treedef string, per-leaf dtypes/shapes/CRCs) is
    validated against both `tree_like` and the bytes actually read;
    any mismatch raises SnapshotIntegrityError."""
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    flat, treedef = _flatten(tree_like)
    meta = _read_meta(path)
    _validate_meta(meta, flat, treedef, path)
    loaded = _load_arrays(path, meta)
    if shardings is not None:
        sflat, _ = _flatten(shardings)
        loaded = [jax.device_put(a, s) for a, s in zip(loaded, sflat)]
    else:
        loaded = [jax.numpy.asarray(a) for a in loaded]
    return jax.tree_util.tree_unflatten(treedef, loaded), step


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree):
        self.wait()
        # snapshot on caller thread: device_get makes host copies now
        flat, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in flat]
        snap = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            try:
                save_checkpoint(self.directory, step, snap)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self):
        steps = list_steps(self.directory)
        return steps[-1] if steps else None

    def restore(self, tree_like, shardings=None, step=None):
        return load_checkpoint(self.directory, tree_like, step, shardings)

    def _gc(self):
        for s in list_steps(self.directory)[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
