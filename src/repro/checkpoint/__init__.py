from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager,
    SnapshotIntegrityError,
    leaf_crc32,
    list_steps,
    load_checkpoint,
    save_checkpoint,
)
